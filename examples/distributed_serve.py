"""Worked walkthrough of the distributed influence-serving subsystem.

The paper scales fused BPTs across devices with sample parallelism; this
example applies the same axis to *serving*: the RRR sketch pool is sharded
over a mesh, each device reduces coverage over its local batches, and one
psum merges the partial counts.  Demonstrated end to end:

1. **Shard** a sketch pool over the mesh's ``data`` axis — slot ``i`` is
   bit-identical to what a single-device pool would hold, the mesh only
   picks which device owns it.
2. **Serve** through `DistributedQueryEngine` (one collective per coverage
   reduction) and check the answers are bit-for-bit the single-device ones.
3. **Go async**: a deadline-batched `AsyncFrontEnd` serves a burst of
   threaded clients — flush on full slot or oldest deadline — while a
   background worker refreshes stale shards between dispatches.
4. **Re-shard from a snapshot**: the manifest records the shard layout;
   restore re-slots the same batches onto a *different* mesh shape.

Runs on a laptop: 8 host CPU devices are forced before jax initializes.

    PYTHONPATH=src python examples/distributed_serve.py [--n 2000] [--k 8]
"""
import argparse
import os
import tempfile
import threading
import time

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8").strip()

import jax                   # noqa: E402
import numpy as np           # noqa: E402

from repro.graph import generators                              # noqa: E402
from repro.serve.distributed import (AsyncFrontEnd,             # noqa: E402
                                     DistributedQueryEngine,
                                     ShardedSketchStore)
from repro.serve.influence import (MicroBatcher, PoolConfig,    # noqa: E402
                                   QueryEngine, ResultCache, SketchStore)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--deg", type=float, default=10.0)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--colors", type=int, default=64)
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--budget-mb", type=float, default=8.0,
                    help="PER-SHARD memory budget")
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--deadline-ms", type=float, default=20.0)
    args = ap.parse_args()

    g = generators.powerlaw_cluster(args.n, args.deg, prob=(0.0, 0.25),
                                    seed=1)
    cfg = PoolConfig(num_colors=args.colors, max_batches=64,
                     memory_budget_mb=args.budget_mb, master_seed=7)

    # --- 1. shard a pool over the mesh's data axis -----------------------
    mesh = jax.make_mesh((8,), ("data",))
    store = ShardedSketchStore(g, cfg, mesh)
    t0 = time.time()
    store.ensure(args.batches)
    print(f"sharded pool: {len(store.batches)} batches × {args.colors} "
          f"colors over {store.num_shards} shards in {time.time()-t0:.1f}s "
          f"(per-shard budget admits {store.capacity} total batches; "
          f"layout {store.shard_layout()})")

    # --- 2. distributed answers == single-device answers -----------------
    engine = DistributedQueryEngine(store)
    seeds, sigma = engine.top_k(args.k)
    single = SketchStore(g, cfg)
    single.ensure(args.batches)
    ref_seeds, ref_sigma = QueryEngine(single).top_k(args.k)
    assert np.array_equal(seeds, ref_seeds) and sigma == ref_sigma
    print(f"top-{args.k} over 8 shards: {seeds.tolist()}  σ̂={sigma:.1f}  "
          f"(bit-identical to the single-device engine)")

    # Snapshot NOW, before the async stage: its background refresh will
    # resample slots, and stage 4 asserts the restored pool reproduces
    # these exact pre-refresh answers.
    ckpt = tempfile.mkdtemp(prefix="sharded_pool_")
    store.save(ckpt)

    # --- 3. async deadline-batched serving under client threads ----------
    fe = AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=args.deadline_ms / 1e3,
                       refresh_every=5.0)
    rng = np.random.default_rng(0)
    futs, lock = [], threading.Lock()

    def client(q):
        f = fe.submit_sigma(q)
        with lock:
            futs.append((q, f))

    queries = [rng.integers(0, args.n, 3).tolist()
               for _ in range(args.clients)]
    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(q,)) for q in queries]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    vals = [f.result(timeout=300) for _, f in futs]
    dt = time.perf_counter() - t0
    print(f"async: {args.clients} threaded clients in {dt:.2f}s — "
          f"{fe.stats.flushes} flushes ({fe.stats.slot_flushes} slot-full / "
          f"{fe.stats.deadline_flushes} deadline), worst queue wait "
          f"{fe.stats.max_queue_wait*1e3:.0f} ms "
          f"(deadline {args.deadline_ms:.0f} ms); mean σ̂ {np.mean(vals):.1f}")
    fe.close()

    # --- 4. restore the 8-shard snapshot under 2 shards ------------------
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    restored = ShardedSketchStore.restore(ckpt, g, cfg, mesh2)
    r_seeds, r_sigma = DistributedQueryEngine(restored).top_k(args.k)
    assert np.array_equal(seeds, r_seeds) and sigma == r_sigma
    print(f"elastic restore: snapshot written under "
          f"{ShardedSketchStore.saved_layout(ckpt)['num_shards']} shards, "
          f"restored under {restored.num_shards} — answers bit-identical "
          f"(manifest at {ckpt})")


if __name__ == "__main__":
    main()
