"""Train a small LM end-to-end on CPU: a few hundred steps, visible loss
drop, checkpoint/restart, any of the 10 assigned architectures.

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b \
        --steps 200 [--size 10m] [--crash-demo]

``--size 10m`` scales the reduced config up to ~10M params (CPU-trainable
in minutes); the full configs are exercised via the dry-run, not here.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import registry
from repro.train import loop


def sized_config(arch: str, size: str):
    cfg = registry.smoke(arch)
    if size == "10m":
        cfg = dataclasses.replace(
            cfg, d_model=256, num_layers=max(cfg.num_layers, 4),
            num_heads=8 if cfg.num_heads else 0,
            num_kv_heads=min(cfg.num_kv_heads or 0, 8) or
            (8 if cfg.num_heads else 0),
            head_dim=32 if cfg.num_heads else 0,
            d_ff=1024 if cfg.d_ff else 0, vocab_size=4096,
            ssm_heads=8 if cfg.family in ("ssm", "hybrid") else 0)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=registry.ARCHS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--size", default="smoke", choices=("smoke", "10m"))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--crash-demo", action="store_true",
                    help="inject a crash mid-run and restart from the "
                         "checkpoint (fault-tolerance demo)")
    args = ap.parse_args()

    cfg = sized_config(args.arch, args.size)
    n_params = cfg.param_count()
    print(f"[train_lm] {cfg.name}: ~{n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}×{args.seq_len}")

    ckpt = args.checkpoint_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    if args.crash_demo:
        res = loop.train_with_restarts(
            cfg, steps=args.steps, checkpoint_dir=ckpt,
            crash_schedule=(args.steps // 2,),
            batch=args.batch, seq_len=args.seq_len, lr=args.lr,
            ckpt_every=max(args.steps // 10, 1))
        print(f"[train_lm] survived injected crash; resumed from step "
              f"{res.resumed_from}")
    else:
        res = loop.train(cfg, batch=args.batch, seq_len=args.seq_len,
                         steps=args.steps, lr=args.lr,
                         checkpoint_dir=ckpt,
                         ckpt_every=max(args.steps // 10, 1))
    first, last = res.losses[0], res.losses[-1]
    print(f"[train_lm] loss {first:.3f} → {last:.3f} "
          f"({'LEARNED' if last < first - 0.3 else 'check config'}); "
          f"checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
