"""Unified Sampler API demo: one pool, every backend, identical bits.

Builds the SAME sketch pool under the dense single-device backend and the
shard_map ``data_parallel`` backend (8 forced host devices), verifies the
pools are bit-identical slot for slot (the facade's cross-backend RNG
contract), serves identical top-k answers from both, and reports the
build-time comparison.  Also shows the LT diffusion riding the same spec,
and the ``graph_parallel`` backend on a 2-D (data × model) mesh — the
graph's rows sharded across devices — producing the same bits again.

    PYTHONPATH=src python examples/sampler_backends.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time                     # noqa: E402

import jax                      # noqa: E402
import numpy as np              # noqa: E402

from repro import sampling      # noqa: E402
from repro.graph import generators                          # noqa: E402
from repro.serve.distributed import (DistributedQueryEngine,    # noqa: E402
                                     ShardedSketchStore)
from repro.serve.influence import (PoolConfig, QueryEngine,     # noqa: E402
                                   SketchStore)


def main():
    print("devices:", jax.devices())
    # Dedupe once for every backend: the graph_parallel tile layout needs
    # parallel edges merged, and bit-identity needs one shared edge list.
    from repro.graph import csr
    g = csr.dedupe(generators.powerlaw_cluster(1000, 8.0, prob=0.25, seed=3))
    mesh = jax.make_mesh((8,), ("data",))
    batches, colors = 16, 64

    # One spec per backend — everything else identical.
    dense_spec = sampling.SamplerSpec(diffusion="ic", backend="dense",
                                      num_colors=colors, master_seed=42)
    dp_spec = dense_spec.replace(backend="data_parallel")

    stores = {}
    for name, spec in (("dense", dense_spec), ("data_parallel", dp_spec)):
        cfg = PoolConfig(max_batches=batches, spec=spec)
        store = (ShardedSketchStore(g, cfg, mesh)
                 if name == "data_parallel" else SketchStore(g, cfg))
        store.ensure(1)                          # compile outside the timing
        t0 = time.perf_counter()
        store.ensure(batches)
        dt = time.perf_counter() - t0
        stores[name] = (store, dt)
        print(f"{name:>14}: built {batches} batches × {colors} colors "
              f"in {dt:.2f}s ({(batches - 1) / dt:.1f} batches/s)")

    # --- bit identity: the mesh only decides WHERE a slot is computed ------
    (s_dense, t_dense), (s_dp, t_dp) = stores["dense"], stores["data_parallel"]
    for a, b in zip(s_dense.batches, s_dp.batches):
        assert a.batch_index == b.batch_index
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    print(f"bit-identity: {batches} slots identical across backends "
          f"(dense {t_dense:.2f}s vs shard_map block {t_dp:.2f}s on a "
          "shared-silicon CPU mesh — the ratio is the pod trajectory)")

    # --- identical answers, single-device vs distributed engine ------------
    k = 5
    seeds1, sig1 = QueryEngine(s_dense).top_k(k)
    seeds8, sig8 = DistributedQueryEngine(s_dp).top_k(k)
    assert np.array_equal(seeds1, seeds8) and sig1 == sig8
    print(f"top-{k}: seeds={seeds8.tolist()} σ̂={sig8:.1f} "
          "(bit-identical on both engines)")

    # --- graph parallel: rows over 'model', batches over 'data' ------------
    mesh2d = jax.make_mesh((4, 2), ("data", "model"))
    gp_store = ShardedSketchStore(
        g, PoolConfig(max_batches=batches,
                      spec=dense_spec.replace(backend="graph_parallel")),
        mesh2d)
    gp_store.ensure(batches)
    for a, b in zip(s_dense.batches, gp_store.batches):
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    gp_seeds, gp_sig = DistributedQueryEngine(gp_store).top_k(k)
    assert np.array_equal(seeds1, gp_seeds) and sig1 == gp_sig
    print(f"graph_parallel: rows sharded 2-way, batches 4-way — pool and "
          f"top-{k} still bit-identical (σ̂={gp_sig:.1f})")

    # --- sparse frontier: same bits, work-proportional levels --------------
    # Its regime is a LOW-occupancy frontier (paper Fig. 9: activity
    # collapses after a couple of levels) — the demo graph above is
    # dense-frontier by construction, so sparse shows ~1× there.
    g_lo = csr.dedupe(generators.powerlaw_cluster(4000, 16.0,
                                                  prob=(0.0, 0.05), seed=3))
    lo_spec = dense_spec.replace(tile_size=64)
    sp = sampling.make_sampler(g_lo, lo_spec.replace(frontier="sparse"))
    dn = sampling.make_sampler(g_lo, lo_spec)
    idx = list(range(batches, 2 * batches))
    # Warm with a same-shaped block: jit caches key on the block shape.
    sp.sample_many(list(range(batches)))
    dn.sample_many(list(range(batches)))
    t0 = time.perf_counter(); got = sp.sample_many(idx)
    t_sp = time.perf_counter() - t0
    t0 = time.perf_counter(); ref = dn.sample_many(idx)
    t_dn = time.perf_counter() - t0
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
        assert a.fused_edge_visits == b.fused_edge_visits
    print(f"sparse frontier: {batches} fused batches in {t_sp:.2f}s vs "
          f"dense {t_dn:.2f}s ({t_dn / max(t_sp, 1e-9):.1f}×) — masks AND "
          "work counters bit-identical")

    # --- LT rides the same spec --------------------------------------------
    lt_store = ShardedSketchStore(
        g, PoolConfig(max_batches=batches,
                      spec=dp_spec.replace(diffusion="lt")), mesh)
    lt_store.ensure(8)
    lt_seeds, lt_sig = DistributedQueryEngine(lt_store).top_k(k)
    print(f"LT top-{k}: seeds={lt_seeds.tolist()} σ̂={lt_sig:.1f} "
          "(same facade, diffusion='lt')")


if __name__ == "__main__":
    main()
