"""Quickstart: fused probabilistic traversals in 30 lines.

Runs a fused batch of 64 BPTs on a power-law graph, shows the work saved
vs unfused (Theorem 1 in action, on coupled realizations), and extracts
RRR sets from the visited bitmask.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, traversal
from repro.graph import generators

# 1. a graph: 2,000 vertices, power-law degrees, IC probabilities ~U(0,0.3)
g = generators.powerlaw_cluster(2000, 10.0, prob=(0.0, 0.3), seed=0)
print(f"graph: |V|={g.num_vertices} |E|={g.num_edges}")

# 2. fuse 64 traversals ("colors") into ONE frontier sweep
colors = 64
starts = traversal.random_starts(jax.random.key(0), g.num_vertices, colors)
result = traversal.run_fused(g, starts, colors, seed=jnp.uint32(42))

fused = int(result.stats.fused_edge_visits.sum())
unfused = int(result.stats.unfused_edge_visits.sum())
print(f"levels run:        {int(result.stats.levels_run)}")
print(f"edge visits fused:   {fused:8d}")
print(f"edge visits unfused: {unfused:8d}   "
      f"(work saved: {100*(1-fused/unfused):.1f}%)")

# 3. the visited bitmask IS the RRR-set collection, columnar:
sizes = np.asarray(bitmask.count_colors(result.visited))
print(f"reachable-set sizes: min={sizes[sizes>0].min()} "
      f"mean={sizes.mean():.1f} max={sizes.max()}")
rrr_0 = np.flatnonzero(np.asarray(result.visited[:, 0]) & 1)
print(f"RRR set of color 0 (start={int(starts[0])}): "
      f"{len(rrr_0)} vertices, first 10: {rrr_0[:10].tolist()}")
