"""Distributed fused-BPT demo on 8 forced host devices.

Shows the two distribution axes of DESIGN.md §3 working together and
matching the single-device result bit-for-bit:
  * sample parallelism  — batches sharded over "data",
  * graph parallelism   — 1-D vertex partition over "model" with the
    per-level frontier all-gather,
plus the distributed greedy max-cover reduction.

    PYTHONPATH=src python examples/distributed_traversal.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import imm, tiles, traversal            # noqa: E402
from repro.distributed import traversal as dtrav        # noqa: E402
from repro.graph import csr, generators, partition      # noqa: E402


def main():
    print("devices:", jax.devices())
    g = generators.powerlaw_cluster(1500, 8.0, prob=0.25, seed=3)

    # --- sample parallel: 16 batches over 8 devices -----------------------
    mesh = jax.make_mesh((8,), ("data",))
    B, C = 16, 64
    starts = jnp.stack([traversal.random_starts(jax.random.key(b),
                                                g.num_vertices, C)
                        for b in range(B)])
    seeds = jnp.arange(B, dtype=jnp.uint32)
    visited = dtrav.sample_parallel_visited(g, starts, seeds, C, mesh)
    print(f"sample-parallel: {B} batches × {C} colors = "
          f"{B*C} traversals; visited sharded as "
          f"{visited.sharding.spec}")

    seeds_sel, cov = dtrav.distributed_greedy_max_cover(visited, 5, C, mesh)
    print(f"distributed greedy: seeds={seeds_sel.tolist()} "
          f"coverage={cov:.4f}")

    # --- graph parallel: vertex partition over 'model' --------------------
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    g2 = csr.dedupe(g)
    ptg = partition.partition(tiles.from_graph(g2), num_shards=4)
    st = traversal.random_starts(jax.random.key(9), g2.num_vertices, C)
    vis_gp, levels = dtrav.graph_parallel_traversal(ptg, st, C, 11, mesh2)
    ref = traversal.run_fused(g2, st, C, jnp.uint32(11))
    same = bool((np.asarray(vis_gp) == np.asarray(ref.visited)).all())
    print(f"graph-parallel: {ptg.num_shards} vertex shards, "
          f"{int(levels)} levels, bit-identical to single-device: {same}")


if __name__ == "__main__":
    main()
