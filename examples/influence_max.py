"""End-to-end influence maximization (the paper's application).

Pipeline: θ estimation (IMM martingale bound) → fused reverse-BPT sampling
through the FAULT-TOLERANT driver (injected failures + stragglers, batches
re-issued idempotently) → greedy max-k-cover seed selection → validation of
σ(S) against forward Monte-Carlo simulation.

    PYTHONPATH=src python examples/influence_max.py [--k 8] [--n 3000]
"""
import argparse
import time

import numpy as np

from repro.core import imm, rrr
from repro.core.driver import SamplingDriver
from repro.graph import csr, generators


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--deg", type=float, default=12.0)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--colors", type=int, default=64)
    ap.add_argument("--theta", type=int, default=4096)
    ap.add_argument("--failure-rate", type=float, default=0.15)
    args = ap.parse_args()

    g = generators.powerlaw_cluster(args.n, args.deg, prob=(0.0, 0.25),
                                    seed=1)
    print(f"graph |V|={g.num_vertices} |E|={g.num_edges}; "
          f"θ={args.theta}, k={args.k}, {args.colors} colors/batch")

    # --- sampling through the fault-tolerant work queue ------------------
    n_batches = -(-args.theta // args.colors)
    drv = SamplingDriver(csr.transpose(g), args.colors, master_seed=7,
                         num_workers=4, failure_rate=args.failure_rate,
                         slow_rate=0.1, slow_s=0.1, max_attempts=25)
    t0 = time.time()
    batches = drv.run(n_batches)
    dt = time.time() - t0
    print(f"sampled {len(batches)} batches in {dt:.1f}s "
          f"(injected failures={drv.stats.failures}, "
          f"reissues={drv.stats.reissues}, "
          f"speculative={drv.stats.speculative})")

    # --- seed selection ---------------------------------------------------
    visited = rrr.stack_visited(batches)
    seeds, cov = imm.greedy_max_cover(visited, args.k, args.colors)
    sigma_rev = cov * g.num_vertices
    print(f"seeds: {seeds.tolist()}")
    print(f"coverage {cov:.4f}  →  σ̂(S) ≈ {sigma_rev:.1f} vertices")

    # --- validate against forward simulation ------------------------------
    sigma_fwd = imm.simulate_influence(g, seeds, num_trials=512)
    print(f"forward-simulated σ(S) = {sigma_fwd:.1f} "
          f"(reverse/forward ratio {sigma_rev/sigma_fwd:.3f})")

    rnd = np.random.default_rng(0).integers(0, g.num_vertices, args.k)
    sigma_rnd = imm.simulate_influence(g, rnd, num_trials=256)
    print(f"random-seed baseline σ = {sigma_rnd:.1f}  "
          f"(greedy is {sigma_fwd/max(sigma_rnd,1e-9):.2f}× better)")


if __name__ == "__main__":
    main()
