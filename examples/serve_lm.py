"""Batched serving demo: prefill once, decode N tokens, any architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b \
        --batch 4 --new-tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=registry.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    import dataclasses
    cfg = dataclasses.replace(registry.smoke(args.arch),
                              num_patches=0, capacity_factor=8.0)
    params = model.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    shape = ((args.batch, cfg.num_codebooks, args.prompt_len)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, shape))

    t0 = time.time()
    out = engine.generate(params, cfg, prompt, args.new_tokens,
                          key=jax.random.key(7),
                          temperature=args.temperature)
    dt = time.time() - t0
    toks = args.batch * args.new_tokens * max(cfg.num_codebooks, 1)
    print(f"[serve] {cfg.name}: batch={args.batch} prompt={args.prompt_len} "
          f"generated {args.new_tokens} tokens/req in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, CPU smoke config)")
    print("[serve] sample output ids:",
          np.asarray(out)[0].reshape(-1)[:16].tolist())


if __name__ == "__main__":
    main()
