"""Worked walkthrough of the online influence-query serving subsystem.

Lifecycle demonstrated end to end (sample → serve → refresh → persist):

1. **Sample** a budgeted pool of fused-BPT RRR sketch batches.
2. **Serve** a mixed micro-batched load — one device dispatch per query
   kind answers top-k, σ(S), and marginal-gain queries together.
3. **Refresh** the oldest sketches (new epoch, fresh RNG streams) and watch
   the result cache invalidate itself.
4. **Persist** the pool and restore it bit-identically — a restarted server
   answers from the exact same samples.

    PYTHONPATH=src python examples/serve_influence.py [--n 2000] [--k 8]
"""
import argparse
import tempfile
import time

import numpy as np

from repro.core import imm
from repro.graph import generators
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--deg", type=float, default=10.0)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--colors", type=int, default=64)
    ap.add_argument("--batches", type=int, default=16)
    ap.add_argument("--budget-mb", type=float, default=64.0)
    ap.add_argument("--clients", type=int, default=24)
    args = ap.parse_args()

    g = generators.powerlaw_cluster(args.n, args.deg, prob=(0.0, 0.25),
                                    seed=1)

    # --- 1. sample a budgeted sketch pool --------------------------------
    store = SketchStore(g, PoolConfig(num_colors=args.colors,
                                      max_batches=64,
                                      memory_budget_mb=args.budget_mb,
                                      master_seed=7))
    t0 = time.time()
    store.ensure(args.batches)
    print(f"pool: {len(store.batches)} batches × {args.colors} colors = "
          f"{store.num_samples} RRR sets in {time.time()-t0:.1f}s "
          f"(budget admits {store.capacity} batches)")

    # --- 2. serve a mixed query load through the micro-batcher -----------
    engine = QueryEngine(store, query_slots=8, max_seeds=8)
    batcher = MicroBatcher(engine, cache=ResultCache())
    rng = np.random.default_rng(0)
    topk_t = batcher.submit_top_k(args.k)
    sigma_ts = [batcher.submit_sigma(
        rng.integers(0, g.num_vertices, rng.integers(1, 6)).tolist())
        for _ in range(args.clients)]
    marg_t = batcher.submit_marginal(rng.integers(0, g.num_vertices,
                                                  3).tolist())
    t0 = time.time()
    res = batcher.flush()
    seeds, sigma_hat = res[topk_t]
    print(f"served {2 + args.clients} queries in {batcher.dispatches} "
          f"dispatches, {time.time()-t0:.2f}s")
    print(f"  top-{args.k}: {seeds.tolist()}  σ̂={sigma_hat:.1f}")
    print(f"  σ(S) mean over {args.clients} client queries: "
          f"{np.mean([res[t] for t in sigma_ts]):.1f}")
    gains = res[marg_t]
    print(f"  best marginal extension: vertex {int(np.argmax(gains))} "
          f"(Δσ̂={float(np.max(gains)):.1f})")

    # --- 3. refresh an epoch; cache invalidates itself -------------------
    slots = store.refresh(0.25)
    t = batcher.submit_sigma([int(seeds[0])])
    batcher.flush()
    print(f"refreshed slots {slots} → epoch {store.epoch}; cache "
          f"{batcher.cache.hits} hits / {batcher.cache.misses} misses")

    # --- 4. persist + restore bit-identically ----------------------------
    ckpt = tempfile.mkdtemp(prefix="sketch_pool_")
    store.save(ckpt)
    restored = SketchStore.restore(ckpt, g,
                                   PoolConfig(num_colors=args.colors))
    same = np.array_equal(np.asarray(store.visited_stack()),
                          np.asarray(restored.visited_stack()))
    print(f"persisted to {ckpt}; restore bit-identical: {same}")

    # --- offline IMM is just another client of the pool ------------------
    res_imm = imm.run_imm(g, k=args.k, eps=0.5, num_colors=args.colors,
                          master_seed=7, theta_cap=2048, pool=store)
    print(f"offline run_imm through the SAME pool: θ={res_imm.theta}, "
          f"seeds {res_imm.seeds.tolist()} (pool grew to "
          f"{len(store.batches)} batches, reusable for serving)")


if __name__ == "__main__":
    main()
