"""CI guard: sparse-frontier work counters == dense, deterministically.

The sparse engine's claim is *work proportionality without changing the
work accounting*: `TraversalStats.fused_edge_visits` counts edges whose
source row carries an active color, and every such edge lives in an
active (hence gathered) tile — so sparse and dense must agree EXACTLY,
per batch, on a fixed graph.  Counter equality is deterministic (same
counter RNG, same int32 arithmetic), so this can gate CI without flaking
the way a wall-clock threshold would.

Run from the repo root (ci.sh does):

    PYTHONPATH=src python scripts/check_work_counters.py
"""
from __future__ import annotations

import numpy as np

from repro import sampling
from repro.graph import csr, generators


def main() -> None:
    g = csr.dedupe(generators.powerlaw_cluster(500, 6.0, prob=(0.05, 0.3),
                                               seed=17))
    spec = sampling.SamplerSpec(num_colors=64, master_seed=9)
    dense = sampling.make_sampler(g, spec)
    sparse = sampling.make_sampler(g, spec.replace(frontier="sparse"))
    for bi in range(4):
        a, b = dense.sample(bi), sparse.sample(bi)
        assert a.fused_edge_visits >= 0, "dense batch not instrumented"
        if (a.fused_edge_visits != b.fused_edge_visits
                or a.unfused_edge_visits != b.unfused_edge_visits):
            raise SystemExit(
                f"work-counter mismatch at batch {bi}: dense "
                f"(fused={a.fused_edge_visits}, "
                f"unfused={a.unfused_edge_visits}) vs sparse "
                f"(fused={b.fused_edge_visits}, "
                f"unfused={b.unfused_edge_visits})")
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    print(f"[check_work_counters] OK: 4 batches, sparse == dense "
          f"(fused={a.fused_edge_visits}, unfused={a.unfused_edge_visits} "
          "at batch 3)")


if __name__ == "__main__":
    main()
