"""CI guard: sparse-frontier work counters == dense, deterministically.

The sparse engine's claim is *work proportionality without changing the
work accounting*: `TraversalStats.fused_edge_visits` counts edges whose
source row carries an active color, and every such edge lives in an
active (hence gathered) tile — so sparse and dense must agree EXACTLY,
per batch, on a fixed graph.  Counter equality is deterministic (same
counter RNG, same int32 arithmetic), so this can gate CI without flaking
the way a wall-clock threshold would.

The second section makes the same deterministic claim for the Pallas
kernel grid: on a low-occupancy graph (frontier collapses after the
first levels), the `kernel` backend's sparse-frontier grid must produce
bit-identical visited masks to the dense reference for BOTH diffusions
while running STRICTLY fewer grid steps (`Sampler.last_grid_steps`, the
Σ-of-rung-capacities counter) than the dense grid's
``levels · num_tiles`` — work proportionality of the kernel launch
itself, not just of the jnp oracle.

Run from the repo root (ci.sh does):

    PYTHONPATH=src python scripts/check_work_counters.py
"""
from __future__ import annotations

import numpy as np

from repro import sampling
from repro.graph import csr, generators


def check_sparse_counters() -> None:
    g = csr.dedupe(generators.powerlaw_cluster(500, 6.0, prob=(0.05, 0.3),
                                               seed=17))
    spec = sampling.SamplerSpec(num_colors=64, master_seed=9)
    dense = sampling.make_sampler(g, spec)
    sparse = sampling.make_sampler(g, spec.replace(frontier="sparse"))
    for bi in range(4):
        a, b = dense.sample(bi), sparse.sample(bi)
        assert a.fused_edge_visits >= 0, "dense batch not instrumented"
        if (a.fused_edge_visits != b.fused_edge_visits
                or a.unfused_edge_visits != b.unfused_edge_visits):
            raise SystemExit(
                f"work-counter mismatch at batch {bi}: dense "
                f"(fused={a.fused_edge_visits}, "
                f"unfused={a.unfused_edge_visits}) vs sparse "
                f"(fused={b.fused_edge_visits}, "
                f"unfused={b.unfused_edge_visits})")
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    print(f"[check_work_counters] OK: 4 batches, sparse == dense "
          f"(fused={a.fused_edge_visits}, unfused={a.unfused_edge_visits} "
          "at batch 3)")


def check_kernel_grid() -> None:
    # Low-occupancy graph (the BENCH low_occupancy regime, sized for
    # interpret-mode kernels): most levels touch a fraction of the tiles.
    g = csr.dedupe(generators.powerlaw_cluster(400, 8.0, prob=(0.0, 0.05),
                                               seed=17))
    for diffusion in ("ic", "lt"):
        spec = sampling.SamplerSpec(diffusion=diffusion, backend="kernel",
                                    num_colors=64, master_seed=9,
                                    tile_size=32)
        ref = sampling.make_sampler(g, spec.replace(backend="dense"))
        kern = sampling.make_sampler(g, spec)
        ksp = sampling.make_sampler(g, spec.replace(frontier="sparse"))
        for bi in range(2):
            a = np.asarray(ref.sample(bi).visited)
            b = np.asarray(kern.sample(bi).visited)
            dense_steps = kern.last_grid_steps
            c = np.asarray(ksp.sample(bi).visited)
            np.testing.assert_array_equal(a, b)
            np.testing.assert_array_equal(a, c)
            assert dense_steps == kern.last_levels * kern.tg_rev.num_tiles
            if not 0 < ksp.last_grid_steps < dense_steps:
                raise SystemExit(
                    f"kernel sparse grid not work-proportional at "
                    f"({diffusion}, batch {bi}): sparse "
                    f"{ksp.last_grid_steps} vs dense {dense_steps} steps")
        print(f"[check_work_counters] OK: {diffusion} kernel grid "
              f"bit-identical, sparse {ksp.last_grid_steps} < dense "
              f"{dense_steps} grid steps at batch 1")


def main() -> None:
    check_sparse_counters()
    check_kernel_grid()


if __name__ == "__main__":
    main()
