#!/usr/bin/env bash
# Tier-1 CI: editable install + full pytest suite on CPU.
#
# Mirrors the ROADMAP verify command; JAX runs on the CPU backend so the
# suite is runnable on any GitHub-hosted runner. If the editable install
# can't reach an index (air-gapped sandboxes), fall back to PYTHONPATH —
# tests/conftest.py already substitutes a deterministic hypothesis fallback
# when the real package is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# Guard: bytecode must never be tracked (PR 1 accidentally committed some).
if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
    echo "[ci] FAIL: tracked __pycache__/.pyc files (see list above)" >&2
    exit 1
fi

# Guard: all RRR sampling must route through the repro.sampling facade —
# rrr.sample_batch is its private primitive.  Only its definition (in
# core/rrr.py) and calls inside src/repro/sampling/ are allowed; tests may
# still use it as a low-level oracle.
if grep -rn "sample_batch(" src benchmarks examples --include='*.py' \
        | grep -v '^src/repro/sampling/' \
        | grep -v 'def sample_batch('; then
    echo "[ci] FAIL: rrr.sample_batch called outside repro/sampling/" \
         "(see list above) — go through repro.sampling.make_sampler" >&2
    exit 1
fi

# Graph-parallel serving smoke: the 2-D (data × model) mesh path end to
# end on forced host devices — pool build with the graph row-partitioned,
# pool visited rows sharded V/M over the model axis, bit-identity vs the
# dense pool, elastic restore, refresh.  One IC and one LT run (each is a
# separate process, so the forced device count never leaks into the
# pytest run).
graph_parallel_smoke() {
    python -m repro.launch.serve_influence --smoke --mesh 2x4 \
        --sampler-backend graph_parallel
    python -m repro.launch.serve_influence --smoke --mesh 2x2 \
        --diffusion lt       # M>1 defaults to graph_parallel
    # Sparse-frontier leg: compacted per-level expansion + the ButterFly
    # log(M)-stage pairwise exchange of compacted (word_idx, word) pairs
    # where the frontier fits (dense all-gather fallback where it
    # doesn't), checked bit-identical to the dense-frontier dense-backend
    # reference pool inside the smoke; 2x3 exercises the
    # non-power-of-two dissemination schedule.
    python -m repro.launch.serve_influence --smoke --mesh 2x4 \
        --sampler-backend graph_parallel --frontier sparse
    python -m repro.launch.serve_influence --smoke --mesh 2x3 \
        --sampler-backend graph_parallel --frontier sparse
}

# Deterministic work-proportionality guard: sparse fused_edge_visits must
# equal dense EXACTLY on a fixed graph (counter equality, not wall clock,
# so it cannot flake).
work_counter_guard() {
    python scripts/check_work_counters.py
}

# Serving-tier smoke: 2 tenants × 2 replicas through the production tier —
# shed-rate (quota-starved tenant0 sheds with retry-after), replica
# bit-identity vs a direct engine, and the mixed-epoch gather refusal are
# all asserted inside the launcher smoke.
tier_smoke() {
    python -m repro.launch.serve_influence --smoke --tier \
        --tenants 2 --replicas 2 --autoscale
}

# Streaming-delta smoke: mutate the graph mid-serve through the tier's
# admission-gated write path (single-device tier with EpochMixError +
# quota-shed asserts), then the 8-shard data_parallel store, then an LT
# sparse-frontier pool — each asserts the incrementally-refreshed pool is
# bit-identical to a cold rebuild on the mutated graph.
stream_smoke() {
    python -m repro.launch.serve_influence --stream-smoke
    python -m repro.launch.serve_influence --stream-smoke --mesh 8x1
    python -m repro.launch.serve_influence --stream-smoke \
        --diffusion lt --frontier sparse
}

# Pallas-kernel interpret smoke: on the CPU backend every kernel runs in
# interpret mode (kernels.ops._interpret), so CI exercises the REAL kernel
# code paths — the pytest suite above holds the unit bit-identity
# (test_kernels.py / test_sampling.py), check_work_counters.py gates the
# sparse kernel grid, and this runs the serving lifecycle end to end on
# the kernel backend (IC dense-frontier, LT sparse-frontier) plus the
# graph-parallel kernel leg (REPRO_GP_KERNEL=1 routes each shard's tile
# expansion through the kernels on a 2-D mesh).
kernel_interpret_smoke() {
    python -m repro.launch.serve_influence --smoke \
        --sampler-backend kernel
    python -m repro.launch.serve_influence --smoke \
        --sampler-backend kernel --diffusion lt --frontier sparse
    REPRO_GP_KERNEL=1 python -m repro.launch.serve_influence --smoke \
        --mesh 2x2 --sampler-backend graph_parallel
}

if python -m pip install -e . ; then
    python -m pytest -x -q
    graph_parallel_smoke
    work_counter_guard
    tier_smoke
    stream_smoke
    kernel_interpret_smoke
else
    echo "[ci] pip install failed; running from source tree" >&2
    export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
    python -m pytest -x -q
    graph_parallel_smoke
    work_counter_guard
    tier_smoke
    stream_smoke
    kernel_interpret_smoke
fi
