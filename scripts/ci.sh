#!/usr/bin/env bash
# Tier-1 CI: editable install + full pytest suite on CPU.
#
# Mirrors the ROADMAP verify command; JAX runs on the CPU backend so the
# suite is runnable on any GitHub-hosted runner. If the editable install
# can't reach an index (air-gapped sandboxes), fall back to PYTHONPATH —
# tests/conftest.py already substitutes a deterministic hypothesis fallback
# when the real package is absent.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if python -m pip install -e . ; then
    python -m pytest -x -q
else
    echo "[ci] pip install failed; running from source tree" >&2
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q
fi
