"""Int8-compressed DP training: converges like the exact step (subprocess,
8 forced devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

_SRC = str(pathlib.Path(__file__).parents[1] / "src")

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import registry
from repro.data.pipeline import SyntheticLM
from repro.models import model
from repro.optim import adamw
from repro.train.dp_step import make_dp_train_step

cfg = registry.smoke("llama3.2-3b")
mesh = jax.make_mesh((8,), ("data",))
data = SyntheticLM(cfg, 16, 32, seed=4)

def run(compressed):
    params = model.init_params(jax.random.key(0), cfg)
    opt = adamw.init(params)
    step, init_res = make_dp_train_step(cfg, lambda s: 1e-3, mesh,
                                        compressed=compressed)
    err = init_res(params)
    losses = []
    for s in range(25):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        params, opt, err, m = step(params, opt, err, b)
        losses.append(float(m["loss"]))
    return losses

exact = run(False)
comp = run(True)
print("exact first/last:", exact[0], exact[-1])
print("comp  first/last:", comp[0], comp[-1])
assert comp[-1] < comp[0] - 0.4, "compressed run must learn"
assert abs(comp[-1] - exact[-1]) < 0.25, (comp[-1], exact[-1])
print("OK dp_compression")
"""


@pytest.mark.slow
def test_compressed_dp_converges_like_exact():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", _CHILD],
                          capture_output=True, text=True, env=env,
                          timeout=1800)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-2500:]
    assert "OK dp_compression" in proc.stdout
