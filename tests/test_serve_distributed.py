"""Drives tests/serve_distributed_check.py in a subprocess with 8 forced
host devices (keeps the main process's 1-device invariant; see conftest.py),
plus in-process deadline/thread-safety tests for the async front-end over a
single-device engine (no mesh needed)."""
import os
import pathlib
import subprocess
import sys
import threading
import time

import pytest

from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)
from repro.serve.distributed import AsyncFrontEnd

_SCRIPT = pathlib.Path(__file__).parent / "serve_distributed_check.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


@pytest.mark.slow
def test_sharded_serving_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(_SCRIPT)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("OK shard_slots", "OK engine_equivalence",
                   "OK ragged_shards", "OK per_shard_budget",
                   "OK elastic_restore", "OK data_parallel_sampling",
                   "OK data_parallel_pool", "OK lt_data_parallel",
                   "OK graph_parallel_pool", "OK graph_parallel_kernel",
                   "OK graph_parallel_manifest",
                   "OK sparse_frontier", "OK async_frontend",
                   "OK stream_updates"):
        assert marker in proc.stdout, proc.stdout


# ---------------------------------------------------- in-process front-end
@pytest.fixture(scope="module")
def engine():
    from repro.graph import generators
    g = generators.powerlaw_cluster(150, 5.0, prob=0.25, seed=17)
    s = SketchStore(g, PoolConfig(num_colors=64, max_batches=8,
                                  master_seed=9))
    s.ensure(4)
    return QueryEngine(s)


def test_frontend_lone_request_flushes_at_deadline(engine):
    """A lone request must be dispatched by its deadline, not wait for the
    slot batch to fill (the pre-PR MicroBatcher starvation bug)."""
    with AsyncFrontEnd(MicroBatcher(engine), default_deadline=0.1,
                       flush_slots=64) as fe:
        fut = fe.submit_sigma([1, 2, 3], deadline=0.1)
        got = fut.result(timeout=30)
    assert got == engine.sigma([[1, 2, 3]])[0]
    assert fe.stats.deadline_flushes >= 1
    assert fe.stats.slot_flushes == 0


def test_frontend_full_slot_flushes_early(engine):
    """flush_slots pending queries dispatch immediately — well before the
    (deliberately huge) deadline."""
    with AsyncFrontEnd(MicroBatcher(engine), default_deadline=60.0,
                       flush_slots=4) as fe:
        t0 = time.monotonic()
        futs = [fe.submit_sigma([i]) for i in range(4)]
        for f in futs:
            f.result(timeout=30)
        assert time.monotonic() - t0 < 30.0
    assert fe.stats.slot_flushes >= 1


def test_frontend_concurrent_submitters_get_own_answers(engine):
    sets = [[i, i + 7] for i in range(12)]
    futs = {}
    with AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=0.05) as fe:
        def client(i):
            futs[i] = fe.submit_sigma(sets[i])
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(len(sets))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        got = {i: futs[i].result(timeout=30) for i in futs}
    want = engine.sigma(sets[:8]).tolist() + engine.sigma(sets[8:]).tolist()
    assert [got[i] for i in range(len(sets))] == pytest.approx(want)


def test_frontend_invalid_submit_fails_caller_only(engine):
    with AsyncFrontEnd(MicroBatcher(engine), default_deadline=0.05) as fe:
        ok = fe.submit_sigma([1, 2])
        with pytest.raises(ValueError):
            fe.submit_sigma(list(range(engine.max_seeds + 1)))
        assert ok.result(timeout=30) == engine.sigma([[1, 2]])[0]


def test_frontend_close_drains_and_rejects(engine):
    fe = AsyncFrontEnd(MicroBatcher(engine), default_deadline=30.0)
    fut = fe.submit_sigma([5])
    fe.close()
    assert fut.result(timeout=5) == engine.sigma([[5]])[0]
    with pytest.raises(RuntimeError):
        fe.submit_sigma([6])


def test_frontend_background_refresh_bumps_version(engine):
    store = engine.store
    before = store.version
    with AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=0.02, refresh_every=0.2) as fe:
        deadline = time.monotonic() + 30
        while fe.stats.refreshes == 0 and time.monotonic() < deadline:
            time.sleep(0.05)
        # queries keep being answered across the epoch bump
        val = fe.submit_sigma([2, 4]).result(timeout=30)
    assert fe.stats.refreshes >= 1
    assert store.version != before
    assert val == engine.sigma([[2, 4]])[0]


class _FlakyEngine:
    """Wraps a real engine; the first σ dispatch raises."""
    def __init__(self, inner):
        self.inner = inner
        self.query_slots = inner.query_slots
        self.max_seeds = inner.max_seeds
        self.fail_next = True

    @property
    def store(self):
        return self.inner.store

    def top_k(self, k):
        return self.inner.top_k(k)

    def sigma(self, seed_sets):
        if self.fail_next:
            self.fail_next = False
            raise RuntimeError("boom")
        return self.inner.sigma(seed_sets)


def test_batcher_flush_error_names_consumed_tickets(engine):
    from repro.serve.influence import FlushError
    b = MicroBatcher(_FlakyEngine(engine))
    t1, t2 = b.submit_sigma([1]), b.submit_sigma([2])
    with pytest.raises(FlushError) as ei:
        b.flush()
    assert set(ei.value.tickets) == {t1, t2}
    b.submit_sigma([3])               # later submit untouched, still queued
    assert b.pending_count == 1


def test_batcher_flush_error_keeps_partial_results(engine):
    """A σ dispatch failure must not discard the top-k answer computed
    earlier in the same flush."""
    from repro.serve.influence import FlushError
    b = MicroBatcher(_FlakyEngine(engine))
    t_top = b.submit_top_k(2)
    t_sig = b.submit_sigma([1])
    with pytest.raises(FlushError) as ei:
        b.flush()
    assert set(ei.value.tickets) == {t_sig}
    seeds, sigma = ei.value.partial[t_top]
    ref_seeds, ref_sigma = engine.top_k(2)
    assert (seeds == ref_seeds).all() and sigma == ref_sigma


def test_frontend_cancelled_future_does_not_kill_dispatcher(engine):
    """A client cancelling its queued future must not crash the dispatcher
    thread (futures are resolved via set_running_or_notify_cancel)."""
    with AsyncFrontEnd(MicroBatcher(engine), default_deadline=0.2) as fe:
        doomed = fe.submit_sigma([1])
        assert doomed.cancel()
        ok = fe.submit_sigma([2])
        assert ok.result(timeout=30) == engine.sigma([[2]])[0]


def test_frontend_flush_error_fails_only_consumed_callers(engine):
    """A broken dispatch fails the callers it consumed; the front-end keeps
    serving and later submits succeed."""
    from repro.serve.influence import FlushError
    with AsyncFrontEnd(MicroBatcher(_FlakyEngine(engine)),
                       default_deadline=0.05) as fe:
        bad = fe.submit_sigma([1])
        with pytest.raises(FlushError):
            bad.result(timeout=30)
        good = fe.submit_sigma([2])
        assert good.result(timeout=30) == engine.sigma([[2]])[0]


class _IntermittentEngine:
    """Wraps a real engine; every ``period``-th σ dispatch raises."""
    def __init__(self, inner, period=3):
        self.inner = inner
        self.query_slots = inner.query_slots
        self.max_seeds = inner.max_seeds
        self.period = period
        self.calls = 0

    @property
    def store(self):
        return self.inner.store

    def top_k(self, k):
        return self.inner.top_k(k)

    def sigma(self, seed_sets):
        self.calls += 1
        if self.calls % self.period == 0:
            raise RuntimeError("intermittent boom")
        return self.inner.sigma(seed_sets)


def test_batcher_stress_every_ticket_resolves_exactly_once(engine):
    """Many submitter threads racing flush() against intermittent dispatch
    failures: every ticket must end up answered exactly once OR named in
    exactly one FlushError.tickets — never both, never neither."""
    from repro.serve.influence import FlushError
    b = MicroBatcher(_IntermittentEngine(engine, period=3))
    submitted, answered, failed = set(), {}, []
    lock = threading.Lock()

    def submitter(base):
        for j in range(6):
            t = b.submit_sigma([base, base + j + 1])
            with lock:
                submitted.add(t)
            time.sleep(0.001)

    def flusher():
        for _ in range(20):
            try:
                out = b.flush()
            except FlushError as e:
                with lock:
                    failed.extend(e.tickets)
                    answered.update(e.partial)
            else:
                with lock:
                    answered.update(out)
            time.sleep(0.002)

    threads = ([threading.Thread(target=submitter, args=(i,))
                for i in range(6)]
               + [threading.Thread(target=flusher) for _ in range(2)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    while b.pending_count:                    # drain stragglers
        try:
            answered.update(b.flush())
        except FlushError as e:
            failed.extend(e.tickets)
            answered.update(e.partial)
    assert set(answered) | set(failed) == submitted
    assert not set(answered) & set(failed), \
        "a ticket must not be both answered and failed"
    assert len(failed) == len(set(failed)), \
        "a ticket must appear in at most one FlushError"
    assert failed, "period=3 over 20+ flushes must have tripped at least once"


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_frontend_close_fails_undrained_futures_instead_of_hanging(engine):
    """If the dispatcher dies on an unexpected (non-FlushError) exception,
    close() must fail the stranded futures with a clear FlushError rather
    than leaving callers blocked forever."""
    from repro.serve.influence import FlushError
    fe = AsyncFrontEnd(MicroBatcher(engine), default_deadline=30.0)
    fut = fe.submit_sigma([3])
    fe.batcher.flush = lambda: (_ for _ in ()).throw(RuntimeError("dead"))
    with fe._cv:
        fe._cv.notify_all()                   # nothing pending past deadline,
    fe.close()                                # so the dispatcher dies in drain
    with pytest.raises(FlushError) as ei:
        fut.result(timeout=5)
    assert "drained" in str(ei.value.__cause__)
    assert len(ei.value.tickets) == 1


def test_frontend_close_drain_failure_resolves_every_future(engine):
    """A flaky dispatch during the close() drain still resolves every
    submitted future — answers or FlushError, nothing left pending."""
    from repro.serve.influence import FlushError
    fe = AsyncFrontEnd(MicroBatcher(_FlakyEngine(engine)),
                       default_deadline=30.0)
    futs = [fe.submit_sigma([i]) for i in range(3)]
    fe.close()
    assert all(f.done() for f in futs), "close() must leave nothing pending"
    outcomes = []
    for f in futs:
        try:
            outcomes.append(f.result(timeout=5))
        except FlushError:
            outcomes.append("failed")
    assert "failed" in outcomes, "the flaky first dispatch must surface"


def test_result_cache_stats_snapshot(engine):
    cache = ResultCache()
    b = MicroBatcher(engine, cache=cache)
    b.submit_sigma([1, 2]), b.flush()
    b.submit_sigma([1, 2]), b.flush()          # same epoch ⇒ hit
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["misses"] >= 1
    assert stats["size"] == len(cache)
    assert stats["hit_rate"] == pytest.approx(
        stats["hits"] / (stats["hits"] + stats["misses"]))
    assert set(stats) == {"hits", "misses", "size", "hit_rate"}


# ------------------------------------------------------ batcher deadlines
def test_batcher_deadline_bookkeeping(engine):
    b = MicroBatcher(engine)
    assert b.oldest_deadline() is None and b.pending_count == 0
    t0 = time.monotonic()
    b.submit_sigma([1], deadline=5.0)
    b.submit_sigma([2], deadline=1.0)
    b.submit_top_k(3)                       # no deadline
    assert b.pending_count == 3
    oldest = b.oldest_deadline()
    assert oldest is not None and 0.5 < oldest - t0 < 1.5
    b.flush()
    assert b.pending_count == 0 and b.oldest_deadline() is None


def test_batcher_threaded_submit_flush(engine):
    """Hammer submits from many threads against concurrent flushes; every
    ticket must be answered exactly once with its own query's answer."""
    b = MicroBatcher(engine, cache=ResultCache())
    results, lock = {}, threading.Lock()

    def submitter(base):
        tickets = [(b.submit_sigma([base, base + 3]), base) for _ in range(5)]
        with lock:
            results.update({t: base for t, base in tickets})

    def flusher():
        for _ in range(10):
            out = b.flush()
            with lock:
                flushed.update(out)
            time.sleep(0.005)

    flushed = {}
    threads = ([threading.Thread(target=submitter, args=(i,))
                for i in range(8)]
               + [threading.Thread(target=flusher) for _ in range(2)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    flushed.update(b.flush())               # drain stragglers
    assert set(flushed) == set(results), "every ticket answered exactly once"
    for ticket, base in results.items():
        assert flushed[ticket] == engine.sigma([[base, base + 3]])[0]
