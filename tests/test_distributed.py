"""Drives tests/distributed_check.py in a subprocess with 8 forced host
devices.  Keeping the fork outside pytest's process preserves the 1-device
invariant for all other tests (see conftest.py note)."""
import os
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = pathlib.Path(__file__).parent / "distributed_check.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


@pytest.mark.slow
def test_distributed_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(_SCRIPT)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    for marker in ("OK sample_parallel", "OK distributed_greedy",
                   "OK graph_parallel", "OK graph_parallel_multipod"):
        assert marker in proc.stdout, proc.stdout
