"""Influence-maximization correctness: greedy cover guarantees, RRR-vs-forward
estimator agreement, θ bound monotonicity, batch idempotence (fault-tolerance
contract)."""
import itertools
import math

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import bitmask, imm, rrr
from repro.graph import csr, generators


@pytest.fixture(scope="module")
def graph():
    return generators.powerlaw_cluster(200, 6.0, prob=0.25, seed=13)


def _brute_force_cover(visited, k, num_colors):
    """Optimal k-cover by exhaustion (tiny graphs only)."""
    b, v, w = visited.shape
    vis = np.asarray(visited)
    tail = bitmask.color_tail_mask(num_colors)
    best = -1
    theta = b * num_colors
    for combo in itertools.combinations(range(v), k):
        active = np.broadcast_to(tail, (b, w)).copy()
        for s in combo:
            active &= ~vis[:, s, :]
        covered = theta - int(
            np.unpackbits(active.view(np.uint8)).sum())
        best = max(best, covered)
    return best / theta


def test_greedy_cover_within_1_minus_1_over_e():
    """Greedy ≥ (1 − 1/e)·OPT on the SAME collection — deterministic."""
    g = generators.erdos_renyi(24, 3.0, prob=0.4, seed=5)
    batches = rrr.sample_collection(g, theta=256, num_colors=64,
                                    master_seed=3)
    visited = rrr.stack_visited(batches)
    seeds, cov = imm.greedy_max_cover(visited, 3, 64)
    opt = _brute_force_cover(visited, 3, 64)
    assert cov >= (1 - 1 / math.e) * opt - 1e-9
    assert len(set(seeds.tolist())) == 3, "distinct seeds"


def test_greedy_cover_kernel_matches_jnp(graph):
    batches = rrr.sample_collection(graph, theta=128, num_colors=64,
                                    master_seed=1)
    visited = rrr.stack_visited(batches)
    s1, c1 = imm.greedy_max_cover(visited, 4, 64, use_kernel=True)
    s2, c2 = imm.greedy_max_cover(visited, 4, 64, use_kernel=False)
    np.testing.assert_array_equal(s1, s2)
    assert c1 == c2


def test_coverage_of_matches_greedy_report(graph):
    batches = rrr.sample_collection(graph, theta=128, num_colors=64)
    visited = rrr.stack_visited(batches)
    seeds, cov = imm.greedy_max_cover(visited, 3, 64)
    assert abs(imm.coverage_of(visited, seeds, 64) - cov) < 1e-12


def test_theta_bound_monotonic():
    t1 = imm.theta_bound(1000, 5, 0.5)
    t2 = imm.theta_bound(1000, 5, 0.25)     # tighter ε ⇒ more samples
    t3 = imm.theta_bound(10_000, 5, 0.5)    # bigger graph ⇒ more samples
    assert t2 > t1 and t3 > t1
    assert t1 > 0


def test_batch_idempotence(graph):
    """Fault-tolerance contract: re-executing a batch reproduces it exactly."""
    g_rev = csr.transpose(graph)
    a = rrr.sample_batch(g_rev, 64, master_seed=9, batch_index=4)
    b = rrr.sample_batch(g_rev, 64, master_seed=9, batch_index=4)
    np.testing.assert_array_equal(np.asarray(a.visited), np.asarray(b.visited))
    np.testing.assert_array_equal(a.roots, b.roots)
    c = rrr.sample_batch(g_rev, 64, master_seed=9, batch_index=5)
    assert not np.array_equal(np.asarray(a.visited), np.asarray(c.visited))


def test_rrr_root_always_in_own_set(graph):
    g_rev = csr.transpose(graph)
    batch = rrr.sample_batch(g_rev, 64, master_seed=2, batch_index=0)
    vis = np.asarray(batch.visited)
    for c, root in enumerate(batch.roots):
        assert vis[root, c // 32] >> (c % 32) & 1


def test_run_imm_end_to_end(graph):
    res = imm.run_imm(graph, k=4, eps=0.5, num_colors=64, theta_cap=2048)
    assert len(res.seeds) == 4
    assert 0 < res.coverage <= 1
    assert res.sigma_estimate >= 4, "seeds influence at least themselves"
    assert res.fused_edge_visits <= res.unfused_edge_visits, "Theorem 1"


def test_reverse_estimate_matches_forward_simulation():
    """n·E[cover] on a FRESH RRR collection ≈ forward IC simulation of σ(S).

    (Coverage on the *selection* collection is upward-biased — greedy
    optimizes on those very samples; IMM's analysis accounts for it. The
    unbiased check uses independent samples.)"""
    g = generators.erdos_renyi(150, 5.0, prob=0.15, seed=8)
    res = imm.run_imm(g, k=3, eps=0.4, num_colors=128, theta_cap=8192)
    fresh = rrr.stack_visited(
        rrr.sample_collection(g, theta=8192, num_colors=128,
                              master_seed=4242))
    rev = imm.coverage_of(fresh, res.seeds, 128) * g.num_vertices
    fwd = imm.simulate_influence(g, res.seeds, num_trials=1024)
    # Two Monte-Carlo estimates of the same σ(S); agree within ~10%.
    assert abs(rev - fwd) / max(fwd, 1.0) < 0.10, (rev, fwd)


def test_greedy_beats_random_seeds(graph):
    res = imm.run_imm(graph, k=5, eps=0.5, num_colors=64, theta_cap=4096)
    rng = np.random.default_rng(0)
    batches = rrr.sample_collection(graph, 4096, 64, master_seed=123)
    visited = rrr.stack_visited(batches)
    rand_cov = np.mean([
        imm.coverage_of(visited, rng.integers(0, graph.num_vertices, 5), 64)
        for _ in range(10)])
    greedy_cov = imm.coverage_of(visited, res.seeds, 64)
    assert greedy_cov > rand_cov, "greedy seeds must beat random seeds"
