"""Launch-layer checks under 8 forced host devices (subprocess twin of
tests/test_launch.py): the REAL lower_cell code path at reduced scale for
every kind (train/prefill/decode) and family, plus sharding-rule sanity."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses      # noqa: E402

import jax              # noqa: E402
import numpy as np      # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.distributed import sharding_rules as rules   # noqa: E402
from repro.launch import dryrun, specs                  # noqa: E402
from repro.models.config import ShapeConfig             # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    shapes = {
        "train": ShapeConfig("t", "train", 64, 8),
        "prefill": ShapeConfig("p", "prefill", 64, 4),
        "decode": ShapeConfig("d", "decode", 64, 8),
    }
    # one arch per family keeps runtime sane; all 10 are covered at full
    # scale by the real dry-run sweep.
    archs = ["llama3.2-3b", "deepseek-v3-671b", "zamba2-2.7b",
             "mamba2-1.3b", "musicgen-medium"]
    for arch in archs:
        cfg = dataclasses.replace(
            registry.smoke(arch), num_patches=0, attn_block_q=32,
            attn_block_k=32, ssm_chunk=32)
        for kind, shape in shapes.items():
            rec = dryrun.lower_cell(arch, kind, multi_pod=False, cfg=cfg,
                                    mesh=mesh, shape=shape)
            assert rec["status"] == "ok", (arch, kind, rec.get("error"),
                                           rec.get("traceback", "")[-500:])
            assert rec["flops_per_device"] > 0, (arch, kind)
            rt = rec["roofline"]
            assert rt["compute_s"] >= 0 and rt["memory_s"] > 0
            print(f"OK lower {arch} {kind} dom={rt['dominant']}")

    # sharding rules: every param leaf gets a valid sharding on this mesh
    cfg = registry.smoke("qwen1.5-110b")
    p_shapes = specs.param_specs(cfg)
    sh = rules.param_shardings(mesh, p_shapes)
    n_sharded = 0
    for leaf_shape, leaf_sh in zip(jax.tree.leaves(p_shapes),
                                   jax.tree.leaves(sh)):
        spec = leaf_sh.spec
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))])
            assert leaf_shape.shape[dim] % size == 0, (leaf_shape, spec)
            n_sharded += 1
    assert n_sharded > 0
    print(f"OK sharding_rules ({n_sharded} sharded dims)")


if __name__ == "__main__":
    main()
