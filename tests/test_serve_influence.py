"""Serving subsystem: sketch-store persistence, engine-vs-IMM agreement,
batched σ(S) vs forward simulation, micro-batching, cache epoch semantics."""
import numpy as np
import pytest

from repro.core import imm, rrr
from repro.graph import generators
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


@pytest.fixture(scope="module")
def graph():
    return generators.powerlaw_cluster(200, 6.0, prob=0.25, seed=13)


@pytest.fixture(scope="module")
def store(graph):
    s = SketchStore(graph, PoolConfig(num_colors=64, max_batches=32,
                                      master_seed=3))
    s.ensure(8)
    return s


def test_pool_budget_caps_growth(graph):
    cfg = PoolConfig(num_colors=64, max_batches=32,
                     memory_budget_mb=3 * graph.num_vertices * 2 * 4 / 2**20)
    s = SketchStore(graph, cfg)
    assert s.capacity == 3
    s.ensure(10)
    assert len(s.batches) == 3, "memory budget must cap the pool"


def test_save_restore_bit_identical(store, graph, tmp_path):
    store.save(str(tmp_path))
    r = SketchStore.restore(str(tmp_path), graph,
                            PoolConfig(num_colors=64, max_batches=32))
    np.testing.assert_array_equal(np.asarray(store.visited_stack()),
                                  np.asarray(r.visited_stack()))
    assert r.epoch == store.epoch
    assert r.next_batch_index == store.next_batch_index
    assert r.master_seed == store.master_seed
    assert [b.batch_index for b in r.batches] == \
        [b.batch_index for b in store.batches]
    for a, b in zip(store.batches, r.batches):
        np.testing.assert_array_equal(a.roots, b.roots)
        assert (a.fused_edge_visits, a.unfused_edge_visits) == \
            (b.fused_edge_visits, b.unfused_edge_visits)


def test_restore_rejects_color_mismatch(store, graph, tmp_path):
    store.save(str(tmp_path))
    with pytest.raises(ValueError):
        SketchStore.restore(str(tmp_path), graph,
                            PoolConfig(num_colors=128))


def test_engine_topk_matches_imm_on_same_pool(store):
    seeds_engine, sigma = QueryEngine(store).top_k(4)
    seeds_imm, cov = imm.greedy_max_cover(store.visited_stack(), 4,
                                          store.num_colors)
    np.testing.assert_array_equal(seeds_engine, seeds_imm)
    assert sigma == pytest.approx(cov * store.graph.num_vertices)
    ref, _ = imm.greedy_max_cover_ref(store.visited_stack(), 4,
                                      store.num_colors)
    np.testing.assert_array_equal(seeds_engine, ref)


def test_run_imm_through_pool_identity(graph):
    plain = imm.run_imm(graph, k=3, eps=0.5, num_colors=64, master_seed=5,
                        theta_cap=1024)
    pool = SketchStore(graph, PoolConfig(num_colors=64, max_batches=64,
                                         master_seed=5))
    routed = imm.run_imm(graph, k=3, eps=0.5, num_colors=64, master_seed=5,
                         theta_cap=1024, pool=pool)
    np.testing.assert_array_equal(plain.seeds, routed.seeds)
    assert plain.coverage == routed.coverage
    assert plain.theta == routed.theta
    assert len(pool.batches) == routed.num_batches, "batches live in the pool"


def test_run_imm_raises_on_undersized_pool(graph):
    """A budget-capped pool that can't supply θ must fail loudly — silently
    under-sampling would void the (1 − 1/e − ε) guarantee."""
    pool = SketchStore(graph, PoolConfig(num_colors=64, max_batches=2,
                                         master_seed=5))
    with pytest.raises(ValueError, match="capacity"):
        imm.run_imm(graph, k=3, eps=0.5, num_colors=64, master_seed=5,
                    theta_cap=1024, pool=pool)


def test_run_imm_theta_cap_with_prepopulated_pool(graph):
    """Selection uses the first ⌈θ/colors⌉ pool slots, so a big serving pool
    still honors theta_cap and reproduces the pool-less result."""
    plain = imm.run_imm(graph, k=3, eps=0.5, num_colors=64, master_seed=5,
                        theta_cap=512)
    pool = SketchStore(graph, PoolConfig(num_colors=64, max_batches=64,
                                         master_seed=5))
    pool.ensure(32)                       # serving pool ≫ theta_cap
    routed = imm.run_imm(graph, k=3, eps=0.5, num_colors=64, master_seed=5,
                         theta_cap=512, pool=pool)
    assert routed.theta == plain.theta <= 512
    assert routed.num_batches == plain.num_batches
    np.testing.assert_array_equal(plain.seeds, routed.seeds)
    assert len(pool.batches) == 32, "pool keeps its extra serving batches"


def test_batched_sigma_matches_forward_simulation():
    g = generators.erdos_renyi(150, 5.0, prob=0.15, seed=8)
    s = SketchStore(g, PoolConfig(num_colors=128, max_batches=64,
                                  master_seed=11))
    s.ensure(64)                     # 8192 RRR samples
    eng = QueryEngine(s, max_seeds=8)
    sets = [[0], [3, 50, 99], [10, 20, 30, 40, 50]]
    sig = eng.sigma(sets)
    for est, seed_set in zip(sig, sets):
        fwd = imm.simulate_influence(g, seed_set, num_trials=1024)
        # Two Monte-Carlo estimates of σ(S): 10% relative, 1-vertex floor
        # (tiny σ values put 10% below one seed's self-influence).
        assert abs(est - fwd) < max(0.10 * fwd, 1.0), (seed_set, est, fwd)


def test_sigma_matches_coverage_of(store):
    eng = QueryEngine(store)
    seeds, _ = eng.top_k(3)
    est = eng.sigma([seeds.tolist()])[0]
    cov = imm.coverage_of(store.visited_stack(), seeds, store.num_colors)
    assert est == pytest.approx(cov * store.graph.num_vertices)


def test_marginal_gains_exclusions(store):
    eng = QueryEngine(store)
    seeds, _ = eng.top_k(3)
    gains = eng.marginal_gains(seeds[:2].tolist())
    assert gains[seeds[0]] == 0 and gains[seeds[1]] == 0
    # Exact greedy extension must pick the global argmax of the gains.
    assert int(np.argmax(gains)) == \
        int(eng.best_extension(seeds[:2].tolist(), 1)[0]) == int(seeds[2])


def test_greedy_extend_resumes_full_greedy(store):
    """Incremental kernel contract: extending a prefix reproduces the rest."""
    vis = store.visited_stack()
    full, _ = imm.greedy_max_cover(vis, 5, store.num_colors)
    ext = QueryEngine(store).best_extension(full[:2].tolist(), 3)
    np.testing.assert_array_equal(full[2:], ext)


def test_batcher_dedups_and_pads(store):
    eng = QueryEngine(store, query_slots=2, max_seeds=4)
    b = MicroBatcher(eng)
    t = [b.submit_sigma([1, 2]), b.submit_sigma([2, 1]),     # same canonical
         b.submit_sigma([5]), b.submit_sigma([9, 10, 11])]   # overflow → 2nd
    r = b.flush()
    assert r[t[0]] == r[t[1]]
    assert b.dispatches == 2, "4 queries, 3 unique, 2 slots → 2 dispatches"
    single = eng.sigma([[5]])[0]
    assert r[t[2]] == pytest.approx(single)


def test_batcher_rejects_oversized_seed_set_at_submit(store):
    """Invalid queries fail on the offending caller; a shared flush must
    never lose other callers' tickets to someone else's bad input."""
    b = MicroBatcher(QueryEngine(store, max_seeds=2))
    ok = b.submit_sigma([1, 2])
    with pytest.raises(ValueError):
        b.submit_sigma([1, 2, 3])
    assert ok in b.flush(), "good ticket survives the rejected submit"


def test_engine_results_are_read_only(store):
    """Results are shared via cache/dedup fan-out — mutation must fail loudly
    instead of corrupting another caller's answer."""
    eng = QueryEngine(store)
    gains = eng.marginal_gains([1])
    with pytest.raises(ValueError):
        gains[0] = 1.0
    seeds, _ = eng.top_k(2)
    with pytest.raises(ValueError):
        seeds[0] = 0


def test_cache_invalidates_on_epoch_bump(graph):
    s = SketchStore(graph, PoolConfig(num_colors=64, max_batches=8,
                                      master_seed=21))
    s.ensure(4)
    cache = ResultCache()
    b = MicroBatcher(QueryEngine(s), cache=cache)
    t1 = b.submit_sigma([1, 2, 3]); r1 = b.flush()
    t2 = b.submit_sigma([3, 2, 1]); r2 = b.flush()
    assert cache.hits == 1 and b.dispatches == 1, "canonical-key cache hit"
    old_version = s.version
    s.refresh(0.5)
    assert s.version != old_version
    t3 = b.submit_sigma([1, 2, 3]); r3 = b.flush()
    assert b.dispatches == 2, "epoch bump must force a recompute"
    assert cache.hits == 1


def test_cache_invalidates_on_pool_growth(store, graph):
    s = SketchStore(graph, PoolConfig(num_colors=64, max_batches=8,
                                      master_seed=22))
    s.ensure(2)
    cache = ResultCache()
    b = MicroBatcher(QueryEngine(s), cache=cache)
    b.submit_sigma([4]); b.flush()
    s.ensure(4)                       # growth changes the estimator
    b.submit_sigma([4]); b.flush()
    assert b.dispatches == 2


def test_refresh_replaces_oldest_and_never_reuses_streams(graph):
    s = SketchStore(graph, PoolConfig(num_colors=64, max_batches=8,
                                      master_seed=31))
    s.ensure(4)
    before = {b.batch_index for b in s.batches}
    slots = s.refresh(0.5)
    assert len(slots) == 2 and s.epoch == 1
    after = [b.batch_index for b in s.batches]
    assert len(set(after)) == 4
    for i in slots:
        assert after[i] not in before, "refresh must use fresh RNG streams"
        assert s.batch_epochs[i] == 1
    # Second refresh picks the remaining epoch-0 batches first.
    slots2 = s.refresh(0.5)
    assert set(slots2) == set(range(4)) - set(slots)
