"""Distributed-serving equivalence checks, executed by
tests/test_serve_distributed.py in a subprocess with 8 forced host devices
(the main pytest process keeps its 1-device invariant — see conftest.py).
Prints "OK <name>" per passing check; any exception fails.

The contract under test: an N-shard pool + DistributedQueryEngine is
**bit-for-bit** equal to the 1-device SketchStore + QueryEngine path —
same top-k seeds, same σ(S), same marginal gains — because sampling is
per-slot deterministic and every distributed reduction is an integer psum.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile                 # noqa: E402
import threading                # noqa: E402
import time                     # noqa: E402

import numpy as np              # noqa: E402
import jax                      # noqa: E402

from repro import sampling                                  # noqa: E402
from repro.graph import generators                          # noqa: E402
from repro.serve.influence import (MicroBatcher, PoolConfig,    # noqa: E402
                                   QueryEngine, ResultCache, SketchStore)
from repro.serve.distributed import (AsyncFrontEnd,             # noqa: E402
                                     DistributedQueryEngine,
                                     ShardedSketchStore)


def main():
    # Watchdog: if anything ever wedges (thread deadlock, lost wakeup),
    # die with a full all-thread stack dump well inside the driving
    # test's 900 s subprocess timeout instead of hanging silently.
    import faulthandler
    faulthandler.dump_traceback_later(600, exit=True)

    assert len(jax.devices()) == 8, jax.devices()
    g = generators.powerlaw_cluster(200, 6.0, prob=0.25, seed=13)
    cfg = PoolConfig(num_colors=64, max_batches=32, master_seed=3)

    # ---- per-slot bit identity: mesh only decides placement ---------------
    single = SketchStore(g, cfg)
    single.ensure(8)
    mesh8 = jax.make_mesh((8,), ("data",))
    sharded = ShardedSketchStore(g, cfg, mesh8)
    sharded.ensure(8)
    assert sharded.num_shards == 8
    for a, b in zip(single.batches, sharded.batches):
        assert a.batch_index == b.batch_index
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    print("OK shard_slots")

    # ---- engine equivalence: top-k / σ(S) / marginal bit-identical --------
    e1, e8 = QueryEngine(single), DistributedQueryEngine(sharded)
    s1, sig1 = e1.top_k(4)
    s8, sig8 = e8.top_k(4)
    np.testing.assert_array_equal(s1, s8)
    assert sig1 == sig8
    sets = [[0], [3, 50, 99], [10, 20, 30, 40]]
    np.testing.assert_array_equal(e1.sigma(sets), e8.sigma(sets))
    excl = [int(s1[0]), int(s1[1])]
    np.testing.assert_array_equal(e1.marginal_gains(excl),
                                  e8.marginal_gains(excl))
    np.testing.assert_array_equal(e1.best_extension(excl, 2),
                                  e8.best_extension(excl, 2))
    print("OK engine_equivalence")

    # ---- ragged slot count: 5 batches on 8 shards (zero-pad slots) --------
    s5 = SketchStore(g, cfg)
    s5.ensure(5)
    sh5 = ShardedSketchStore(g, cfg, mesh8)
    sh5.ensure(5)
    assert sh5.padded_batches == 8 and len(sh5.batches) == 5
    a1 = QueryEngine(s5).top_k(3)
    a8 = DistributedQueryEngine(sh5).top_k(3)
    np.testing.assert_array_equal(a1[0], a8[0])
    assert a1[1] == a8[1]
    print("OK ragged_shards")

    # ---- per-shard budget: N shards admit N× the per-device batches -------
    tight = PoolConfig(num_colors=64, max_batches=64, master_seed=3,
                       memory_budget_mb=2 * sharded.bytes_per_batch / 2**20)
    assert SketchStore(g, tight).capacity == 2
    assert ShardedSketchStore(g, tight, mesh8).capacity == 16
    print("OK per_shard_budget")

    # ---- elastic manifest restore: 8 shards → 2 shards → 1 device ---------
    with tempfile.TemporaryDirectory() as d:
        sharded.save(d)
        extra = ShardedSketchStore.saved_layout(d)
        assert extra["num_shards"] == 8
        assert extra["shard_layout"] == list(range(8))
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        r2 = ShardedSketchStore.restore(d, g, cfg, mesh2)
        assert r2.num_shards == 2 and r2.shard_layout() == [0] * 4 + [1] * 4
        s2, sig2 = DistributedQueryEngine(r2).top_k(4)
        np.testing.assert_array_equal(s1, s2)
        assert sig1 == sig2
        rp = SketchStore.restore(d, g, cfg)     # plain 1-device restore
        sp, sigp = QueryEngine(rp).top_k(4)
        np.testing.assert_array_equal(s1, sp)
        assert sig1 == sigp
    print("OK elastic_restore")

    # ---- data_parallel sampler: shard_map blocks ≡ dense per-batch --------
    # The unified Sampler contract on a real multi-device mesh: the same
    # (master_seed, batch_index) yields bit-identical visited masks whether
    # batches run one at a time on the default device (dense) or as a
    # sharded block with per-shard RNG streams (data_parallel), for both
    # diffusions.
    for diffusion in ("ic", "lt"):
        spec = sampling.SamplerSpec(diffusion=diffusion,
                                    backend="data_parallel",
                                    num_colors=64, master_seed=3)
        dp = sampling.make_sampler(g, spec, mesh=mesh8)
        dense = sampling.make_sampler(g, spec.replace(backend="dense"))
        for got in dp.sample_many(range(7)):        # ragged on 8 shards
            ref = dense.sample(got.batch_index)
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(ref.visited))
            np.testing.assert_array_equal(got.roots, np.asarray(ref.roots))
        stacked = dp.sample_stacked(range(8))
        assert stacked.sharding.spec == jax.sharding.PartitionSpec("data")
    print("OK data_parallel_sampling")

    # ---- data_parallel pool builds: ensure + refresh via shard_map --------
    # ShardedSketchStore with the data_parallel spec builds/refreshes shard
    # slots in one shard_map block (no per-batch default-device staging)
    # and stays bit-identical to the 1-device dense pool, slot for slot.
    dp_cfg = PoolConfig(max_batches=32,
                        spec=sampling.SamplerSpec(backend="data_parallel",
                                                  num_colors=64,
                                                  master_seed=3))
    dp_store = ShardedSketchStore(g, dp_cfg, mesh8)
    dp_store.ensure(8)
    ref_store = SketchStore(g, cfg)                 # dense, master_seed=3
    ref_store.ensure(8)
    for a, b in zip(ref_store.batches, dp_store.batches):
        assert a.batch_index == b.batch_index
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    slots_dp = dp_store.refresh(0.5)
    slots_ref = ref_store.refresh(0.5)
    assert slots_dp == slots_ref and dp_store.epoch == ref_store.epoch
    for a, b in zip(ref_store.batches, dp_store.batches):
        assert a.batch_index == b.batch_index
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    ed, er = DistributedQueryEngine(dp_store), QueryEngine(ref_store)
    sd, sigd = ed.top_k(4)
    sr, sigr = er.top_k(4)
    np.testing.assert_array_equal(sd, sr)
    assert sigd == sigr
    # spec rides the manifest: an LT restore of this IC pool must refuse
    with tempfile.TemporaryDirectory() as d:
        dp_store.save(d)
        assert ShardedSketchStore.saved_layout(d)["sampler_spec"][
            "backend"] == "data_parallel"
        try:
            ShardedSketchStore.restore(
                d, g, PoolConfig(spec=dp_cfg.spec.replace(diffusion="lt")),
                mesh8)
            raise AssertionError("diffusion mismatch must raise")
        except ValueError as e:
            assert "diffusion" in str(e)
        r = ShardedSketchStore.restore(d, g, dp_cfg, mesh8)
        s2, sig2 = DistributedQueryEngine(r).top_k(4)
        np.testing.assert_array_equal(sd, s2)
        assert sigd == sig2
    print("OK data_parallel_pool")

    # ---- LT diffusion through the full distributed stack ------------------
    lt_cfg = PoolConfig(max_batches=32,
                        spec=sampling.SamplerSpec(diffusion="lt",
                                                  backend="data_parallel",
                                                  num_colors=64,
                                                  master_seed=5))
    lt_store = ShardedSketchStore(g, lt_cfg, mesh8)
    lt_store.ensure(8)
    lt_single = SketchStore(
        g, PoolConfig(max_batches=32,
                      spec=lt_cfg.spec.replace(backend="dense")))
    lt_single.ensure(8)
    lt_seeds, lt_sig = DistributedQueryEngine(lt_store).top_k(4)
    l1_seeds, l1_sig = QueryEngine(lt_single).top_k(4)
    np.testing.assert_array_equal(lt_seeds, l1_seeds)
    assert lt_sig == l1_sig and lt_sig > 0
    print("OK lt_data_parallel")

    # ---- graph_parallel pools: 2-D (data × model) meshes ≡ 1-device dense -
    # The graph ITSELF is row-partitioned over 'model' (each device holds
    # only its slice of the adjacency tiles; the frontier is all-gathered
    # per level), batches shard over 'data' — and every pool slot is STILL
    # bit-identical to the 1-device dense pool, for both diffusions, on
    # both mesh orientations.
    # One dedupe-clean edge list for BOTH sides of the comparison: the tile
    # layout needs parallel edges merged, and bit-identity needs the dense
    # reference sampling the very same graph.
    from repro.graph import csr
    g2 = csr.dedupe(g)
    gp = dense_ref = None          # the ic stores feed the manifest section
    for diffusion in ("lt", "ic"):
        dense_ref = SketchStore(
            g2, PoolConfig(max_batches=32,
                          spec=sampling.SamplerSpec(diffusion=diffusion,
                                                    num_colors=64,
                                                    master_seed=3)))
        dense_ref.ensure(6)
        for d, m in ((2, 4), (4, 2)):
            mesh_dm = jax.make_mesh((d, m), ("data", "model"))
            gp_cfg = PoolConfig(
                max_batches=32,
                spec=sampling.SamplerSpec(diffusion=diffusion,
                                          backend="graph_parallel",
                                          num_colors=64, master_seed=3))
            gp = ShardedSketchStore(g2, gp_cfg, mesh_dm)
            gp.ensure(6)
            assert gp.num_shards == d
            for a, b in zip(dense_ref.batches, gp.batches):
                assert a.batch_index == b.batch_index
                np.testing.assert_array_equal(np.asarray(a.visited),
                                              np.asarray(b.visited))
        # engine answers from the last (4 × 2) store
        s_gp, sig_gp = DistributedQueryEngine(gp).top_k(4)
        s_rf, sig_rf = QueryEngine(dense_ref).top_k(4)
        np.testing.assert_array_equal(s_gp, s_rf)
        assert sig_gp == sig_rf
    print("OK graph_parallel_pool")

    # ---- graph_parallel KERNEL leg: Pallas tile kernels per shard ---------
    # REPRO_GP_KERNEL=1 swaps every shard's local tile expansion from the
    # jnp oracle to the Pallas kernels (`fused_expand` / `lt_select_expand`,
    # interpret mode on these CPU host devices).  The pool must STILL be
    # bit-identical to the 1-device dense pool, slot for slot, for both
    # diffusions and both frontier modes — the kernel is an execution
    # engine, never an answer change.
    os.environ["REPRO_GP_KERNEL"] = "1"
    try:
        mesh_22 = jax.make_mesh((2, 2), ("data", "model"))
        for diffusion in ("ic", "lt"):
            ref_k = SketchStore(
                g2, PoolConfig(max_batches=32,
                               spec=sampling.SamplerSpec(diffusion=diffusion,
                                                         num_colors=64,
                                                         master_seed=3)))
            ref_k.ensure(4)
            for frontier in ("dense", "sparse"):
                gpk = ShardedSketchStore(
                    g2, PoolConfig(max_batches=32,
                                   spec=sampling.SamplerSpec(
                                       diffusion=diffusion,
                                       backend="graph_parallel",
                                       num_colors=64, master_seed=3,
                                       tile_size=64, frontier=frontier)),
                    mesh_22)
                gpk.ensure(4)
                for a, b in zip(ref_k.batches, gpk.batches):
                    assert a.batch_index == b.batch_index
                    np.testing.assert_array_equal(np.asarray(a.visited),
                                                  np.asarray(b.visited))
            s_k, sig_k = DistributedQueryEngine(gpk).top_k(4)
            s_d, sig_d = QueryEngine(ref_k).top_k(4)
            np.testing.assert_array_equal(s_k, s_d)
            assert sig_k == sig_d
    finally:
        os.environ.pop("REPRO_GP_KERNEL", None)
    print("OK graph_parallel_kernel")

    # ---- graph_parallel refresh + manifest layout + restore refusal -------
    # (continues with the ic (4 × 2) store from the last loop iteration)
    slots_gp = gp.refresh(0.5)
    slots_rf = dense_ref.refresh(0.5)
    assert slots_gp == slots_rf and gp.epoch == dense_ref.epoch
    for a, b in zip(dense_ref.batches, gp.batches):
        assert a.batch_index == b.batch_index
        np.testing.assert_array_equal(np.asarray(a.visited),
                                      np.asarray(b.visited))
    with tempfile.TemporaryDirectory() as dir_:
        gp.save(dir_)
        extra = ShardedSketchStore.saved_layout(dir_)
        assert extra["mesh_shape"] == {"data": 4, "model": 2}
        assert extra["sampler_spec"]["backend"] == "graph_parallel"
        # layout mismatch: a graph_parallel restore onto a mesh with no
        # model axis must refuse (future refreshes could not row-partition)
        try:
            ShardedSketchStore.restore(dir_, g2, gp.config, mesh8)
            raise AssertionError("layout mismatch must raise")
        except ValueError as e:
            assert "model" in str(e)
        # a DIFFERENT (data × model) layout restores fine — elastic slot
        # re-sharding + fresh row partition for future refreshes
        mesh_24 = jax.make_mesh((2, 4), ("data", "model"))
        r = ShardedSketchStore.restore(dir_, g2, gp.config, mesh_24)
        assert r.num_shards == 2
        s_r, sig_r = DistributedQueryEngine(r).top_k(4)
        s_g, sig_g = DistributedQueryEngine(gp).top_k(4)
        np.testing.assert_array_equal(s_r, s_g)
        assert sig_r == sig_g
        # config=None adopts the snapshot's recorded spec wholesale: the
        # pool comes back with a graph_parallel sampler, never a silent
        # dense fallback for a graph that may not fit one device
        r_def = ShardedSketchStore.restore(dir_, g2, None, mesh_24)
        assert r_def.spec.backend == "graph_parallel"
        assert r_def.spec == gp.spec
    print("OK graph_parallel_manifest")

    # ---- sparse frontier on real multi-device meshes ≡ dense --------------
    # The sparse execution mode end to end on forced devices: compacted
    # per-level expansion inside shard_map bodies (data_parallel, 8 shards)
    # and the compacted (word_idx, word) frontier all-gather over the model
    # axis (graph_parallel, 2×4 — a tiny gather capacity forces the sparse
    # leg at every level that fits).  Pools must stay bit-identical to the
    # dense-frontier dense-backend reference, and the donated-buffer
    # refresh must keep them that way with the stack already staged.
    for diffusion in ("ic", "lt"):
        ref = SketchStore(
            g2, PoolConfig(max_batches=32,
                           spec=sampling.SamplerSpec(diffusion=diffusion,
                                                     num_colors=64,
                                                     master_seed=3)))
        ref.ensure(8)
        mesh_24 = jax.make_mesh((2, 4), ("data", "model"))
        stores = [
            ShardedSketchStore(
                g2, PoolConfig(max_batches=32, spec=sampling.SamplerSpec(
                    diffusion=diffusion, backend="data_parallel",
                    num_colors=64, master_seed=3, frontier="sparse")),
                mesh8),
            ShardedSketchStore(
                g2, PoolConfig(max_batches=32, spec=sampling.SamplerSpec(
                    diffusion=diffusion, backend="graph_parallel",
                    num_colors=64, master_seed=3, frontier="sparse",
                    frontier_capacity=4)), mesh_24),
        ]
        for st in stores:
            st.ensure(8)
            st.visited_stack()          # arm the in-place refresh path
        ref.refresh(0.5)
        for st in stores:
            st.refresh(0.5)
            for a, b in zip(ref.batches, st.batches):
                assert a.batch_index == b.batch_index
                np.testing.assert_array_equal(np.asarray(a.visited),
                                              np.asarray(b.visited))
            s_sp, sig_sp = DistributedQueryEngine(st).top_k(4)
            s_rf, sig_rf = QueryEngine(ref).top_k(4)
            np.testing.assert_array_equal(s_sp, s_rf)
            assert sig_sp == sig_rf
    print("OK sparse_frontier")

    # ---- butterfly log(M) frontier exchange: traffic + bit-identity -------
    # The sparse graph_parallel leg is a ⌈log₂M⌉-stage pairwise exchange
    # of compacted (word_idx, word) pairs.  Per level it must move FEWER
    # packed words over the model axis than the dense all-gather whenever
    # it engages, and the pool must stay bit-identical to the dense
    # single-device reference — including on a non-power-of-two model
    # axis (M=3, where the dissemination schedule's last stage overlaps
    # and the `have` bitmap dedups re-delivered blocks) and with a
    # capacity so tiny the dense early levels overflow back to the flat
    # all-gather via lax.cond.
    from jax.sharding import Mesh
    mesh_bf = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    mesh_m3 = Mesh(np.array(jax.devices()[:6]).reshape(2, 3),
                   ("data", "model"))
    for diffusion in ("ic", "lt"):
        ref_bf = SketchStore(g2, PoolConfig(
            max_batches=32, spec=sampling.SamplerSpec(
                diffusion=diffusion, num_colors=64, master_seed=3)))
        ref_bf.ensure(4)

        def bf_store(mesh_dm, capacity, frontier="sparse"):
            st = ShardedSketchStore(g2, PoolConfig(
                max_batches=32, spec=sampling.SamplerSpec(
                    diffusion=diffusion, backend="graph_parallel",
                    num_colors=64, master_seed=3, frontier=frontier,
                    frontier_capacity=capacity)), mesh_dm)
            st.ensure(4)
            for a, b in zip(ref_bf.batches, st.batches):
                assert a.batch_index == b.batch_index
                np.testing.assert_array_equal(np.asarray(a.visited),
                                              np.asarray(b.visited))
            return np.asarray(st.sampler.last_gather_words).sum(0), st
        gw_dense, _ = bf_store(mesh_bf, 0, frontier="dense")
        gw_bf, st_bf = bf_store(mesh_bf, 64)
        levels = np.flatnonzero(gw_dense)
        assert levels.size, "traversal must record per-level gather traffic"
        # never worse than dense, strictly better wherever it engaged
        assert (gw_bf[levels] <= gw_dense[levels]).all(), (gw_bf, gw_dense)
        assert (gw_bf[levels] < gw_dense[levels]).any(), (gw_bf, gw_dense)
        # capacity-overflow fallback: 1 packed word per shard — the dense
        # early levels MUST take the flat-gather leg (identical traffic)
        # and the bits must not care which leg any level took
        gw_ov, _ = bf_store(mesh_bf, 1)
        assert (gw_ov[levels] == gw_dense[levels]).any(), (gw_ov, gw_dense)
        assert (gw_ov[levels] >= gw_bf[levels]).all(), (gw_ov, gw_bf)
        # non-power-of-two model axis
        gw_m3, st_m3 = bf_store(mesh_m3, 64)
        s_m3, sig_m3 = DistributedQueryEngine(st_m3).top_k(4)
        s_bf, sig_bf = QueryEngine(ref_bf).top_k(4)
        np.testing.assert_array_equal(s_m3, s_bf)
        assert sig_m3 == sig_bf
    print("OK butterfly_exchange")

    # ---- model-sharded pool rows: V/M per device, elastic across D×M ------
    # On a mesh carrying a size-M model axis the pool's VERTEX rows shard
    # too: the stack is (Bp, Vp, W) with each device holding only its
    # (slot block × V/M row slice), the query engine merges with one psum
    # over data and one over model, and the answers stay bit-identical to
    # the 1-device engine.  Host batches stay full-V, so a snapshot saved
    # under 2×4 restores onto 4×2 or a model-free 8-shard mesh unchanged.
    mesh_rs = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    rs = ShardedSketchStore(g, cfg, mesh_rs)
    rs.ensure(8)
    assert rs.row_shards == 4 and rs.padded_vertices % 4 == 0
    stack = rs.visited_stack()
    assert stack.shape[:2] == (rs.padded_batches, rs.padded_vertices)
    blk = next(iter(stack.addressable_shards)).data
    assert blk.shape[1] == rs.padded_vertices // 4      # V/M rows/device
    er = DistributedQueryEngine(rs)
    s_rs, sig_rs = er.top_k(4)
    np.testing.assert_array_equal(s1, s_rs)
    assert sig1 == sig_rs
    np.testing.assert_array_equal(e1.sigma(sets), er.sigma(sets))
    np.testing.assert_array_equal(e1.marginal_gains(excl),
                                  er.marginal_gains(excl))
    np.testing.assert_array_equal(e1.best_extension(excl, 2),
                                  er.best_extension(excl, 2))
    # in-place refresh keeps the 2-D placement consistent (vertex-padded
    # donated scatter), pad rows stay zero
    rs.refresh(0.5)
    after = np.asarray(rs.visited_stack())
    np.testing.assert_array_equal(
        after[:len(rs.batches), :g.num_vertices],
        np.stack([np.asarray(b.visited) for b in rs.batches]))
    assert not after[:, g.num_vertices:].any()
    with tempfile.TemporaryDirectory() as d_:
        rs.save(d_)
        extra = ShardedSketchStore.saved_layout(d_)
        assert extra["row_layout"]["shards"] == 4
        assert extra["row_layout"]["padded_vertices"] == rs.padded_vertices
        want = DistributedQueryEngine(rs).top_k(4)
        mesh_42 = Mesh(np.array(jax.devices()).reshape(4, 2),
                       ("data", "model"))
        for new_mesh, m_new in ((mesh_42, 2), (mesh8, 1)):
            r_new = ShardedSketchStore.restore(d_, g, cfg, new_mesh)
            assert r_new.row_shards == m_new
            got = DistributedQueryEngine(r_new).top_k(4)
            np.testing.assert_array_equal(want[0], got[0])
            assert want[1] == got[1]
    print("OK rowsharded_pool")

    # ---- async front-end: deadline flush, concurrency, refresh ------------
    deadline = 0.2
    engine = DistributedQueryEngine(sharded)
    engine.sigma([[0]])     # compile before the deadline clock matters
    fe = AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=deadline, flush_slots=8,
                       refresh_every=1.5)
    # a lone request must flush at its deadline, not wait for a full slot
    lone = fe.submit_sigma([3, 50, 99])
    v = lone.result(timeout=30)
    assert v == engine.sigma([[3, 50, 99]])[0]
    assert fe.stats.deadline_flushes >= 1, fe.stats
    # concurrent callers from many threads, correct fan-out
    futs, expect = [], {}
    lock = threading.Lock()

    def client(i):
        q = [i % 50, (i * 7) % 50 + 50]
        f = fe.submit_sigma(q)
        with lock:
            futs.append((f, tuple(q)))

    threads = [threading.Thread(target=client, args=(i,)) for i in range(24)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # Drain every future BEFORE computing references: a direct
    # engine.sigma here while the dispatch thread is mid-flush would run
    # two 8-participant collective programs concurrently, and the CPU
    # backend's shared rendezvous pool can starve-deadlock on that.
    got = [(f.result(timeout=30), q) for f, q in futs]
    for v_got, q in got:
        assert v_got == engine.sigma([list(q)])[0], q
    # no request waited past its deadline (dispatch-start vs submit time);
    # generous epsilon for CPU scheduling jitter
    assert fe.stats.max_queue_wait <= deadline + 0.25, fe.stats
    time.sleep(2.0)                       # let the background refresh fire
    fe.close()
    assert fe.stats.refreshes >= 1, fe.stats
    # refresh bumped the epoch → old answers recompute under the new pool
    assert engine.store.epoch >= 1
    # close() joined the worker: the version must now hold still across a
    # full refresh period
    ver_after_close = engine.store.version
    time.sleep(1.6)
    assert engine.store.version == ver_after_close
    print("OK async_frontend")

    # ---- streaming deltas on sharded pools ≡ cold rebuild ≡ 1-device ------
    # A graph delta swept through the 8-shard data_parallel store via the
    # incremental (dirty-slot-only) path must leave the pool bit-identical
    # to (a) a cold rebuild of the same batch indices on the mutated pair
    # and (b) a 1-device dense SketchStore built fresh on that pair — for
    # both diffusions.  The donated-scatter stack must track it in place.
    from repro.stream import (DirtySlotTracker, cold_rebuild_batches,
                              incremental_refresh, random_delta)
    for diffusion in ("ic", "lt"):
        st_cfg = PoolConfig(max_batches=32, spec=sampling.SamplerSpec(
            diffusion=diffusion, backend="data_parallel", num_colors=64,
            master_seed=3, tile_size=64, frontier="sparse"))
        st8 = ShardedSketchStore(g2, st_cfg, mesh8)
        st8.ensure(8)
        st8.visited_stack()
        tracker = DirtySlotTracker.for_store(st8)
        rng = np.random.default_rng(29)
        delta = random_delta(st8.graph, rng, num_deletes=5, num_inserts=5)
        report = incremental_refresh(st8, tracker, delta)
        assert st8.version[0] == 1 and report.dirty_slots >= 1
        cold = cold_rebuild_batches(st8)
        single = SketchStore(st8.graph,
                             PoolConfig(max_batches=32,
                                        spec=st_cfg.spec.replace(
                                            backend="dense")),
                             g_rev=st8.g_rev)
        single.ensure(8)
        for got, want, ref in zip(st8.batches, cold, single.batches):
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(want.visited))
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(ref.visited))
            # Counters compare within one backend only: the shard_map
            # sampler reports the -1 "not tracked" sentinel.
            assert got.fused_edge_visits == want.fused_edge_visits
            assert got.unfused_edge_visits == want.unfused_edge_visits
        np.testing.assert_array_equal(
            np.asarray(st8.visited_stack()),
            np.stack([np.asarray(b.visited) for b in cold]))
    print("OK stream_updates")


if __name__ == "__main__":
    main()
