"""Unit + property tests for the packed color-bitmask layer."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitmask


def test_num_words():
    assert bitmask.num_words(1) == 1
    assert bitmask.num_words(32) == 1
    assert bitmask.num_words(33) == 2
    assert bitmask.num_words(1024) == 32


def test_tail_mask():
    m = bitmask.color_tail_mask(40)
    assert m.shape == (2,)
    assert m[0] == 0xFFFFFFFF and m[1] == 0xFF


@given(st.lists(st.integers(0, 31), min_size=1, max_size=64))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(colors):
    mask = bitmask.make_mask(4, 32)
    rows = jnp.zeros(len(colors), jnp.int32)
    mask = bitmask.set_color(mask, rows, jnp.asarray(colors, jnp.int32))
    bits = bitmask.unpack_bits(mask)
    assert bool((bitmask.pack_bits(bits) == mask).all())
    expected = np.zeros(32, bool)
    expected[list(set(colors))] = True
    np.testing.assert_array_equal(np.asarray(bits)[0, 0], expected)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_popcount_matches_python(word):
    got = int(bitmask.popcount(jnp.asarray([word], jnp.uint32))[0])
    assert got == bin(word).count("1")


def test_set_color_duplicates_or():
    """Duplicate (row, color) and same-row different colors both OR in."""
    mask = bitmask.make_mask(3, 64)
    rows = jnp.asarray([1, 1, 1, 2], jnp.int32)
    cols = jnp.asarray([0, 0, 33, 5], jnp.int32)
    mask = bitmask.set_color(mask, rows, cols)
    m = np.asarray(mask)
    assert m[1, 0] == 1 and m[1, 1] == (1 << 1)
    assert m[2, 0] == (1 << 5)
    assert m[0].sum() == 0


def test_count_colors():
    mask = jnp.asarray([[0x3, 0x0], [0xFFFFFFFF, 0x1]], jnp.uint32)
    np.testing.assert_array_equal(np.asarray(bitmask.count_colors(mask)), [2, 33])


def test_scatter_or_words_duplicate_indices():
    dst = jnp.zeros((4, 2), jnp.uint32)
    rows = jnp.asarray([2, 2, 0], jnp.int32)
    words = jnp.asarray([1, 1, 0], jnp.int32)
    vals = jnp.asarray([0b01, 0b10, 0xF], jnp.uint32)
    out = np.asarray(bitmask.scatter_or_words(dst, rows, words, vals))
    assert out[2, 1] == 0b11
    assert out[0, 0] == 0xF


def test_scatter_or_words_unique_fast_path_matches_general():
    """The packed ``unique=True`` fast path (1× index traffic) must equal
    the 32×-unpacked general path whenever every (row, word) target is
    distinct — including OR-ing into already-set destination bits."""
    rng = np.random.default_rng(0)
    rows_n, words_n, k = 64, 2, 40
    flat = rng.choice(rows_n * words_n, size=k, replace=False)
    rows = jnp.asarray(flat // words_n, jnp.int32)
    words = jnp.asarray(flat % words_n, jnp.int32)
    vals = jnp.asarray(rng.integers(0, 2 ** 32, k, np.uint32))
    dst = jnp.asarray(rng.integers(0, 2 ** 32, (rows_n, words_n), np.uint32))
    slow = bitmask.scatter_or_words(dst, rows, words, vals)
    fast = bitmask.scatter_or_words(dst, rows, words, vals, unique=True)
    np.testing.assert_array_equal(np.asarray(slow), np.asarray(fast))
    # OR semantics, not overwrite: pre-set bits survive
    assert np.all(np.asarray(fast) & np.asarray(dst) == np.asarray(dst))
