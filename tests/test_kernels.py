"""Per-kernel tests: interpret-mode Pallas vs pure-jnp oracle, swept over
shapes/dtypes/graphs, plus end-to-end tiled-vs-CSR traversal coupling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitmask, tiles, tiled_traversal, traversal
from repro.graph import csr, generators
from repro.kernels import coverage, flash_attention, fused_expand, ops, ref


def _random_graph(n, e, p, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e)
    dst = (src + 1 + rng.integers(0, n - 1, e)) % n
    if isinstance(p, tuple):
        probs = rng.uniform(*p, e).astype(np.float32)
    else:
        probs = np.full(e, p, np.float32)
    return csr.from_edges(src, dst, probs, n, dedupe=True)


# ---------------------------------------------------------------- fused_expand
@pytest.mark.parametrize("tile_size", [64, 128])
@pytest.mark.parametrize("n_colors", [32, 64, 96])
@pytest.mark.parametrize("p", [0.0, 0.3, 1.0, (0.1, 0.9)])
def test_fused_expand_kernel_matches_ref(tile_size, n_colors, p):
    g = _random_graph(300, 1500, p, seed=tile_size + n_colors)
    tg = tiles.from_graph(g, tile_size=tile_size)
    starts = traversal.random_starts(jax.random.key(0), g.num_vertices, n_colors)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, n_colors, starts),
        tg.padded_vertices)
    seed, level = jnp.uint32(5), jnp.uint32(0)
    out_ref = ref.fused_expand_ref(tg.prob, tg.edge_id, tg.tile_src,
                                   tg.tile_dst, fr, fr, seed, level)
    out_ker = fused_expand.fused_expand(
        tg.prob, tg.edge_id, tg.tile_src, tg.tile_dst, tg.first_of_dst,
        fr, fr, seed, level, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_ker))


def test_fused_expand_matches_csr_step():
    """Tile path ≡ CSR edge-centric path, bit-for-bit (coupled RNG)."""
    g = _random_graph(500, 4000, (0.2, 0.8), seed=3)
    tg = tiles.from_graph(g)
    starts = traversal.random_starts(jax.random.key(2), g.num_vertices, 64)
    fr = traversal.init_frontier(g.num_vertices, 64, starts)
    nf_csr, _, _ = traversal.fused_step(
        g, fr, bitmask.make_mask(g.num_vertices, 64), jnp.int32(0),
        jnp.uint32(11))
    fr_p = tiles.pad_mask_rows(fr, tg.padded_vertices)
    nf_tile = ops.fused_expand(tg, fr_p, fr_p, 11, 0)
    np.testing.assert_array_equal(
        np.asarray(nf_tile)[: g.num_vertices], np.asarray(nf_csr))


def test_fused_expand_empty_frontier():
    g = _random_graph(200, 800, 0.5)
    tg = tiles.from_graph(g)
    fr = jnp.zeros((tg.padded_vertices, 2), jnp.uint32)
    out = ops.fused_expand(tg, fr, fr, 0, 0)
    assert int(np.asarray(out).sum()) == 0


def test_fused_expand_padded_tiles_are_noops():
    g = _random_graph(300, 1200, 0.6, seed=9)
    tg = tiles.from_graph(g)
    tg_pad = tiles.from_graph(g, pad_tiles_to=tg.num_tiles + 7)
    starts = traversal.random_starts(jax.random.key(1), g.num_vertices, 32)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, 32, starts),
        tg.padded_vertices)
    a = ops.fused_expand(tg, fr, fr, 4, 0)
    b = ops.fused_expand(tg_pad, fr, fr, 4, 0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_tiled_traversal_equals_csr_traversal(use_kernel):
    g = _random_graph(400, 2500, (0.1, 0.7), seed=17)
    n_colors = 64
    starts = traversal.random_starts(jax.random.key(5), g.num_vertices, n_colors)
    res_csr = traversal.run_fused(g, starts, n_colors, jnp.uint32(21))
    tg = tiles.from_graph(g)
    vis_tiled, levels, grid_steps = tiled_traversal.run_fused_tiled(
        tg, starts, n_colors, 21, use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(vis_tiled),
                                  np.asarray(res_csr.visited))
    assert int(levels) == int(res_csr.stats.levels_run)
    assert int(grid_steps) == int(levels) * tg.num_tiles   # dense grid


# ------------------------------------------------------------ lt_select_expand
@pytest.mark.parametrize("tile_size", [32, 64, 128])
@pytest.mark.parametrize("n_colors", [32, 64, 96])
def test_lt_select_expand_kernel_matches_ref(tile_size, n_colors):
    """One LT expansion level: Pallas kernel ≡ jnp oracle, bit for bit,
    across tile sizes (incl. padded last blocks) and multi-word colors."""
    from repro.core import lt
    from repro.kernels import lt_select_expand as lse
    g = lt.normalize_lt_weights(
        _random_graph(300, 1500, (0.1, 0.9), seed=tile_size + n_colors))
    tg = tiles.from_graph(g, tile_size=tile_size)
    cb = tiles.edge_values_to_tiles(tg, lt.selection_cum_before(g))
    starts = traversal.random_starts(jax.random.key(0), g.num_vertices,
                                     n_colors)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, n_colors, starts),
        tg.padded_vertices)
    u = ref.lt_selection_uniforms(jnp.uint32(5), tg.padded_vertices,
                                  n_colors)
    out_ref = ref.lt_select_expand_ref(tg.prob, cb, tg.tile_src,
                                       tg.tile_dst, fr, fr, u)
    out_ker = lse.lt_select_expand(tg.prob, cb, tg.tile_src, tg.tile_dst,
                                   tg.first_of_dst, fr, fr, u,
                                   interpret=True)
    np.testing.assert_array_equal(np.asarray(out_ref), np.asarray(out_ker))


@pytest.mark.parametrize("frontier", ["dense", "sparse"])
def test_lt_tiled_kernel_traversal_equals_dense_lt(frontier):
    """Full LT traversal through the Pallas kernel (dense grid and the
    compacted sparse grid) ≡ `lt.run_fused_lt` on the CSR path; the sparse
    grid must run no more steps than the dense grid."""
    from repro.core import lt
    g = lt.normalize_lt_weights(_random_graph(400, 2500, (0.1, 0.7),
                                              seed=11))
    starts = traversal.random_starts(jax.random.key(4), g.num_vertices, 64)
    ref_vis = lt.run_fused_lt(g, starts, 64, jnp.uint32(9))
    tg = tiles.from_graph(g)
    cb = tiles.edge_values_to_tiles(tg, lt.selection_cum_before(g))
    vis, levels, gs = tiled_traversal.run_fused_lt_tiled(
        tg, cb, starts, 64, 9, use_kernel=True, frontier=frontier)
    np.testing.assert_array_equal(np.asarray(vis), np.asarray(ref_vis))
    if frontier == "dense":
        assert int(gs) == int(levels) * tg.num_tiles
    else:
        assert 0 < int(gs) <= int(levels) * tg.num_tiles


# -------------------------------------------------------------------- coverage
@pytest.mark.parametrize("rows,words", [(128, 1), (256, 2), (384, 4), (1024, 32)])
def test_cover_counts_matches_ref(rows, words):
    rng = np.random.default_rng(rows + words)
    vis = jnp.asarray(rng.integers(0, 2**32, (rows, words), dtype=np.uint32))
    act = jnp.asarray(rng.integers(0, 2**32, (words,), dtype=np.uint32))
    out_k = coverage.cover_counts(vis, act, interpret=True)
    out_r = ref.cover_counts_ref(vis, act)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_cover_counts_unpadded_rows():
    rng = np.random.default_rng(0)
    vis = jnp.asarray(rng.integers(0, 2**32, (300, 2), dtype=np.uint32))
    act = jnp.asarray([0xFFFFFFFF, 0xFF], dtype=jnp.uint32)
    out = ops.cover_counts(vis, act)
    assert out.shape == (300,)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.cover_counts_ref(vis, act)))


def test_cover_counts_active_mask_excludes():
    vis = jnp.full((128, 1), 0xFFFFFFFF, jnp.uint32)
    assert int(ops.cover_counts(vis, jnp.asarray([0x0F], jnp.uint32))[0]) == 4


# ------------------------------------------------------------- flash attention
@pytest.mark.parametrize("L,H,D", [(128, 2, 64), (256, 4, 128), (384, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(L, H, D, dtype, causal):
    k1, k2, k3 = jax.random.split(jax.random.key(L + H), 3)
    q = jax.random.normal(k1, (L, H, D), dtype)
    k = jax.random.normal(k2, (L, H, D), dtype)
    v = jax.random.normal(k3, (L, H, D), dtype)
    out = flash_attention.flash_attention(q, k, v, causal=causal,
                                          block_q=128, block_k=128,
                                          interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    """Decode: 128 new queries against a 512 cache with kv_offset."""
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (128, 2, 64), jnp.float32)
    k = jax.random.normal(k2, (512, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (512, 2, 64), jnp.float32)
    out = flash_attention.flash_attention(q, k, v, causal=True, kv_offset=384,
                                          interpret=True)
    expected = ref.flash_attention_ref(q, k, v, causal=True, kv_offset=384)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_block_shape_invariance():
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(k1, (256, 2, 64), jnp.float32)
    k = jax.random.normal(k2, (256, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (256, 2, 64), jnp.float32)
    a = flash_attention.flash_attention(q, k, v, block_q=128, block_k=128,
                                        interpret=True)
    b = flash_attention.flash_attention(q, k, v, block_q=256, block_k=64,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-5)


# --------------------------------------------------------- quantized kernel
def test_fused_expand_q_kernel_matches_ref():
    from repro.kernels import fused_expand_q as feq
    g = _random_graph(400, 2500, (0.1, 0.9), seed=5)
    tg = tiles.from_graph(g)
    q8 = feq.quantize_probs(tg.prob)
    starts = traversal.random_starts(jax.random.key(0), g.num_vertices, 64)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, 64, starts),
        tg.padded_vertices)
    k = feq.fused_expand_q(q8, tg.tile_src, tg.tile_dst, tg.first_of_dst,
                           fr, fr, jnp.uint32(3), jnp.uint32(0),
                           interpret=True)
    r = feq.fused_expand_q_ref(q8, tg.tile_src, tg.tile_dst, fr, fr,
                               jnp.uint32(3), jnp.uint32(0))
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


def test_fused_expand_q_gathered_matches_dense_grid():
    """The sparse-grid q kernel: a compacted (null-padded) tile list with
    ORIGINAL tile ids prefetched draws the dense grid's position-derived
    RNG bits — output ≡ the dense-grid kernel on the full stacks."""
    from repro.core import sparse
    from repro.kernels import fused_expand_q as feq
    g = _random_graph(400, 2500, (0.1, 0.9), seed=6)
    tg = tiles.from_graph(g)
    q8 = feq.quantize_probs(tg.prob)
    # Low-occupancy frontier: all 64 colors rooted on one vertex.
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, 64,
                                jnp.zeros((64,), jnp.int32)),
        tg.padded_vertices)
    dense = feq.fused_expand_q(q8, tg.tile_src, tg.tile_dst,
                               tg.first_of_dst, fr, fr, jnp.uint32(3),
                               jnp.uint32(0), interpret=True)
    tgn = tiles.with_null_tile(tg)
    q8n = feq.quantize_probs(tgn.prob)
    act = sparse.row_block_activity(fr, tg.tile_size)
    nt = tg.num_tiles
    n_active = int(np.asarray(
        act[tg.tile_src].astype(jnp.int32)).sum())
    assert 0 < n_active < nt                    # genuinely compacted
    cap = n_active + 3                          # force null-tile padding
    ids = tiles.active_tile_ids(tg.tile_src, act, cap, nt)
    fi = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (tgn.tile_dst[ids][1:] != tgn.tile_dst[ids][:-1])
         .astype(jnp.int32)])
    gathered = feq.fused_expand_q_gathered(
        q8n[ids], ids, tgn.tile_src[ids], tgn.tile_dst[ids], fi, fr, fr,
        jnp.uint32(3), jnp.uint32(0), interpret=True)
    np.testing.assert_array_equal(np.asarray(gathered), np.asarray(dense))


def test_quantize_probs_endpoints_exact():
    from repro.kernels import fused_expand_q as feq
    q = np.asarray(feq.quantize_probs(jnp.asarray([0.0, 1.0, 0.5, 1e-9])))
    assert q[0] == 0, "p=0 must stay never-activate"
    assert q[1] == 255, "p=1 must stay always-activate"
    # accept ⇔ u8 ≤ q ∧ q>0: p̂(255) = 256/256 = 1 exactly
    assert q[2] in (127, 128)


def test_fused_expand_q_statistics_match_exact_path():
    """Quantized and exact kernels must agree on expansion statistics
    within Monte-Carlo noise (they use different RNG streams)."""
    from repro.kernels import fused_expand_q as feq
    g = _random_graph(600, 6000, 0.4, seed=8)
    tg = tiles.from_graph(g)
    q8 = feq.quantize_probs(tg.prob)
    starts = traversal.random_starts(jax.random.key(2), g.num_vertices, 128)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, 128, starts),
        tg.padded_vertices)
    a = b = 0
    for seed in range(5):
        out_q = feq.fused_expand_q(q8, tg.tile_src, tg.tile_dst,
                                   tg.first_of_dst, fr, fr,
                                   jnp.uint32(seed), jnp.uint32(0),
                                   interpret=True)
        out_f = ref.fused_expand_ref(tg.prob, tg.edge_id, tg.tile_src,
                                     tg.tile_dst, fr, fr, jnp.uint32(seed),
                                     jnp.uint32(0))
        from repro.core import bitmask
        a += int(bitmask.count_colors(out_q).sum())
        b += int(bitmask.count_colors(out_f).sum())
    assert abs(a - b) / max(b, 1) < 0.05, (a, b)


def test_fused_expand_q_p1_full_bfs():
    """p=1 quantizes exactly: quantized expansion == deterministic BFS."""
    from repro.kernels import fused_expand_q as feq
    g = _random_graph(300, 1500, 1.0, seed=2)
    tg = tiles.from_graph(g)
    q8 = feq.quantize_probs(tg.prob)
    starts = traversal.random_starts(jax.random.key(1), g.num_vertices, 32)
    fr = tiles.pad_mask_rows(
        traversal.init_frontier(g.num_vertices, 32, starts),
        tg.padded_vertices)
    out_q = feq.fused_expand_q(q8, tg.tile_src, tg.tile_dst,
                               tg.first_of_dst, fr, fr, jnp.uint32(0),
                               jnp.uint32(0), interpret=True)
    out_f = ref.fused_expand_ref(tg.prob, tg.edge_id, tg.tile_src,
                                 tg.tile_dst, fr, fr, jnp.uint32(0),
                                 jnp.uint32(0))
    np.testing.assert_array_equal(np.asarray(out_q), np.asarray(out_f))
