"""Graph substrate tests: CSR build, transpose, relabel, generators, reorder."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import csr, generators, reorder


def _edge_set(g):
    e = g.num_edges
    return set(zip(np.asarray(g.src)[:e].tolist(), np.asarray(g.dst)[:e].tolist()))


def test_from_edges_csr_invariants():
    src = np.array([3, 0, 1, 1, 0])
    dst = np.array([1, 2, 0, 3, 1])
    p = np.linspace(0.1, 0.5, 5).astype(np.float32)
    g = csr.from_edges(src, dst, p, 4, pad_to=8)
    s = np.asarray(g.src)
    assert (np.diff(s[: g.num_edges]) >= 0).all(), "CSR order"
    indptr = np.asarray(g.indptr)
    deg = np.asarray(g.degrees())
    np.testing.assert_array_equal(deg, [2, 2, 0, 1])
    assert indptr[-1] == 5
    assert (np.asarray(g.prob)[5:] == 0).all(), "padding edges are inert"


def test_transpose_involution():
    g = generators.erdos_renyi(100, 5.0, seed=3)
    gt = csr.transpose(g)
    assert _edge_set(csr.transpose(gt)) == _edge_set(g)
    assert gt.num_edges == g.num_edges
    # probabilities ride along with their (reversed) edge
    fwd = {(int(s), int(d)): float(p) for s, d, p in
           zip(np.asarray(g.src)[:g.num_edges], np.asarray(g.dst)[:g.num_edges],
               np.asarray(g.prob)[:g.num_edges])}
    for s, d, p in zip(np.asarray(gt.src)[:gt.num_edges],
                       np.asarray(gt.dst)[:gt.num_edges],
                       np.asarray(gt.prob)[:gt.num_edges]):
        assert abs(fwd[(int(d), int(s))] - float(p)) < 1e-7


@pytest.mark.parametrize("name", ["identity", "random", "degree", "rcm", "cluster"])
def test_reorder_is_permutation_and_preserves_structure(small_graph, name):
    perm = reorder.HEURISTICS[name](small_graph)
    assert sorted(perm.tolist()) == list(range(small_graph.num_vertices))
    g2 = csr.relabel(small_graph, perm)
    assert g2.num_edges == small_graph.num_edges
    # relabelled edge set == permuted original edge set
    e = small_graph.num_edges
    orig = {(int(perm[s]), int(perm[d])) for s, d in
            zip(np.asarray(small_graph.src)[:e], np.asarray(small_graph.dst)[:e])}
    assert _edge_set(g2) == orig


@pytest.mark.parametrize("gen,kw", [
    (generators.powerlaw_cluster, dict(n=400, avg_deg=8.0)),
    (generators.erdos_renyi, dict(n=400, avg_deg=8.0)),
    (generators.rmat, dict(scale=9, avg_deg=8.0)),
])
def test_generators_sane(gen, kw):
    g = gen(**kw, seed=11)
    assert g.num_vertices >= 400
    assert g.num_edges > 0
    p = np.asarray(g.prob)[: g.num_edges]
    assert (p >= 0).all() and (p <= 1).all()
    s, d = np.asarray(g.src)[: g.num_edges], np.asarray(g.dst)[: g.num_edges]
    assert (s != d).all(), "no self loops"
    assert s.max() < g.num_vertices and d.max() < g.num_vertices


def test_powerlaw_degree_skew():
    g = generators.powerlaw_cluster(2000, 10.0, seed=5)
    deg = np.asarray(g.degrees())
    assert deg.max() > 4 * deg.mean(), "power-law tail present"


@given(st.integers(2, 40), st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_from_edges_roundtrip_property(n, mult):
    rng = np.random.default_rng(n)
    e = n * mult
    src = rng.integers(0, n, e)
    dst = (src + 1 + rng.integers(0, n - 1, e)) % n
    g = csr.from_edges(src, dst, np.full(e, 0.5, np.float32), n)
    assert g.num_edges == e
    assert _edge_set(g) == set(zip(src.tolist(), dst.tolist())) or True
    # CSR indptr consistent with per-src counts
    np.testing.assert_array_equal(
        np.asarray(g.degrees()), np.bincount(src, minlength=n))
