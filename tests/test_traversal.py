"""Fused-BPT behaviour tests: coupled fused/unfused equivalence, Theorem 1,
monotonicity, determinism, and Fig.-3-style hand-checkable cases."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bitmask, traversal
from repro.graph import csr, generators


SEED = jnp.uint32(2024)


def _run(g, n_colors, seed=SEED, key=0, sort=False):
    starts = traversal.random_starts(jax.random.key(key), g.num_vertices,
                                     n_colors, sort=sort)
    return starts, traversal.run_fused(g, starts, n_colors, seed)


def test_fused_equals_unfused_coupled(small_graph):
    """Bit-for-bit: fused visited == union of single-color runs on the SAME
    RNG streams. This is the exactness the counter RNG buys us."""
    starts, res = _run(small_graph, 64)
    vis_unfused, _ = traversal.run_unfused(small_graph, np.asarray(starts),
                                           64, SEED)
    np.testing.assert_array_equal(np.asarray(res.visited),
                                  np.asarray(vis_unfused))


def test_theorem1_fused_visits_leq_unfused(small_graph):
    """Theorem 1 on coupled realizations: fused edge visits ≤ unfused."""
    _, res = _run(small_graph, 128)
    fused = int(res.stats.fused_edge_visits.sum())
    unfused = int(res.stats.unfused_edge_visits.sum())
    assert fused <= unfused
    assert fused > 0


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_theorem1_property(seed):
    """Theorem 1 must hold for every graph/seed — property test."""
    g = generators.erdos_renyi(120, 5.0, prob=0.4, seed=seed % 97)
    starts = traversal.random_starts(jax.random.key(seed), g.num_vertices, 32)
    res = traversal.run_fused(g, starts, 32, jnp.uint32(seed))
    assert int(res.stats.fused_edge_visits.sum()) <= \
        int(res.stats.unfused_edge_visits.sum())


def test_start_vertices_always_visited(small_graph):
    starts, res = _run(small_graph, 64)
    vis = np.asarray(res.visited)
    for c, v in enumerate(np.asarray(starts)):
        assert vis[v, c // 32] >> (c % 32) & 1, f"color {c} missing own start"


def test_visited_closed_under_reachability_p1(tiny_graph):
    """With p=1 the BPT is a plain BFS: visited == reachable set."""
    g = tiny_graph
    e = g.num_edges
    g1 = csr.from_edges(np.asarray(g.src)[:e], np.asarray(g.dst)[:e],
                        np.ones(e, np.float32), g.num_vertices)
    starts = jnp.zeros((1,), jnp.int32)          # single color from vertex 0
    res = traversal.run_fused(g1, starts, 1, SEED)
    vis = np.asarray(res.visited)[:, 0] & 1
    # host BFS oracle
    adj = {}
    for s, d in zip(np.asarray(g1.src)[:e], np.asarray(g1.dst)[:e]):
        adj.setdefault(int(s), []).append(int(d))
    seen, stack = {0}, [0]
    while stack:
        v = stack.pop()
        for u in adj.get(v, []):
            if u not in seen:
                seen.add(u)
                stack.append(u)
    expected = np.zeros(g1.num_vertices, np.uint32)
    expected[list(seen)] = 1
    np.testing.assert_array_equal(vis, expected)


def test_zero_prob_never_propagates(tiny_graph):
    g = tiny_graph
    e = g.num_edges
    g0 = csr.from_edges(np.asarray(g.src)[:e], np.asarray(g.dst)[:e],
                        np.zeros(e, np.float32), g.num_vertices)
    starts = jnp.asarray([2, 5], jnp.int32)
    res = traversal.run_fused(g0, starts, 2, SEED)
    assert int(bitmask.count_colors(res.visited).sum()) == 2  # only starts


def test_determinism_same_seed(small_graph):
    s1, r1 = _run(small_graph, 32, key=5)
    s2, r2 = _run(small_graph, 32, key=5)
    np.testing.assert_array_equal(np.asarray(r1.visited), np.asarray(r2.visited))


def test_different_seed_differs(small_graph):
    starts, _ = _run(small_graph, 32)
    r1 = traversal.run_fused(small_graph, starts, 32, jnp.uint32(1))
    r2 = traversal.run_fused(small_graph, starts, 32, jnp.uint32(2))
    assert not np.array_equal(np.asarray(r1.visited), np.asarray(r2.visited))


def test_visited_monotone_in_prob():
    """Stochastic-dominance sanity: higher p ⇒ more visited (coupled draws
    share the same uniforms, so dominance is exact per color)."""
    rng = np.random.default_rng(0)
    src = rng.integers(0, 200, 1200)
    dst = (src + 1 + rng.integers(0, 199, 1200)) % 200
    starts = jnp.arange(16, dtype=jnp.int32)
    sizes = []
    for p in (0.05, 0.3, 0.8):
        g = csr.from_edges(src, dst, np.full(1200, p, np.float32), 200)
        res = traversal.run_fused(g, starts, 16, SEED)
        sizes.append(int(bitmask.count_colors(res.visited).sum()))
    assert sizes[0] <= sizes[1] <= sizes[2]


def test_multiple_colors_same_start(tiny_graph):
    """Paper Fig. 3: several traversals may start at one vertex."""
    starts = jnp.asarray([1, 1, 1], jnp.int32)
    res = traversal.run_fused(tiny_graph, starts, 3, SEED)
    vis = np.asarray(res.visited)
    assert vis[1, 0] & 0b111 == 0b111
    # colors evolve independently despite the shared start
    cols = [(vis[:, 0] >> c) & 1 for c in range(3)]
    assert not (np.array_equal(cols[0], cols[1])
                and np.array_equal(cols[1], cols[2]))


def test_max_levels_cap():
    """A long path graph with p=1 stops at the level cap but keeps frontier
    colors in visited."""
    n = 50
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    g = csr.from_edges(src, dst, np.ones(n - 1, np.float32), n)
    res = traversal.run_fused(g, jnp.zeros((1,), jnp.int32), 1, SEED,
                              max_levels=10)
    assert int(res.stats.levels_run) == 10
    vis = np.asarray(res.visited)[:, 0]
    assert vis[:11].all() and not vis[12:].any()


def test_stats_occupancy_bounds(small_graph):
    _, res = _run(small_graph, 64)
    occ = np.asarray(res.stats.occupancy_num)
    assert (occ >= 0).all() and (occ <= 1.0 + 1e-6).all()
    frac = np.asarray(res.stats.active_tile_frac)
    assert (frac >= 0).all() and (frac <= 1.0).all()


def test_run_fused_block_matches_per_batch(small_graph):
    """The fused multi-batch sweep (ONE lax.map dispatch — the pool-build
    fast path) must reproduce per-batch run_fused exactly: visited masks
    AND summed edge-visit counters."""
    starts = jnp.stack([
        traversal.random_starts(jax.random.key(k), small_graph.num_vertices,
                                64) for k in range(3)])
    seeds = jnp.asarray([7, 8, 9], jnp.uint32)
    vis, fused, unfused = traversal.run_fused_block(small_graph, starts,
                                                    seeds, 64)
    for i in range(3):
        ref = traversal.run_fused(small_graph, starts[i], 64, seeds[i])
        np.testing.assert_array_equal(np.asarray(vis[i]),
                                      np.asarray(ref.visited))
        assert int(fused[i]) == int(np.asarray(
            ref.stats.fused_edge_visits, np.int64).sum())
        assert int(unfused[i]) == int(np.asarray(
            ref.stats.unfused_edge_visits, np.int64).sum())


def test_run_fused_lt_block_matches_per_batch(small_graph):
    from repro.core import lt
    g = lt.normalize_lt_weights(small_graph)
    cb = jnp.asarray(lt.selection_cum_before(g))
    starts = jnp.stack([
        traversal.random_starts(jax.random.key(k), g.num_vertices, 64)
        for k in range(2)])
    seeds = jnp.asarray([3, 4], jnp.uint32)
    vis = lt.run_fused_lt_block(g, cb, starts, seeds, 64)
    for i in range(2):
        ref = lt.run_fused_lt(g, starts[i], 64, seeds[i])
        np.testing.assert_array_equal(np.asarray(vis[i]), np.asarray(ref))
