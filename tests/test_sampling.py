"""Unified Sampler API: spec validation, cross-backend bit-identity (dense
vs tiled vs kernel vs single-device graph_parallel, single process), LT
serving end-to-end, PoolConfig spec rules, and the manifest diffusion
guard.  (Multi-device data_parallel / graph_parallel need forced host
devices — covered by tests/serve_distributed_check.py.)"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import sampling
from repro.core import imm, lt, rrr
from repro.graph import csr, generators
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


@pytest.fixture(scope="module")
def graph():
    """Dedupe-clean graph: the tile layout (tiled/kernel backends) needs
    parallel edges merged, and bit-identity requires one shared edge list."""
    return csr.dedupe(
        generators.powerlaw_cluster(250, 6.0, prob=(0.1, 0.6), seed=23))


# ----------------------------------------------------------------- spec
def test_spec_rejects_unknown_fields_and_combos():
    with pytest.raises(ValueError):
        sampling.SamplerSpec(diffusion="sir")
    with pytest.raises(ValueError):
        sampling.SamplerSpec(backend="warp")
    # The support matrix is complete: every (diffusion, backend) cell has
    # an implementation (LT's Pallas cell is `kernels.lt_select_expand`).
    for backend in ("dense", "tiled", "kernel", "data_parallel",
                    "graph_parallel"):
        for diffusion in ("ic", "lt"):
            assert sampling.supported(diffusion, backend)
    sampling.SamplerSpec(diffusion="lt", backend="kernel")  # constructs
    # graph_parallel needs distinct batch and row axes
    with pytest.raises(ValueError, match="DISTINCT"):
        sampling.SamplerSpec(backend="graph_parallel", mesh_axis="x",
                             model_axis="x")


def test_spec_is_hashable_and_manifest_round_trips():
    spec = sampling.SamplerSpec(diffusion="lt", num_colors=96, master_seed=4)
    assert hash(spec) == hash(dataclasses.replace(spec))
    assert sampling.SamplerSpec.from_manifest(spec.to_manifest()) == spec
    # forward compat: unknown manifest keys are ignored
    d = spec.to_manifest() | {"future_knob": 1}
    assert sampling.SamplerSpec.from_manifest(d) == spec


def test_spec_from_sample_kw_warns_and_converts(graph):
    with pytest.warns(DeprecationWarning):
        spec = sampling.spec_from_sample_kw(
            {"model": "lt", "max_levels": 32, "sort_starts": True},
            num_colors=32, master_seed=9)
    assert spec == sampling.SamplerSpec(
        diffusion="lt", backend="dense", num_colors=32, master_seed=9,
        max_iters=32, sort_starts=True)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown sample_kw"):
            sampling.spec_from_sample_kw({"bogus": 1})


# --------------------------------------------- cross-backend bit identity
def test_dense_tiled_kernel_bit_identical(graph):
    """Same (master_seed, batch_index) ⇒ identical RRRBatch.visited on
    every backend (the facade's core contract)."""
    specs = {b: sampling.SamplerSpec(backend=b, num_colors=64, master_seed=5)
             for b in ("dense", "tiled", "kernel")}
    samplers = {b: sampling.make_sampler(graph, s) for b, s in specs.items()}
    for bi in (0, 3):
        ref = samplers["dense"].sample(bi)
        assert ref.batch_index == bi
        for b in ("tiled", "kernel"):
            got = samplers[b].sample(bi)
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(ref.visited))
            np.testing.assert_array_equal(got.roots, ref.roots)


def test_sampler_matches_legacy_sample_batch(graph):
    s = sampling.make_sampler(graph, sampling.SamplerSpec(num_colors=64,
                                                          master_seed=11))
    ref = rrr.sample_batch(csr.transpose(graph), 64, 11, 2)
    got = s.sample(2)
    np.testing.assert_array_equal(np.asarray(got.visited),
                                  np.asarray(ref.visited))


def test_lt_sampler_normalizes_weights_itself(graph):
    """The facade owns LT normalization: a raw IC-weighted graph and a
    pre-normalized one sample identically (normalization is idempotent)."""
    spec = sampling.SamplerSpec(diffusion="lt", num_colors=64, master_seed=7)
    raw = sampling.make_sampler(graph, spec)
    pre = sampling.make_sampler(
        graph, spec, g_rev=lt.normalize_lt_weights(csr.transpose(graph)))
    np.testing.assert_array_equal(np.asarray(raw.sample(1).visited),
                                  np.asarray(pre.sample(1).visited))


def test_tiled_backend_rejects_parallel_edges():
    src = np.array([0, 0, 1]); dst = np.array([1, 1, 2])
    g = csr.from_edges(src, dst, np.full(3, 0.5, np.float32), 3)
    with pytest.raises(ValueError, match="dedupe"):
        sampling.make_sampler(g, sampling.SamplerSpec(backend="tiled"))


def test_lt_tiled_bit_identical_to_dense(graph):
    """The ("lt", "tiled") matrix cell: tile expansion under the fixed
    live-edge selection reproduces the dense LT sweep bit for bit."""
    spec = sampling.SamplerSpec(diffusion="lt", num_colors=64, master_seed=5)
    dense = sampling.make_sampler(graph, spec)
    tiled = sampling.make_sampler(graph, spec.replace(backend="tiled"))
    for bi in (0, 3):
        ref = dense.sample(bi)
        got = tiled.sample(bi)
        assert got.batch_index == bi
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(ref.visited))
        np.testing.assert_array_equal(got.roots, np.asarray(ref.roots))


def test_lt_kernel_bit_identical_to_dense(graph):
    """The ("lt", "kernel") matrix cell — the Pallas `lt_select_expand`
    kernel (interpret mode on CPU) reproduces the dense LT sweep bit for
    bit, on the dense grid AND the compacted sparse grid, and the sparse
    grid runs strictly fewer grid steps.  tile_size=16 gives the ladder
    enough tiles (255) that compaction has headroom on this 250-vertex
    fixture."""
    spec = sampling.SamplerSpec(diffusion="lt", backend="kernel",
                                num_colors=64, master_seed=5, tile_size=16)
    dense_ref = sampling.make_sampler(graph, spec.replace(backend="dense"))
    kern = sampling.make_sampler(graph, spec)
    kern_sparse = sampling.make_sampler(graph,
                                        spec.replace(frontier="sparse"))
    for bi in (0, 3):
        ref = dense_ref.sample(bi)
        got = kern.sample(bi)
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(ref.visited))
        dense_steps = kern.last_grid_steps
        assert dense_steps == kern.last_levels * kern.tg_rev.num_tiles
        got_sp = kern_sparse.sample(bi)
        np.testing.assert_array_equal(np.asarray(got_sp.visited),
                                      np.asarray(ref.visited))
        assert 0 < kern_sparse.last_grid_steps < dense_steps


def test_graph_parallel_bit_identical_on_trivial_mesh(graph):
    """The whole row-partitioned block program (frontier all-gather,
    psum-agreed termination, 2-D batch × row sharding) on a 1×1 mesh —
    runnable in the single-device suite — must equal dense exactly."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for diffusion in ("ic", "lt"):
        spec = sampling.SamplerSpec(diffusion=diffusion,
                                    backend="graph_parallel",
                                    num_colors=64, master_seed=9)
        gp = sampling.make_sampler(graph, spec, mesh=mesh)
        dense = sampling.make_sampler(graph, spec.replace(backend="dense"))
        got = gp.sample_many([0, 2])
        for b in got:
            ref = dense.sample(b.batch_index)
            np.testing.assert_array_equal(np.asarray(b.visited),
                                          np.asarray(ref.visited))
            np.testing.assert_array_equal(b.roots, np.asarray(ref.roots))
    stacked = gp.sample_stacked([1])
    assert stacked.shape == (1, graph.num_vertices, 2)


def test_mesh_backends_require_mesh_and_axes(graph):
    with pytest.raises(ValueError, match="mesh"):
        sampling.make_sampler(
            graph, sampling.SamplerSpec(backend="data_parallel"))
    with pytest.raises(ValueError, match="mesh"):
        sampling.make_sampler(
            graph, sampling.SamplerSpec(backend="graph_parallel"))
    # graph_parallel refuses a mesh without the row-partition axis
    with pytest.raises(ValueError, match="model"):
        sampling.make_sampler(
            graph, sampling.SamplerSpec(backend="graph_parallel"),
            mesh=jax.make_mesh((1,), ("data",)))


# -------------------------------------------------- sparse frontier mode
def test_sparse_frontier_bit_identical_across_matrix(graph):
    """frontier="sparse" must be BIT-identical to the dense path on every
    single-process cell of the (diffusion × backend) matrix — compaction
    changes what gets computed, never what comes out."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for diffusion in ("ic", "lt"):
        backends = ["dense", "tiled", "kernel"]
        ref = sampling.make_sampler(graph, sampling.SamplerSpec(
            diffusion=diffusion, num_colors=64, master_seed=5))
        for backend in backends + ["graph_parallel"]:
            spec = sampling.SamplerSpec(
                diffusion=diffusion, backend=backend, num_colors=64,
                master_seed=5, frontier="sparse")
            m = mesh if backend == "graph_parallel" else None
            s = sampling.make_sampler(graph, spec, mesh=m)
            for bi in (0, 2):
                got = s.sample(bi)
                want = ref.sample(bi)
                np.testing.assert_array_equal(np.asarray(got.visited),
                                              np.asarray(want.visited))
                np.testing.assert_array_equal(got.roots,
                                              np.asarray(want.roots))


def test_sparse_frontier_work_counters_equal_dense(graph):
    """The deterministic work-proportionality contract: sparse counts
    exactly the edges the dense sweep counts (an edge is visited iff its
    source row carries an active color — all of which live in gathered
    tiles), for single batches AND fused sample_many blocks."""
    spec = sampling.SamplerSpec(num_colors=64, master_seed=5)
    dense = sampling.make_sampler(graph, spec)
    sparse_ = sampling.make_sampler(graph, spec.replace(frontier="sparse"))
    for a, b in zip(dense.sample_many([0, 1, 2]),
                    sparse_.sample_many([0, 1, 2])):
        assert a.fused_edge_visits == b.fused_edge_visits > 0
        assert a.unfused_edge_visits == b.unfused_edge_visits
        one = sparse_.sample(a.batch_index)       # single-batch path too
        assert one.fused_edge_visits == a.fused_edge_visits


def test_sparse_frontier_dead_frontier_and_all_active():
    """Edge cases: a graph whose frontier dies immediately (every edge
    prob 0 — level 1 is empty) and one where every tile is active by
    level 1 (complete-ish, prob ~1 — compaction runs at the ladder's top
    rung)."""
    n = 40
    src, dst = np.nonzero(~np.eye(n, dtype=bool))
    for prob in (0.0, 0.999):
        g = csr.from_edges(src, dst, np.full(len(src), prob, np.float32),
                           n, dedupe=True)
        for diffusion in ("ic", "lt"):
            for backend in ("dense", "tiled", "kernel"):
                spec = sampling.SamplerSpec(
                    diffusion=diffusion, backend=backend, num_colors=64,
                    master_seed=3, tile_size=8)
                ref = sampling.make_sampler(g, spec).sample(0)
                got = sampling.make_sampler(
                    g, spec.replace(frontier="sparse")).sample(0)
                np.testing.assert_array_equal(np.asarray(got.visited),
                                              np.asarray(ref.visited))
            if prob == 0.0 and diffusion == "ic":
                # only the start colors survive
                assert np.count_nonzero(np.asarray(ref.visited)) <= 64


def test_sparse_frontier_capacity_bucket_boundaries(graph):
    """Every ladder shape — a 1-wide bottom rung, a two-rung explicit
    capacity, the degenerate single top rung — must reproduce dense bits
    AND stats exactly (the top rung always fits, so correctness never
    depends on the knob)."""
    from repro.core import sparse, traversal, rrr
    g_rev = csr.transpose(graph)
    fidx = sparse.build_frontier_index(g_rev, tile_rows=64)
    starts = rrr.batch_starts(graph.num_vertices, 64, 5, 0)
    seed = rrr.batch_seed(5, 0)
    ref = traversal.run_fused(g_rev, starts, 64, seed)
    nb = fidx.num_blocks
    for ladder in ((1, nb), (2, 16, nb), (nb,),
                   sparse.bucket_ladder(nb, capacity=7)):
        res = sparse.run_fused_sparse(fidx, starts, 64, seed, ladder=ladder)
        np.testing.assert_array_equal(np.asarray(res.visited),
                                      np.asarray(ref.visited))
        np.testing.assert_array_equal(
            np.asarray(res.stats.fused_edge_visits),
            np.asarray(ref.stats.fused_edge_visits))
        np.testing.assert_array_equal(
            np.asarray(res.stats.unfused_edge_visits),
            np.asarray(ref.stats.unfused_edge_visits))
        assert int(res.stats.levels_run) == int(ref.stats.levels_run)


def test_sparse_frontier_padded_edge_blocks_inert(graph):
    """Block padding (edge_block ∤ per-row-block edge counts) and the
    appended null block must never contribute: a tiny edge_block maximizes
    padding, and the visited mask still matches dense bit for bit."""
    from repro.core import sparse, traversal, rrr
    g_rev = csr.transpose(graph)
    fidx = sparse.build_frontier_index(g_rev, tile_rows=32, edge_block=16)
    assert int(np.asarray(fidx.blk_valid).sum()) == g_rev.padded_edges
    assert not np.asarray(fidx.blk_valid[-1]).any()      # null block inert
    starts = rrr.batch_starts(graph.num_vertices, 64, 5, 1)
    seed = rrr.batch_seed(5, 1)
    res = sparse.run_fused_sparse(fidx, starts, 64, seed)
    ref = traversal.run_fused(g_rev, starts, 64, seed)
    np.testing.assert_array_equal(np.asarray(res.visited),
                                  np.asarray(ref.visited))


def test_spec_validates_frontier_knobs():
    with pytest.raises(ValueError, match="frontier"):
        sampling.SamplerSpec(frontier="compact")
    with pytest.raises(ValueError, match="frontier_capacity"):
        sampling.SamplerSpec(frontier_capacity=-1)
    spec = sampling.SamplerSpec(frontier="sparse", frontier_capacity=128)
    assert sampling.SamplerSpec.from_manifest(spec.to_manifest()) == spec


# ------------------------------------------------------------ PoolConfig
def test_pool_config_resolves_default_spec():
    cfg = PoolConfig(num_colors=32, master_seed=6)
    assert cfg.spec == sampling.SamplerSpec(num_colors=32, master_seed=6)
    assert hash(cfg) == hash(PoolConfig(num_colors=32, master_seed=6))


def test_pool_config_spec_wins_and_conflicts_raise():
    spec = sampling.SamplerSpec(num_colors=128, master_seed=3)
    cfg = PoolConfig(spec=spec)                 # defaults adopt the spec
    assert cfg.num_colors == 128 and cfg.master_seed == 3
    with pytest.raises(ValueError, match="conflicts"):
        PoolConfig(num_colors=64, master_seed=9, spec=spec)


def test_pool_config_sample_kw_shim_is_gone():
    """The deprecated ``sample_kw`` InitVar (warned since the Sampler-API
    PR) is removed — a typed spec is the only way to configure sampling."""
    with pytest.raises(TypeError, match="sample_kw"):
        PoolConfig(num_colors=64, master_seed=2, sample_kw={"model": "lt"})


def test_pool_config_instances_share_no_mutable_state():
    """The old frozen-dataclass-with-dict-default bug: two default configs
    must not alias a mutable field (the spec is frozen and hashable now)."""
    a, b = PoolConfig(), PoolConfig()
    assert a == b and a.spec == b.spec
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.spec = None
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.spec.num_colors = 1


# -------------------------------------------- LT serving smoke end-to-end
def test_lt_pool_serves_topk_end_to_end(graph):
    cfg = PoolConfig(max_batches=64,
                     spec=sampling.SamplerSpec(diffusion="lt", num_colors=64,
                                               master_seed=13))
    store = SketchStore(graph, cfg)
    store.ensure(6)
    engine = QueryEngine(store)
    batcher = MicroBatcher(engine, cache=ResultCache())
    t = batcher.submit_top_k(4)
    seeds, sigma = batcher.flush()[t]
    assert len(set(seeds.tolist())) == 4 and sigma > 0
    # LT seeds must agree with greedy max-cover over the same LT pool
    ref, cov = imm.greedy_max_cover(store.visited_stack(), 4, 64)
    np.testing.assert_array_equal(seeds, ref)
    # and run_imm under the same spec routes through the pool identically
    fresh = SketchStore(graph, cfg)
    res = imm.run_imm(graph, k=4, eps=0.5, spec=cfg.spec, theta_cap=512,
                      pool=fresh)
    plain = imm.run_imm(graph, k=4, eps=0.5, spec=cfg.spec, theta_cap=512)
    np.testing.assert_array_equal(res.seeds, plain.seeds)


def test_run_imm_legacy_sample_kw_warns(graph):
    with pytest.warns(DeprecationWarning):
        res = imm.run_imm(graph, k=2, eps=0.5, num_colors=64, master_seed=1,
                          theta_cap=256, sort_starts=True)
    assert len(res.seeds) == 2


# -------------------------------------------------- manifest spec guard
def test_restore_refuses_diffusion_mismatch(graph, tmp_path):
    """An IC-sampled pool must never silently serve as LT (or vice versa)."""
    ic_cfg = PoolConfig(num_colors=64, master_seed=8)
    store = SketchStore(graph, ic_cfg)
    store.ensure(2)
    store.save(str(tmp_path))
    lt_cfg = PoolConfig(
        spec=sampling.SamplerSpec(diffusion="lt", num_colors=64,
                                  master_seed=8))
    with pytest.raises(ValueError, match="diffusion"):
        SketchStore.restore(str(tmp_path), graph, lt_cfg)
    # matching spec restores bit-identically and keeps the spec
    r = SketchStore.restore(str(tmp_path), graph, ic_cfg)
    assert r.spec == store.spec
    np.testing.assert_array_equal(np.asarray(store.visited_stack()),
                                  np.asarray(r.visited_stack()))


def test_manifest_records_sampler_spec(graph, tmp_path):
    from repro.checkpoint import manager
    spec = sampling.SamplerSpec(diffusion="lt", num_colors=64, master_seed=1)
    store = SketchStore(graph, PoolConfig(spec=spec))
    store.ensure(1)
    store.save(str(tmp_path))
    extra = manager.read_manifest(str(tmp_path)).get("extra", {})
    assert sampling.SamplerSpec.from_manifest(extra["sampler_spec"]) == spec
