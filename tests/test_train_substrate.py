"""Optimizer, compression, data pipeline, checkpoint, serving engine."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import manager as ckpt
from repro.configs import registry
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import model
from repro.optim import adamw, compress
from repro.serve import engine
from repro.train.step import make_train_step


# ------------------------------------------------------------------- AdamW
def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw.init(params)
    lr_fn = lambda s: 0.1
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, opt, _ = adamw.update(params, grads, opt, lr=0.1,
                                      weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip():
    grads = {"a": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    lr = adamw.cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3)
    assert float(lr(100)) == pytest.approx(1e-4, rel=0.01)
    assert float(lr(55)) < float(lr(20))


# ------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3
    q, scale = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_compressed_psum_matches_exact_within_quantization():
    """Run under shard_map on a 1-device mesh (semantics identical)."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jax.random.normal(jax.random.key(1), (256,))}

    def body(gr):
        mean, res = compress.compressed_psum(gr, "data")
        return mean, res

    from jax.sharding import PartitionSpec as P
    from repro.distributed.compat import shard_map
    mean, res = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P()),
        check=False))(g)
    np.testing.assert_allclose(np.asarray(mean["w"] + res["w"]),
                               np.asarray(g["w"]), atol=1e-6)
    # error feedback residual is bounded by half a quantization level
    _, scale = compress.quantize(g["w"])
    assert float(jnp.abs(res["w"]).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges():
    """SGD + int8 compression + error feedback still drives a quadratic to
    zero (compression alone would stall at the quantization floor)."""
    w = jnp.asarray([2.0, -1.5])
    err = jnp.zeros_like(w)
    for _ in range(300):
        g = 2 * w
        q, scale = compress.quantize(g + err)
        g_hat = compress.dequantize(q, scale)
        err = (g + err) - g_hat
        w = w - 0.05 * g_hat
    assert float(jnp.abs(w).max()) < 1e-2


# ------------------------------------------------------------------- data
def test_pipeline_deterministic_by_step():
    cfg = registry.smoke("llama3.2-3b")
    d1 = SyntheticLM(cfg, 4, 32, seed=7)
    d2 = SyntheticLM(cfg, 4, 32, seed=7)
    b1, b2 = d1.batch_at(13), d2.batch_at(13)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], d1.batch_at(14)["tokens"])


def test_pipeline_labels_shifted():
    cfg = registry.smoke("llama3.2-3b")
    b = SyntheticLM(cfg, 2, 16, seed=0).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_prefetcher_order_and_resume():
    cfg = registry.smoke("llama3.2-3b")
    src = SyntheticLM(cfg, 2, 16, seed=3)
    pf = Prefetcher(src, start_step=5)
    try:
        for expect in (5, 6, 7):
            step, batch = pf.get()
            assert step == expect
            np.testing.assert_array_equal(batch["tokens"],
                                          src.batch_at(expect)["tokens"])
    finally:
        pf.close()


# -------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7)]}
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_keeps_last_k(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2


def test_checkpoint_async(tmp_path):
    tree = {"x": jnp.arange(10)}
    t = ckpt.save(str(tmp_path), 1, tree, blocking=False)
    t.join()
    restored, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))


def test_checkpoint_ignores_partial_tmp(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")      # simulated dead writer
    assert ckpt.latest_step(str(tmp_path)) == 1


# ------------------------------------------------------------- train step
def test_train_step_reduces_loss():
    cfg = registry.smoke("llama3.2-3b")
    params = model.init_params(jax.random.key(0), cfg)
    opt = adamw.init(params)
    data = SyntheticLM(cfg, 8, 32, seed=1)
    step_fn = jax.jit(make_train_step(cfg, lambda s: 1e-3))
    first = last = None
    for step in range(30):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        params, opt, m = step_fn(params, opt, b)
        if step == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.5, (first, last)


def test_microbatched_grads_match_full_batch():
    cfg = registry.smoke("llama3.2-3b")
    params = model.init_params(jax.random.key(0), cfg)
    data = SyntheticLM(cfg, 8, 32, seed=2)
    b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    opt = adamw.init(params)
    p1, _, m1 = jax.jit(make_train_step(cfg, lambda s: 1e-3, 1))(params, opt, b)
    p2, _, m2 = jax.jit(make_train_step(cfg, lambda s: 1e-3, 4))(params, opt, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=2e-5)


# ---------------------------------------------------------------- serving
@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-1.3b",
                                  "zamba2-2.7b", "musicgen-medium"])
def test_generate_greedy_matches_teacher_forced(arch):
    """prefill+decode generation equals argmax over the forward logits when
    re-scoring the generated sequence (cache correctness end-to-end)."""
    import dataclasses
    cfg = dataclasses.replace(registry.smoke(arch), capacity_factor=8.0)
    params = model.init_params(jax.random.key(0), cfg)
    B, Lp, n_new = 2, 8, 4
    rng = np.random.default_rng(0)
    shape = ((B, cfg.num_codebooks, Lp) if cfg.num_codebooks else (B, Lp))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, shape))
    out = engine.generate(params, cfg, prompt, n_new, temperature=0.0)
    full = jnp.concatenate([prompt, out], -1 if cfg.num_codebooks else 1)
    logits, _, _ = model.forward(params, cfg,
                                 {"tokens": full, "labels": full})
    # position Lp-1+i predicts generated token i
    for i in range(n_new):
        pred = jnp.argmax(logits[:, Lp - 1 + i], -1)
        got = out[..., i] if cfg.num_codebooks else out[:, i]
        if cfg.num_codebooks:
            np.testing.assert_array_equal(np.asarray(pred),
                                          np.asarray(got))
        else:
            np.testing.assert_array_equal(np.asarray(pred), np.asarray(got))
