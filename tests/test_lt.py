"""Linear Threshold diffusion: live-edge selection invariants + fused LT
traversal behaviour + Table-1 dataset clones."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitmask, lt, traversal
from repro.graph import csr, datasets, generators


@pytest.fixture(scope="module")
def g_lt():
    g = generators.powerlaw_cluster(300, 6.0, prob=(0.2, 1.0), seed=6)
    return lt.normalize_lt_weights(g)


def test_normalize_in_weights_leq_one(g_lt):
    e = g_lt.num_edges
    dst = np.asarray(g_lt.dst)[:e]
    prob = np.asarray(g_lt.prob)[:e].astype(np.float64)
    sums = np.zeros(g_lt.num_vertices)
    np.add.at(sums, dst, prob)
    assert sums.max() <= 1.0 + 1e-5


def test_selection_at_most_one_in_edge_per_color(g_lt):
    """THE LT invariant: every (vertex, color) selects ≤ 1 incoming edge."""
    sel = lt._selection_mask(g_lt, 64, jnp.uint32(3))
    e = g_lt.num_edges
    dst = np.asarray(g_lt.dst)[:e]
    bits = np.asarray(bitmask.unpack_bits(sel[:e]))       # (E, W, 32)
    per_color = bits.reshape(e, -1)                       # (E, C)
    counts = np.zeros((g_lt.num_vertices, per_color.shape[1]), np.int32)
    np.add.at(counts, dst, per_color.astype(np.int32))
    assert counts.max() <= 1


def test_selection_rate_matches_weight(g_lt):
    """P(edge selected) == its LT weight (over many colors)."""
    C = 512
    sel = lt._selection_mask(g_lt, C, jnp.uint32(11))
    e = g_lt.num_edges
    rate = np.asarray(bitmask.count_colors(sel[:e])) / C
    prob = np.asarray(g_lt.prob)[:e]
    heavy = prob > 0.2
    assert heavy.sum() > 10
    np.testing.assert_allclose(rate[heavy], prob[heavy], atol=0.08)


def test_run_fused_lt_reaches_starts_and_is_deterministic(g_lt):
    starts = traversal.random_starts(jax.random.key(0),
                                     g_lt.num_vertices, 32)
    v1 = lt.run_fused_lt(g_lt, starts, 32, 9)
    v2 = lt.run_fused_lt(g_lt, starts, 32, 9)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    vis = np.asarray(v1)
    for c, s in enumerate(np.asarray(starts)):
        assert vis[s, c // 32] >> (c % 32) & 1
    # selections are fixed per traversal: a different seed changes them
    v3 = lt.run_fused_lt(g_lt, starts, 32, 10)
    assert not np.array_equal(np.asarray(v1), np.asarray(v3))


def test_fused_lt_matches_naive_bfs_over_selected_edges(g_lt):
    """Gold test: fused LT traversal ≡ per-color BFS over exactly the
    live edges the selection mask chose (deterministic oracle)."""
    C = 32
    starts = traversal.random_starts(jax.random.key(2),
                                     g_lt.num_vertices, C)
    seed = jnp.uint32(5)
    vis = np.asarray(lt.run_fused_lt(g_lt, starts, C, 5))
    sel = np.asarray(lt._selection_mask(g_lt, C, seed))
    e = g_lt.num_edges
    src = np.asarray(g_lt.src)[:e]
    dst = np.asarray(g_lt.dst)[:e]
    for c in range(C):
        live = (sel[:e, c // 32] >> (c % 32)) & 1
        adj = {}
        for s, d, l in zip(src, dst, live):
            if l:
                adj.setdefault(int(s), []).append(int(d))
        seen, stack = {int(starts[c])}, [int(starts[c])]
        while stack:
            v = stack.pop()
            for u in adj.get(v, []):
                if u not in seen:
                    seen.add(u)
                    stack.append(u)
        got = {int(v) for v in
               np.flatnonzero((vis[:, c // 32] >> (c % 32)) & 1)}
        assert got == seen, f"color {c}"


# ------------------------------------------------------------------ datasets
def test_table1_clone_sizes():
    g = datasets.table1_clone("web-Google", scale=0.01)
    assert abs(g.num_vertices - 8757) < 200
    deg = g.num_edges / g.num_vertices
    assert 5 < deg < 25      # clone tracks the table's avg degree loosely


def test_table1_unknown_raises():
    with pytest.raises(KeyError):
        datasets.table1_clone("not-a-graph")


def test_load_snap_roundtrip(tmp_path):
    p = tmp_path / "tiny.txt"
    p.write_text("# comment\n0 1\n1 2\n2 0\n")
    g = datasets.load_snap(str(p))
    assert g.num_vertices == 3 and g.num_edges == 3


# --------------------------------------------------------------- LT in IMM
def test_imm_pipeline_under_lt(g_lt):
    """RRR sampling + greedy max-cover run end-to-end under LT; the chosen
    seeds beat random seeds on a fresh LT collection."""
    from repro.core import imm, rrr
    g_rev = csr.transpose(g_lt)
    g_rev = lt.normalize_lt_weights(g_rev)
    batches = [rrr.sample_batch(g_rev, 64, 3, b, model="lt")
               for b in range(16)]
    visited = rrr.stack_visited(batches)
    seeds, cov = imm.greedy_max_cover(visited, 4, 64)
    assert 0 < cov <= 1 and len(set(seeds.tolist())) == 4
    fresh = rrr.stack_visited(
        [rrr.sample_batch(g_rev, 64, 99, b, model="lt") for b in range(16)])
    rng0 = np.random.default_rng(1)
    rand_cov = np.mean([imm.coverage_of(
        fresh, rng0.integers(0, g_lt.num_vertices, 4), 64)
        for _ in range(8)])
    assert imm.coverage_of(fresh, seeds, 64) > rand_cov
