"""Production serving tier: token-bucket admission, replica routing with
epoch-consistency, signal-driven autoscaling, metrics, and the end-to-end
acceptance path (sheds + bit-identity vs a direct engine + mid-stream
refresh guard)."""
import concurrent.futures
import itertools
import math
import threading
import time

import numpy as np
import pytest

from repro.core import imm
from repro.graph import generators
from repro.serve.influence import PoolConfig, QueryEngine, SketchStore
from repro.serve.tier import (AdmissionController, AutoScaler, EpochMixError,
                              Histogram, MetricSet, ReplicaGroup, ServingTier,
                              ShedError)


@pytest.fixture(scope="module")
def graph():
    return generators.powerlaw_cluster(180, 5.0, prob=0.25, seed=23)


def make_store(graph, batches=4, max_batches=16):
    s = SketchStore(graph, PoolConfig(num_colors=64, max_batches=max_batches,
                                      master_seed=11))
    s.ensure(batches)
    return s


# ------------------------------------------------------------- admission
class FakeClock:
    def __init__(self):
        self.t = 1000.0

    def __call__(self):
        return self.t


def test_quota_burst_then_shed_with_honest_retry_after():
    clock = FakeClock()
    adm = AdmissionController(rate=2.0, burst=3, clock=clock)
    for _ in range(3):                        # full burst admits
        adm.admit("t")
    with pytest.raises(ShedError) as ei:
        adm.admit("t")
    # empty bucket, rate 2/s, cost 1 ⇒ retry in 0.5s exactly
    assert ei.value.retry_after == pytest.approx(0.5)
    assert ei.value.tenant == "t"
    # shed must not take partial tokens: waiting retry_after then succeeds
    clock.t += ei.value.retry_after
    adm.admit("t")


def test_quota_refill_caps_at_burst():
    clock = FakeClock()
    adm = AdmissionController(rate=10.0, burst=2, clock=clock)
    adm.admit("t"), adm.admit("t")
    clock.t += 3600                           # idle an hour: still only burst
    adm.admit("t"), adm.admit("t")
    with pytest.raises(ShedError):
        adm.admit("t")


def test_quota_per_tenant_isolation_and_unmetered():
    clock = FakeClock()
    adm = AdmissionController(rate=1.0, burst=1, clock=clock)
    adm.set_quota("vip", rate=None)           # unmetered override
    adm.admit("a")
    with pytest.raises(ShedError):
        adm.admit("a")                        # a is dry...
    adm.admit("b")                            # ...b's bucket is untouched
    for _ in range(100):
        adm.admit("vip")                      # unmetered never sheds
    assert adm.quota("vip") is None
    assert adm.quota("a") == (1.0, 1.0)


def test_quota_cost_over_burst_sheds_non_retriably():
    """A cost above burst can never be admitted (tokens cap at burst), so
    its ShedError must carry retry_after=inf — not a finite hint that
    would make a well-behaved client retry forever."""
    clock = FakeClock()
    adm = AdmissionController(rate=2.0, burst=3, clock=clock)
    with pytest.raises(ShedError) as ei:
        adm.admit("t", cost=5.0)
    assert math.isinf(ei.value.retry_after)
    assert "do not retry" in str(ei.value)
    for _ in range(3):                        # the bucket was left untouched
        adm.admit("t")


def test_quota_dotted_tenant_ids_stay_in_totals():
    """A tenant id containing '.' must not nest deeper in the metrics tree
    (that would silently drop it from the tier's admitted/shed totals)."""
    clock, m = FakeClock(), MetricSet()
    adm = AdmissionController(rate=1.0, burst=1, clock=clock, metrics=m)
    adm.admit("org.acme")
    with pytest.raises(ShedError):
        adm.admit("org.acme")
    snap = m.snapshot()
    assert snap["tenant"]["org%2Eacme"] == {"admitted": 1, "shed": 1}
    # escaping is injective: a tenant literally named "org%2Eacme" cannot
    # collide with the escaped form of "org.acme"
    from repro.serve.tier.metrics import escape_label
    assert escape_label("org.acme") != escape_label("org%2Eacme")


def test_quota_counts_into_metrics():
    clock, m = FakeClock(), MetricSet()
    adm = AdmissionController(rate=1.0, burst=1, clock=clock, metrics=m)
    adm.admit("t")
    with pytest.raises(ShedError):
        adm.admit("t")
    snap = m.snapshot()
    assert snap["tenant"]["t"] == {"admitted": 1, "shed": 1}


# --------------------------------------------------------------- metrics
def test_histogram_quantiles_from_bucket_cdf():
    h = Histogram(bounds=[0.001, 0.01, 0.1, 1.0])
    for v in [0.0005] * 50 + [0.05] * 49 + [5.0]:
        h.record(v)
    assert h.quantile(0.50) == pytest.approx(0.001)   # bucket upper bound
    assert h.quantile(0.99) == pytest.approx(0.1)
    assert h.quantile(0.999) == pytest.approx(5.0)    # overflow → observed max
    snap = h.snapshot()
    assert snap["count"] == 100 and snap["max"] == pytest.approx(5.0)
    assert set(snap) == {"count", "mean", "max", "p50", "p99", "p999"}


def test_histogram_empty_and_threaded_counter():
    assert Histogram().quantile(0.99) == 0.0
    m = MetricSet()
    c = m.counter("x.y")

    def hammer():
        for _ in range(1000):
            c.add()

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert m.snapshot()["x"]["y"] == 8000
    assert m.counter("x.y") is c              # created once, stable identity


# ------------------------------------------------------- imm bound inverse
def test_eps_bound_inverts_estimate_theta():
    """eps_bound_for_theta is the exact inverse of the λ*/LB bound driving
    estimate_theta: feeding the θ that a given ε demands must return ε."""
    n, k, eps = 2000, 8, 0.3
    lam = imm._lam_star_coeff(n, k, imm._adjusted_ell(n, 1.0)) / eps ** 2
    theta = int(np.ceil(lam / 1.0))           # opt_lb = 1
    got = imm.eps_bound_for_theta(n, k, theta)
    assert got == pytest.approx(eps, rel=0.02)
    # monotone: more samples / bigger OPT ⇒ tighter bound
    assert imm.eps_bound_for_theta(n, k, 4 * theta) == pytest.approx(
        eps / 2, rel=0.02)
    assert imm.eps_bound_for_theta(n, k, theta, opt_lb=4.0) < got


# ----------------------------------------------------------- clone/shrink
def test_store_clone_shares_pool_bit_identically(graph):
    store = make_store(graph)
    twin = store.clone()
    np.testing.assert_array_equal(np.asarray(store.visited_stack()),
                                  np.asarray(twin.visited_stack()))
    assert twin.version == store.version
    # identical mutation sequences keep the twins converged
    store.refresh(0.5), twin.refresh(0.5)
    np.testing.assert_array_equal(np.asarray(store.visited_stack()),
                                  np.asarray(twin.visited_stack()))
    assert twin.version == store.version


def test_store_shrink_keeps_slot_prefix(graph):
    store = make_store(graph)
    before = np.asarray(store.visited_stack())
    dropped = store.shrink(2)
    assert dropped == [2, 3] and len(store.batches) == 2
    np.testing.assert_array_equal(np.asarray(store.visited_stack()),
                                  before[:2])
    store.ensure(4)                           # regrow extends, same prefix
    np.testing.assert_array_equal(
        np.asarray(store.visited_stack())[:2], before[:2])


def test_store_shrink_then_grow_never_reissues_a_version(graph):
    """Version A-B-A guard: shrink bumps the epoch, so growing back to a
    previous count (which samples NEW rng streams into the re-added slots)
    can never reproduce a previously-issued (epoch, count) — epoch-keyed
    result caches must miss against the new pool contents."""
    store = make_store(graph)
    pre_shrink = store.version
    old_tail_index = store.batches[-1].batch_index
    seen = {pre_shrink}
    store.shrink(2)
    assert store.version not in seen
    seen.add(store.version)
    store.ensure(4)                           # the autoscaler's oscillation
    assert store.version not in seen, \
        "shrink→grow reissued a version: stale cache entries would hit"
    # the re-added slots really are a different sample population
    assert store.batches[-1].batch_index != old_tail_index


# ----------------------------------------------------------------- router
def _fake_future(value, version):
    import concurrent.futures
    f = concurrent.futures.Future()
    f.pool_version = version
    f.set_result(value)
    return f


def test_gather_refuses_mixed_epochs():
    ok = ReplicaGroup.gather([_fake_future(1.0, (0, 4)),
                              _fake_future(2.0, (0, 4))])
    assert ok == [1.0, 2.0]
    with pytest.raises(EpochMixError) as ei:
        ReplicaGroup.gather([_fake_future(1.0, (0, 4)),
                             _fake_future(2.0, (1, 4))])
    assert ei.value.versions == ((0, 4), (1, 4))


def test_gather_timeout_is_one_overall_deadline():
    """gather(timeout=T) bounds the WHOLE gather, not T per future — N
    never-resolving futures must time out in ~T, not N×T."""
    pending = [concurrent.futures.Future() for _ in range(4)]
    for f in pending:
        f.pool_version = (0, 4)
    t0 = time.monotonic()
    with pytest.raises(concurrent.futures.TimeoutError):
        ReplicaGroup.gather(pending, timeout=0.2)
    assert time.monotonic() - t0 < 0.6


def test_replica_group_policies_and_refresh_convergence(graph):
    store = make_store(graph)
    with ReplicaGroup.build(store, 3, policy="round_robin",
                            default_deadline=0.02) as group:
        assert [group.pick().index for _ in range(4)] == [0, 1, 2, 0]
        assert group.consistent()
        # one refresh sweep: replicas re-converge bit-identically at the
        # new epoch
        group.refresh(0.5)
        assert group.consistent()
        stacks = [np.asarray(r.store.visited_stack())
                  for r in group.replicas]
        for s in stacks[1:]:
            np.testing.assert_array_equal(stacks[0], s)
        # answers after the sweep match a fresh direct engine on replica 0
        fut = group.submit_sigma([1, 5, 9])
        want = QueryEngine(group.replicas[0].store).sigma([[1, 5, 9]])[0]
        assert group.gather([fut]) == [want]
    with pytest.raises(ValueError):
        ReplicaGroup.build(store, 1, policy="fastest")


def test_replica_group_scale_to_keeps_replicas_identical(graph):
    with ReplicaGroup.build(make_store(graph), 2,
                            default_deadline=0.02) as group:
        group.scale_to(7)
        assert group.num_batches == 7 and group.consistent()
        group.scale_to(3)
        assert group.num_batches == 3 and group.consistent()
        a, b = (np.asarray(r.store.visited_stack()) for r in group.replicas)
        np.testing.assert_array_equal(a, b)


def test_concurrent_refresh_and_scale_sweeps_keep_replicas_identical(graph):
    """The background refresh sweep and the autoscaler's scale sweep race
    from different threads; the group mutation lock must keep every
    replica on the same mutation sequence in the same ORDER.  Without it,
    replica 0 can apply refresh-then-ensure while replica 1 applies
    ensure-then-refresh — different rng streams land in different slots
    and the replicas diverge while still agreeing on version."""
    store = make_store(graph, batches=3, max_batches=32)
    with ReplicaGroup.build(store, 2, default_deadline=0.0) as group:
        start = threading.Barrier(2)
        sizes = itertools.cycle([4, 2, 5])
        errors = []

        def run(fn):
            try:
                start.wait(10)
                for _ in range(5):
                    fn()
            except Exception as e:            # pragma: no cover
                errors.append(e)

        threads = [
            threading.Thread(target=run, args=(lambda: group.refresh(0.5),)),
            threading.Thread(target=run,
                             args=(lambda: group.scale_to(next(sizes)),))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert not errors
        assert group.consistent()
        r0, r1 = group.replicas
        assert r0.store.next_batch_index == r1.store.next_batch_index
        assert [b.batch_index for b in r0.store.batches] == \
               [b.batch_index for b in r1.store.batches]
        np.testing.assert_array_equal(np.asarray(r0.store.visited_stack()),
                                      np.asarray(r1.store.visited_stack()))


# -------------------------------------------------------------- autoscaler
def test_autoscaler_grows_to_meet_eps_then_holds(graph):
    with ReplicaGroup.build(make_store(graph, batches=2), 2,
                            default_deadline=0.0) as group:
        scaler = AutoScaler(group, k=4, target_eps=0.4)
        d1 = scaler.step()
        assert d1.action == "grow" and d1.batches_after > d1.batches_before
        assert scaler.eps_bound() <= 0.4 + 1e-9
        assert group.consistent()
        d2 = scaler.step()
        assert d2.action == "hold"


def test_autoscaler_shrinks_on_slow_p99_with_eps_headroom(graph):
    hist = Histogram()
    for _ in range(200):
        hist.record(1.0)                      # fake p99 ≈ 1s, way over target
    with ReplicaGroup.build(make_store(graph, batches=6), 1,
                            default_deadline=0.0) as group:
        scaler = AutoScaler(group, k=4, target_eps=10.0,  # huge ⇒ headroom
                            target_p99_ms=50.0, latency_hist=hist)
        d = scaler.step()
        assert d.action == "shrink"
        assert d.batches_after == d.batches_before - 1
        assert group.num_batches == 5


def test_autoscaler_respects_max_batches(graph):
    with ReplicaGroup.build(make_store(graph, batches=2), 1,
                            default_deadline=0.0) as group:
        scaler = AutoScaler(group, k=4, target_eps=0.01, max_batches=3)
        d = scaler.step()
        assert d.batches_after == 3           # clamped, not the eps target
        d2 = scaler.step()
        assert d2.action == "hold" and "max_batches" in d2.reason


# ----------------------------------------------------------- end-to-end
def test_tier_end_to_end_sheds_and_serves_bit_identically(graph):
    """The acceptance path: 2 replicas, an over-quota tenant sheds with
    retry-after while in-quota tenants' answers are bit-identical to a
    direct single-engine QueryEngine over the same pool epoch."""
    store = make_store(graph)
    reference = QueryEngine(store.clone())
    with ServingTier.build(store, replicas=2, quota_qps=None,
                           default_deadline=0.01) as tier:
        tier.set_quota("starved", rate=0.1, burst=2)
        queries = [[i, i + 3, i + 11] for i in range(8)]
        futs, sheds = [], []
        for q in queries:
            futs.append((q, tier.submit_sigma("paid", q)))
        for q in queries:
            try:
                futs.append((q, tier.submit_sigma("starved", q)))
            except ShedError as e:
                sheds.append(e)
        assert sheds, "0.1 qps tenant must shed most of an 8-query burst"
        assert all(s.retry_after > 0 and s.tenant == "starved"
                   for s in sheds)
        values = tier.gather([f for _, f in futs])
        for (q, _), val in zip(futs, values):
            assert val == reference.sigma([q])[0], \
                "tier answer must be bit-identical to the direct engine"
        snap = tier.snapshot()
        assert snap["totals"]["shed"] == len(sheds)
        assert snap["totals"]["admitted"] == len(futs)
        assert 0 < snap["totals"]["shed_rate"] < 1
        assert snap["latency"]["all"]["count"] >= len(futs)
        assert snap["consistent"]
        assert sum(r["dispatches"] for r in snap["replicas"]) >= 1


def test_tier_mid_stream_refresh_never_mixes_epochs(graph):
    """A refresh landing between two gathered queries must surface as
    EpochMixError (or not land between them at all) — never as a silently
    mixed-population answer."""
    store = make_store(graph)
    with ServingTier.build(store, replicas=2, quota_qps=None, policy="round_robin",
                           default_deadline=0.01) as tier:
        before = tier.submit_sigma("a", [1, 2, 3])
        before.result(timeout=60)
        # refresh ONE replica: the group is now epoch-split on purpose
        tier.group.replicas[0].frontend.refresh_now(0.5)
        assert not tier.group.consistent()
        after = tier.submit_sigma("a", [4, 5, 6])
        after.result(timeout=60)
        if before.pool_version != after.pool_version:
            with pytest.raises(EpochMixError):
                tier.gather([before, after])
        # finish the sweep: the group re-converges and gathers pass again
        for r in tier.group.replicas[1:]:
            r.frontend.refresh_now(0.5)
        assert tier.group.consistent()
        f1 = tier.submit_sigma("a", [1, 2, 3])
        f2 = tier.submit_sigma("a", [4, 5, 6])
        assert len(tier.gather([f1, f2])) == 2


def test_tier_autoscale_step_keeps_group_consistent(graph):
    store = make_store(graph, batches=2)
    with ServingTier.build(store, replicas=2, quota_qps=None,
                           autoscale={"k": 4, "target_eps": 0.45},
                           default_deadline=0.0) as tier:
        d = tier.autoscaler.step()
        assert d.action == "grow" and tier.group.consistent()
        a, b = (np.asarray(r.store.visited_stack())
                for r in tier.group.replicas)
        np.testing.assert_array_equal(a, b)
        assert tier.snapshot()["autoscale_last"]["action"] == "grow"
