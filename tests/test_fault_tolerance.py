"""Fault-tolerance contracts: crash/restart determinism, straggler reissue,
idempotent re-execution, elastic worker pools."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.core import rrr
from repro.core.driver import SamplingDriver
from repro.graph import csr, generators
from repro.train import loop


@pytest.fixture(scope="module")
def g_rev():
    return csr.transpose(generators.powerlaw_cluster(300, 6.0, prob=0.3,
                                                     seed=4))


# ------------------------------------------------------------ sampling driver
def test_driver_no_faults_matches_serial(g_rev):
    drv = SamplingDriver(g_rev, 32, master_seed=5, num_workers=4)
    batches = drv.run(8)
    for b_idx, batch in enumerate(batches):
        ref = rrr.sample_batch(g_rev, 32, 5, b_idx)
        np.testing.assert_array_equal(np.asarray(batch.visited),
                                      np.asarray(ref.visited))
    assert drv.stats.completed == 8


def test_driver_survives_failures(g_rev):
    """30% injected failure rate: every batch still completes and the
    collection is bit-identical to the failure-free run (idempotence)."""
    drv = SamplingDriver(g_rev, 32, master_seed=5, num_workers=4,
                         failure_rate=0.3, max_attempts=20)
    batches = drv.run(8)
    assert drv.stats.failures > 0 and drv.stats.reissues > 0
    for b_idx, batch in enumerate(batches):
        ref = rrr.sample_batch(g_rev, 32, 5, b_idx)
        np.testing.assert_array_equal(np.asarray(batch.visited),
                                      np.asarray(ref.visited))


def test_driver_handles_stragglers(g_rev):
    drv = SamplingDriver(g_rev, 32, master_seed=5, num_workers=4,
                         slow_rate=0.3, slow_s=0.2)
    batches = drv.run(8)
    assert len(batches) == 8


def test_driver_elastic_worker_counts(g_rev):
    """Same results regardless of pool size (elastic scaling contract)."""
    a = SamplingDriver(g_rev, 32, master_seed=9, num_workers=1).run(4)
    b = SamplingDriver(g_rev, 32, master_seed=9, num_workers=8).run(4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x.visited),
                                      np.asarray(y.visited))


# --------------------------------------------------------- train crash/restart
def test_crash_restart_matches_uninterrupted(tmp_path):
    cfg = registry.smoke("llama3.2-3b")
    kw = dict(batch=4, seq_len=32, steps=12, ckpt_every=4, lr=1e-3,
              log_every=100, print_fn=lambda *a: None, async_ckpt=False)

    clean = loop.train(cfg, checkpoint_dir=str(tmp_path / "clean"), **kw)
    crashed = loop.train_with_restarts(
        cfg, checkpoint_dir=str(tmp_path / "crashy"),
        crash_schedule=(5, 9), **kw)
    assert crashed.resumed_from is not None
    import jax
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(crashed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_restart_resumes_data_cursor(tmp_path):
    """Losses after resume equal the tail of the uninterrupted run — proves
    the data cursor (== step) restores exactly."""
    cfg = registry.smoke("llama3.2-3b")
    kw = dict(batch=4, seq_len=32, steps=10, ckpt_every=2, lr=1e-3,
              log_every=100, print_fn=lambda *a: None, async_ckpt=False)
    clean = loop.train(cfg, checkpoint_dir=str(tmp_path / "c2"), **kw)
    crashed = loop.train_with_restarts(
        cfg, checkpoint_dir=str(tmp_path / "d2"), crash_schedule=(5,), **kw)
    np.testing.assert_allclose(clean.losses[-crashed.steps_run:],
                               crashed.losses, atol=1e-5)
