"""Deterministic stand-in for the tiny slice of ``hypothesis`` the suite uses.

The real ``hypothesis`` is a declared dependency (pyproject.toml), but some
sandboxes run the suite without network access to install it.  Rather than
skip every property test there, this module provides drop-in ``given`` /
``settings`` / ``strategies`` that replay each property over a fixed,
seeded set of examples: the strategy boundaries first (min/max — where real
bugs live), then seeded pseudo-random draws.  ``tests/conftest.py`` installs
it into ``sys.modules['hypothesis']`` only when the real package is missing,
so environments with hypothesis installed are unaffected.

Supported surface (all the suite needs): ``st.integers(lo, hi)``,
``st.lists(elem, min_size=, max_size=)``, ``@given(*strategies)``,
``@settings(max_examples=, deadline=)``.
"""
from __future__ import annotations

import functools
import inspect

import numpy as np

__version__ = "0.0-fallback"


class _Strategy:
    """A draw function plus boundary examples tried before random draws."""

    def __init__(self, draw, boundaries):
        self.draw = draw                # rng -> value
        self.boundaries = boundaries    # list of deterministic edge values


class _StrategiesNamespace:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def draw(rng):
            # Draw in int64-safe halves so 2**32-scale bounds don't overflow.
            span = max_value - min_value
            return min_value + int(rng.integers(0, span + 1, dtype=np.uint64))
        return _Strategy(draw, [min_value, max_value])

    @staticmethod
    def lists(elem: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(size)]
        bounds = [[elem.boundaries[0]] * max(min_size, 1),
                  [elem.boundaries[-1]] * max_size]
        return _Strategy(draw, [b for b in bounds if len(b) >= min_size])


strategies = _StrategiesNamespace()


def settings(*, max_examples: int = 25, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        max_examples = getattr(fn, "_fallback_settings",
                               {}).get("max_examples", 25)

        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(
                abs(hash(fn.__qualname__)) % (2 ** 32))
            n_bound = max(len(s.boundaries) for s in strats)
            for i in range(max(max_examples, n_bound)):
                if i < n_bound:
                    args = [s.boundaries[min(i, len(s.boundaries) - 1)]
                            for s in strats]
                else:
                    args = [s.draw(rng) for s in strats]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i}): "
                        f"{fn.__name__}{tuple(args)!r}") from e

        # pytest must not mistake the property arguments for fixtures.
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
