"""Multi-device equivalence checks, executed by tests/test_distributed.py in
a subprocess with 8 forced host devices (so the main pytest process keeps a
single device).  Prints "OK <name>" per passing check; any exception fails.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np          # noqa: E402
import jax                  # noqa: E402
import jax.numpy as jnp     # noqa: E402

from repro.core import imm, rrr, tiles, traversal          # noqa: E402
from repro.distributed import traversal as dtrav           # noqa: E402
from repro.graph import csr, generators, partition         # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    g = generators.powerlaw_cluster(500, 8.0, prob=0.3, seed=2)

    # ---- sample parallel ≡ per-batch single-device -------------------------
    mesh = jax.make_mesh((8,), ("data",))
    B, C = 16, 64
    starts = jnp.stack([
        traversal.random_starts(jax.random.key(b), g.num_vertices, C)
        for b in range(B)])
    seeds = jnp.asarray([int(rrr.batch_seed(5, b)) for b in range(B)],
                        jnp.uint32)
    vis_dist = dtrav.sample_parallel_visited(g, starts, seeds, C, mesh)
    for b in range(B):
        res = traversal.run_fused(g, starts[b], C, seeds[b])
        np.testing.assert_array_equal(np.asarray(vis_dist[b]),
                                      np.asarray(res.visited))
    print("OK sample_parallel")

    # ---- distributed greedy ≡ single-device greedy -------------------------
    s_dist, cov_dist = dtrav.distributed_greedy_max_cover(vis_dist, 4, C, mesh)
    s_one, cov_one = imm.greedy_max_cover(vis_dist, 4, C, use_kernel=False)
    np.testing.assert_array_equal(s_dist, s_one)
    assert abs(cov_dist - cov_one) < 1e-12
    print("OK distributed_greedy")

    # ---- graph parallel ≡ single-device (coupled RNG) ----------------------
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    g2 = csr.dedupe(g)
    tg = tiles.from_graph(g2)
    ptg = partition.partition(tg, num_shards=4)
    st = traversal.random_starts(jax.random.key(3), g2.num_vertices, C)
    vis_gp, levels = dtrav.graph_parallel_traversal(ptg, st, C, 17, mesh2)
    res_single = traversal.run_fused(g2, st, C, jnp.uint32(17))
    np.testing.assert_array_equal(np.asarray(vis_gp),
                                  np.asarray(res_single.visited))
    assert int(levels) == int(res_single.stats.levels_run)
    print("OK graph_parallel")

    # ---- graph parallel on a mesh slice with pod axis ----------------------
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    ptg2 = partition.partition(tg, num_shards=2)
    vis_gp2, _ = dtrav.graph_parallel_traversal(ptg2, st, C, 17, mesh3)
    np.testing.assert_array_equal(np.asarray(vis_gp2),
                                  np.asarray(res_single.visited))
    print("OK graph_parallel_multipod")


if __name__ == "__main__":
    main()
