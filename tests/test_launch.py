"""Launch layer: HLO cost parser units + the real lower_cell path in a
subprocess (8 forced devices; see tests/launch_check.py)."""
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as ha

_SCRIPT = pathlib.Path(__file__).parent / "launch_check.py"
_SRC = str(pathlib.Path(__file__).parents[1] / "src")


# -------------------------------------------------------------- HLO parser
def _xla_flops(comp) -> float:
    """``compiled.cost_analysis()`` returns a dict on some jax versions and
    a one-element list of dicts on others — normalize."""
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def test_parser_matches_xla_loop_free():
    def f(a, b):
        return a @ b
    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 32), jnp.float32),
                            jax.ShapeDtypeStruct((32, 128), jnp.float32)
                            ).compile()
    got = ha.full_cost(comp.as_text())
    assert got["flops"] == 2 * 64 * 32 * 128
    assert got["flops"] == _xla_flops(comp)


def test_parser_weights_scan_loops():
    def g(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=12)
        return out
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(g).lower(s, s).compile()
    got = ha.full_cost(comp.as_text())
    assert got["flops"] == 12 * 2 * 64**3, \
        "scan body must be weighted by trip count"
    # XLA's own analysis counts the body once — we must exceed it
    assert got["flops"] > _xla_flops(comp) * 10


def test_parser_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out
    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(g).lower(s, s).compile()
    got = ha.full_cost(comp.as_text())
    assert got["flops"] == 15 * 2 * 32**3


def test_shape_bytes_tuple_and_layout():
    assert ha._shape_bytes("f32[2,3]{1,0}") == 24
    assert ha._shape_bytes("(s32[], bf16[4,4]{1,0}, pred[8])") == 4 + 32 + 8
    assert ha._shape_bytes("(f32[2], /*index=5*/f32[2])") == 16


def test_collectives_counted(tmp_path):
    """all-reduce on a 2-device mesh appears in the collective accounting
    with the 2× ring factor."""
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch import hlo_analysis as ha
mesh = jax.make_mesh((2,), ("d",))
def f(x):
    return jax.lax.psum(x, "d")
from repro.distributed.compat import shard_map
fn = shard_map(f, mesh=mesh, in_specs=(P("d"),), out_specs=P())
comp = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
c = ha.full_cost(comp.as_text())["collective"]
assert c["op_counts"].get("all-reduce", 0) >= 1, c
assert c["per_device_bytes"] >= 2 * 4 * 128 * 4, c
print("OK collective")
"""
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK collective" in proc.stdout


# ------------------------------------------------------------- lower_cell
@pytest.mark.slow
def test_lower_cell_all_kinds_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(_SCRIPT)],
                          capture_output=True, text=True, env=env,
                          timeout=1800)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-3000:]
    assert "OK sharding_rules" in proc.stdout
    assert proc.stdout.count("OK lower") == 15, proc.stdout
