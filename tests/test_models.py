"""Model-zoo correctness: blocked attention vs naive, chunked SSD vs naive
recurrence, MoE dispatch invariants, and the gold test — teacher-forced
decode must reproduce full-sequence forward logits for every architecture."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import attention, common, decode, mlp, model, ssm


def _batch_for(cfg, B, L, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.num_codebooks:
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, cfg.num_codebooks, L)))
    else:
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, L - cfg.num_patches)))
    batch = dict(tokens=tokens, labels=tokens)
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, model.PATCH_EMBED_DIM)),
            jnp.float32) * 0.1
    return batch


# ------------------------------------------------------------- smoke per arch
@pytest.mark.parametrize("arch", registry.ARCHS)
def test_arch_smoke_forward_and_train_shapes(arch):
    cfg = registry.smoke(arch)
    params = model.init_params(jax.random.key(0), cfg)
    B, L = 2, 32
    batch = _batch_for(cfg, B, L)
    logits, aux, _ = model.forward(params, cfg, batch)
    if cfg.num_codebooks:
        assert logits.shape == (B, L, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, L, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN logits"
    loss, metrics = model.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss_fn(p, cfg, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


# --------------------------------------------------- decode ≡ forward (gold)
@pytest.mark.parametrize("arch", registry.ARCHS)
def test_teacher_forced_decode_matches_forward(arch):
    """Feed the same tokens step-by-step through decode_step; logits must
    match the full forward pass at every position (validates every cache:
    KV, MLA latent, mamba state, shared-attn, conv tail)."""
    import dataclasses
    cfg = registry.smoke(arch)
    # patches only make sense in prefill; capacity must be non-binding or
    # full-sequence and per-step MoE dispatch legitimately drop differently.
    cfg = dataclasses.replace(cfg, num_patches=0, capacity_factor=8.0)
    params = model.init_params(jax.random.key(1), cfg)
    B, L = 2, 16
    batch = _batch_for(cfg, B, L, seed=3)
    full_logits, _, _ = model.forward(params, cfg, batch)

    caches = decode.init_caches(cfg, B, L)
    outs = []
    for t in range(L):
        tok = (batch["tokens"][:, :, t:t + 1] if cfg.num_codebooks
               else batch["tokens"][:, t:t + 1])
        logits, caches = decode.decode_step(params, cfg, caches, tok,
                                            jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-2, rtol=2e-2)


# ------------------------------------------------------ attention references
def _naive_attention(q, k, v, scale):
    """q: (B,L,KVH,G,hd), k/v: (B,L,KVH,hd) — full causal softmax."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    L = q.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("L,bq,bk", [(64, 16, 16), (64, 64, 8), (128, 32, 64)])
def test_blocked_attention_matches_naive(L, bq, bk):
    import dataclasses
    cfg = dataclasses.replace(registry.smoke("llama3.2-3b"),
                              attn_block_q=bq, attn_block_k=bk)
    B, KVH, G, hd = 2, 2, 3, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, L, KVH, G, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, L, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, L, KVH, hd), jnp.float32)

    def kv_block(j):
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, 1)
        return k_blk, v_blk

    out = attention._run_q_blocks(q, kv_block, cfg, L, hd)
    expected = _naive_attention(q, k, v, hd ** -0.5)
    expected = jnp.transpose(expected, (0, 1, 2, 3, 4))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-5, rtol=1e-4)


def test_gqa_rope_position_sensitivity():
    cfg = registry.smoke("llama3.2-3b")
    p = attention.init_gqa(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model),
                          jnp.float32)
    pos1 = jnp.arange(16)[None]
    pos2 = pos1 + 7
    o1, _ = attention.gqa_forward(p, x, pos1, cfg)
    o2, _ = attention.gqa_forward(p, x, pos2, cfg)
    assert not np.allclose(np.asarray(o1), np.asarray(o2)), \
        "rope must make attention position-dependent"


# ----------------------------------------------------------------- SSD oracle
def _naive_ssd(x, dt, A, B_, C, D):
    """Sequential SSD recurrence: state_{t} = exp(dt·A)·state + dt·B⊗x."""
    b, L, H, P = x.shape
    S = B_.shape[-1]
    state = np.zeros((b, H, S, P))
    ys = []
    for t in range(L):
        dA = np.exp(dt[:, t] * A)                       # (b,H)
        state = state * dA[..., None, None] + np.einsum(
            "bs,bh,bhp->bhsp", B_[:, t], dt[:, t], x[:, t])
        y = np.einsum("bs,bhsp->bhp", C[:, t], state)
        ys.append(y + D[None, :, None] * x[:, t])
    return np.stack(ys, 1)


@pytest.mark.parametrize("L,chunk", [(32, 8), (64, 16), (48, 48)])
def test_mamba_chunked_matches_naive_recurrence(L, chunk):
    import dataclasses
    cfg = dataclasses.replace(registry.smoke("mamba2-1.3b"), ssm_chunk=chunk)
    p = ssm.init_mamba(jax.random.key(0), cfg)
    B = 2
    x = jax.random.normal(jax.random.key(1), (B, L, cfg.d_model),
                          jnp.float32) * 0.5
    out, (state, tail) = ssm.mamba_forward(p, x, cfg)
    assert out.shape == (B, L, cfg.d_model)

    # Re-derive the naive recurrence from the same pre-SSD tensors.
    z, xbc, dt = ssm._split(x @ p["in_proj"], cfg)
    xbc_c, _ = ssm._causal_conv(xbc, p["conv"], cfg)
    di, S = cfg.d_inner, cfg.ssm_state
    xs, Bc, Cc = np.split(np.asarray(xbc_c), [di, di + S], axis=-1)
    dtv = np.asarray(jax.nn.softplus(dt + p["dt_bias"]))
    A = -np.exp(np.asarray(p["a_log"]))
    H, P = cfg.ssm_heads, di // cfg.ssm_heads
    y_naive = _naive_ssd(xs.reshape(B, L, H, P), dtv, A, Bc, Cc,
                         np.asarray(p["d_skip"]))
    y_naive = y_naive.reshape(B, L, di)
    y_gated = common.rms_norm(
        (jnp.asarray(y_naive, jnp.float32) * jax.nn.silu(z)), p["norm"],
        cfg.norm_eps)
    expected = y_gated @ p["out_proj"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-4, rtol=1e-3)


def test_mamba_decode_matches_forward_statefully():
    cfg = registry.smoke("mamba2-1.3b")
    p = ssm.init_mamba(jax.random.key(0), cfg)
    B, L = 2, 16
    x = jax.random.normal(jax.random.key(2), (B, L, cfg.d_model),
                          jnp.float32) * 0.5
    full, _ = ssm.mamba_forward(p, x, cfg)
    cache = ssm.init_mamba_cache(cfg, B)
    outs = []
    for t in range(L):
        o, cache = ssm.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-4, rtol=1e-3)


# ----------------------------------------------------------------------- MoE
def test_moe_matches_dense_oracle_unbounded_capacity():
    """With capacity ≥ all tokens, MoE == explicit per-token expert mix."""
    import dataclasses
    cfg = dataclasses.replace(registry.smoke("deepseek-v3-671b"),
                              capacity_factor=64.0, num_shared_experts=0)
    p = mlp.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                          jnp.float32) * 0.5
    out, aux = mlp.moe_forward(p, x, cfg)

    xt = np.asarray(x).reshape(-1, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    order = np.argsort(-probs, -1)[:, : cfg.top_k]
    expected = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        gates = probs[t, order[t]]
        gates = gates / gates.sum()
        for j, e in enumerate(order[t]):
            h = np.maximum(xt[t] @ np.asarray(p["experts_w1"][e]), 0)
            h = np.asarray(jax.nn.silu(
                jnp.asarray(xt[t] @ np.asarray(p["experts_w1"][e]))))
            h = h * (xt[t] @ np.asarray(p["experts_w3"][e]))
            expected[t] += gates[j] * (h @ np.asarray(p["experts_w2"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(-1, cfg.d_model),
                               expected, atol=1e-3, rtol=1e-2)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    import dataclasses
    cfg = dataclasses.replace(registry.smoke("deepseek-v3-671b"),
                              capacity_factor=0.25, num_shared_experts=0)
    p = mlp.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, _ = mlp.moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())


# ------------------------------------------------------------------ counting
@pytest.mark.parametrize("arch,expected_b", [
    ("nemotron-4-340b", 340e9), ("qwen1.5-110b", 110e9),
    ("llama3.2-3b", 3.2e9), ("command-r-35b", 35e9),
    ("deepseek-v3-671b", 671e9), ("mamba2-1.3b", 1.3e9),
    ("musicgen-medium", 1.5e9), ("phi-3-vision-4.2b", 4.2e9),
    ("zamba2-2.7b", 2.7e9), ("llama4-maverick-400b-a17b", 400e9),
])
def test_param_count_in_band(arch, expected_b):
    n = registry.get(arch).param_count()
    assert 0.55 * expected_b < n < 1.8 * expected_b, \
        f"{arch}: {n/1e9:.1f}B vs expected ~{expected_b/1e9:.0f}B"


def test_deepseek_active_params():
    cfg = registry.get("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert 25e9 < active < 60e9, f"{active/1e9:.1f}B active (paper: 37B)"
