"""Streaming graph updates: delta application with stable CSR edge ids,
dirty-slot tracking, churn-proportional incremental pool refresh, and the
serving-tier write path (`repro.stream` + `ServingTier.apply_delta`)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lt as lt_lib
from repro.graph import csr, generators
from repro.sampling import SamplerSpec
from repro.serve.influence import PoolConfig, SketchStore
from repro.serve.tier import EpochMixError, ServingTier, ShedError
from repro.stream import (DirtySlotTracker, EdgeDelta, apply_delta,
                          cold_rebuild_batches, compact_graph, compact_store,
                          incremental_refresh, plan_refresh, apply_plan,
                          random_delta, tombstone_fraction,
                          touched_row_blocks)


@pytest.fixture(scope="module")
def graph():
    return csr.dedupe(generators.powerlaw_cluster(
        300, 6.0, prob=(0.05, 0.3), seed=17))


def _arrays(g):
    """Every array a bit-identity claim is made over, padding included."""
    return (np.asarray(g.src), np.asarray(g.dst), np.asarray(g.prob),
            np.asarray(g.indptr), g.num_edges, g.padded_edges)


def _assert_graph_identical(a, b):
    for x, y in zip(_arrays(a), _arrays(b)):
        np.testing.assert_array_equal(x, y)


def _absent_pairs(g, count, seed=0):
    e = g.num_edges
    taken = set(zip(np.asarray(g.src)[:e].tolist(),
                    np.asarray(g.dst)[:e].tolist()))
    rng = np.random.default_rng(seed)
    pairs = []
    while len(pairs) < count:
        s, d = (int(x) for x in rng.integers(0, g.num_vertices, 2))
        if s != d and (s, d) not in taken:
            taken.add((s, d))
            pairs.append((s, d))
    return pairs


def _stream_store(g, *, diffusion="ic", frontier="dense", batches=6,
                  colors=32, tile=64, seed=9):
    spec = SamplerSpec(diffusion=diffusion, backend="dense",
                       num_colors=colors, master_seed=seed,
                       tile_size=tile, frontier=frontier)
    store = SketchStore(g, PoolConfig(max_batches=16, spec=spec))
    store.ensure(batches)
    return store


# --------------------------------------------------------------- EdgeDelta
def test_edge_delta_validation_and_views():
    d = EdgeDelta.concat(EdgeDelta.inserts([1, 2], [3, 4], [0.5, 0.25]),
                         EdgeDelta.deletes([7], [8]))
    assert (len(d), d.num_inserts, d.num_deletes) == (3, 2, 1)
    r = d.reversed()
    np.testing.assert_array_equal(r.src, d.dst)
    np.testing.assert_array_equal(r.dst, d.src)
    inv = EdgeDelta.inserts([1], [2], [0.5]).inverse()
    assert inv.num_deletes == 1 and not inv.insert.any()

    with pytest.raises(ValueError, match="share one length"):
        EdgeDelta([1, 2], [3], [0.5], [True])
    for w in (0.0, -1.0, np.inf, np.nan):
        with pytest.raises(ValueError, match="finite and > 0"):
            EdgeDelta.inserts([1], [2], [w])
    with pytest.raises(ValueError, match="duplicate"):
        EdgeDelta.concat(EdgeDelta.inserts([1], [2], [0.5]),
                         EdgeDelta.deletes([1], [2]))
    with pytest.raises(ValueError, match="all-insert"):
        EdgeDelta.deletes([1], [2]).inverse()


def test_apply_delta_rejects_bad_ops(graph):
    e = graph.num_edges
    s0, d0 = int(np.asarray(graph.src)[0]), int(np.asarray(graph.dst)[0])
    (sa, da), = _absent_pairs(graph, 1)
    with pytest.raises(KeyError, match="absent"):
        apply_delta(graph, EdgeDelta.deletes([sa], [da]))
    with pytest.raises(KeyError, match="live"):
        apply_delta(graph, EdgeDelta.inserts([s0], [d0], [0.5]))
    with pytest.raises(ValueError, match="outside"):
        apply_delta(graph, EdgeDelta.deletes([graph.num_vertices], [0]))
    assert graph.num_edges == e, "apply_delta must be functional"


# ----------------------------------------------------- round-trip property
@settings(max_examples=12, deadline=None)
@given(st.lists(st.integers(0, 2 ** 30), min_size=1, max_size=10))
def test_insert_then_inverse_roundtrip_is_bit_identical(draws):
    """ISSUE satellite: apply_delta(apply_delta(g, ins), del_of_ins) is
    bit-identical to g — indices, indptr, weights, array LENGTHS — via
    the population-neutral extend/trim policy."""
    g = test_insert_then_inverse_roundtrip_is_bit_identical._graph
    pool = test_insert_then_inverse_roundtrip_is_bit_identical._pool
    pairs = sorted({pool[v % len(pool)] for v in draws})
    ins = EdgeDelta.inserts([p[0] for p in pairs], [p[1] for p in pairs],
                            np.linspace(0.05, 0.4, len(pairs)))
    g1, a1 = apply_delta(g, ins)
    assert a1.appended == len(pairs) and a1.inserted == len(pairs)
    assert g1.num_edges == g.num_edges + len(pairs)
    g2, a2 = apply_delta(g1, ins.inverse())
    assert a2.trimmed >= len(pairs)
    _assert_graph_identical(g2, g)


test_insert_then_inverse_roundtrip_is_bit_identical._graph = csr.dedupe(
    generators.powerlaw_cluster(200, 5.0, prob=(0.05, 0.3), seed=3))
test_insert_then_inverse_roundtrip_is_bit_identical._pool = _absent_pairs(
    test_insert_then_inverse_roundtrip_is_bit_identical._graph, 64)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(0, 2 ** 30), min_size=1, max_size=8))
def test_lt_roundtrip_bit_identical_while_sums_stay_below_one(draws):
    """On an LT-normalized graph the round-trip also restores the
    NORMALIZED weights bit-for-bit — provided the in-sums stay ≤ 1
    throughout (normalization is a lossy down-only projection, so tiny
    insert weights keep it the identity in both directions)."""
    g = test_lt_roundtrip_bit_identical_while_sums_stay_below_one._graph
    pool = test_lt_roundtrip_bit_identical_while_sums_stay_below_one._pool
    pairs = sorted({pool[v % len(pool)] for v in draws})
    ins = EdgeDelta.inserts([p[0] for p in pairs], [p[1] for p in pairs],
                            np.full(len(pairs), 1e-4, np.float32))
    g1, _ = apply_delta(g, ins, lt_normalized=True)
    g2, _ = apply_delta(g1, ins.inverse(), lt_normalized=True)
    _assert_graph_identical(g2, g)


test_lt_roundtrip_bit_identical_while_sums_stay_below_one._graph = \
    lt_lib.normalize_lt_weights(csr.dedupe(generators.powerlaw_cluster(
        200, 5.0, prob=(0.01, 0.02), seed=5)))
test_lt_roundtrip_bit_identical_while_sums_stay_below_one._pool = \
    _absent_pairs(
        test_lt_roundtrip_bit_identical_while_sums_stay_below_one._graph, 48)


def test_tombstone_then_resurrect_restores_bits(graph):
    e = graph.num_edges
    pos = np.array([5, 40, e - 100])
    s = np.asarray(graph.src)[pos]
    d = np.asarray(graph.dst)[pos]
    w = np.asarray(graph.prob)[pos]
    g1, a1 = apply_delta(graph, EdgeDelta.deletes(s, d))
    assert a1.deleted == 3 and a1.trimmed == 0
    assert np.asarray(g1.prob)[pos].tolist() == [0.0] * 3, "tombstones"
    np.testing.assert_array_equal(np.asarray(g1.src), np.asarray(graph.src))
    g2, a2 = apply_delta(g1, EdgeDelta.inserts(s, d, w))
    assert a2.resurrected == 3 and a2.appended == 0
    _assert_graph_identical(g2, graph)


def test_fresh_insert_and_trim_are_population_neutral(graph):
    """Padding slots carry src 0 and the dense work counters see them —
    the pad population (len - num_edges) must survive both extend and
    trim, or every row-0-visiting slot would dirty on ANY insert."""
    pad = len(np.asarray(graph.src)) - graph.num_edges
    pairs = _absent_pairs(graph, 4, seed=2)
    ins = EdgeDelta.inserts([p[0] for p in pairs], [p[1] for p in pairs],
                            [0.1] * 4)
    g1, a1 = apply_delta(graph, ins)
    assert a1.appended == 4
    assert len(np.asarray(g1.src)) - g1.num_edges == pad
    assert 0 not in set(a1.touched_rows.tolist()) - {p[0] for p in pairs}, \
        "row 0 must not be touched by the pad bookkeeping"
    g2, a2 = apply_delta(g1, ins.inverse())
    assert a2.trimmed >= 4
    assert len(np.asarray(g2.src)) - g2.num_edges == pad
    _assert_graph_identical(g2, graph)


def test_touched_rows_and_blocks(graph):
    e = graph.num_edges
    s0 = int(np.asarray(graph.src)[7])
    d0 = int(np.asarray(graph.dst)[7])
    _, a = apply_delta(graph, EdgeDelta.deletes([s0], [d0]))
    assert s0 in a.touched_rows
    blocks = touched_row_blocks(a.touched_rows, 64)
    assert s0 // 64 in blocks
    # LT: re-normalizing dst d0 touches the sources of ALL its live
    # in-edges, not just the deleted one.
    gn = lt_lib.normalize_lt_weights(graph)
    _, an = apply_delta(gn, EdgeDelta.deletes([s0], [d0]),
                        lt_normalized=True)
    dst = np.asarray(gn.dst)[:e]
    prob = np.asarray(gn.prob)[:e]
    peers = set(np.asarray(gn.src)[:e][(dst == d0) & (prob > 0)].tolist())
    assert peers - {s0} <= set(an.touched_rows.tolist())


def test_confined_lt_renorm_matches_full_normalize(graph):
    """The confined re-normalization must replicate `normalize_lt_weights`
    arithmetic exactly: structural-apply + full normalize on the whole
    graph is bit-identical to the lt_normalized=True fused path."""
    gn = lt_lib.normalize_lt_weights(graph)
    rng = np.random.default_rng(4)
    delta = random_delta(gn, rng, num_deletes=6, num_inserts=6,
                         weight_range=(0.3, 0.9))
    fused, _ = apply_delta(gn, delta, lt_normalized=True)
    structural, _ = apply_delta(gn, delta)
    reference = lt_lib.normalize_lt_weights(structural)
    _assert_graph_identical(fused, reference)


def test_normalize_lt_weights_is_order_preserving_and_idempotent(graph):
    # Simulate a streamed (un-sorted) edge array: apply a delta first.
    g1, _ = apply_delta(graph, EdgeDelta.inserts(
        *zip(*_absent_pairs(graph, 3, seed=6)), [0.9, 0.8, 0.7]))
    gn = lt_lib.normalize_lt_weights(g1)
    np.testing.assert_array_equal(np.asarray(gn.src), np.asarray(g1.src))
    np.testing.assert_array_equal(np.asarray(gn.dst), np.asarray(g1.dst))
    np.testing.assert_array_equal(np.asarray(gn.indptr),
                                  np.asarray(g1.indptr))
    e = gn.num_edges
    in_sum = np.zeros(gn.num_vertices)
    np.add.at(in_sum, np.asarray(gn.dst)[:e],
              np.asarray(gn.prob)[:e].astype(np.float64))
    assert in_sum.max() <= 1.0 + 1e-6
    _assert_graph_identical(lt_lib.normalize_lt_weights(gn), gn)


def test_random_delta_is_well_formed_and_confined(graph):
    rng = np.random.default_rng(11)
    rows = np.arange(64, 192)
    d = random_delta(graph, rng, num_deletes=5, num_inserts=5,
                     dst_rows=rows)
    assert d.num_deletes == 5 and d.num_inserts == 5
    assert np.isin(d.dst, rows).all()
    apply_delta(graph, d)   # applies cleanly


# ---------------------------------------------------------------- tracker
def test_tracker_records_queries_and_stats(graph):
    store = _stream_store(graph, frontier="sparse")
    tracker = DirtySlotTracker.for_store(store)
    assert tracker.num_slots == len(store.batches)
    assert tracker.num_row_blocks == -(-graph.num_vertices // 64)
    # Recorded bits match the masks they were derived from.
    vis = np.asarray(store.batches[0].visited)
    rows = np.nonzero((vis != 0).any(axis=1))[0]
    np.testing.assert_array_equal(tracker.visited_blocks(0),
                                  np.unique(rows // 64))
    hit = tracker.dirty_slots([int(rows[0]) // 64])
    assert 0 in hit
    with pytest.raises(ValueError, match="row block outside"):
        tracker.dirty_slots([tracker.num_row_blocks])
    stats = tracker.stats()
    assert stats["slots"] == tracker.num_slots
    assert stats["tracker_bytes"] == tracker._bits.nbytes
    assert stats["mean_visited_blocks"] > 0


def test_tracker_sync_rerecords_only_changed_slots(graph):
    store = _stream_store(graph)
    tracker = DirtySlotTracker.for_store(store)
    assert tracker.sync(store) == 0, "clean re-sync is free"
    refreshed = store.refresh(fraction=0.34)
    assert tracker.sync(store) == len(refreshed)
    store.shrink(3)
    tracker.sync(store)
    assert tracker.num_slots == 3
    store.ensure(5)
    assert tracker.sync(store) == 2
    # A graph-epoch bump invalidates every recorded slot.
    store.graph_epoch += 1
    assert tracker.sync(store) == 5


# ---------------------------------------------------- incremental refresh
@pytest.mark.parametrize("diffusion,frontier", [("ic", "dense"),
                                                ("ic", "sparse"),
                                                ("lt", "sparse")])
def test_incremental_refresh_matches_cold_rebuild(graph, diffusion,
                                                  frontier):
    store = _stream_store(graph, diffusion=diffusion, frontier=frontier)
    store.visited_stack()
    tracker = DirtySlotTracker.for_store(store)
    rng = np.random.default_rng(21)
    delta = random_delta(store.graph, rng, num_deletes=4, num_inserts=4)
    v0 = store.version
    report = incremental_refresh(store, tracker, delta)
    assert store.version == (v0[0] + 1, v0[1], v0[2])
    assert report.graph_epoch == store.graph_epoch
    assert 0 < report.dirty_slots <= report.total_slots
    cold = cold_rebuild_batches(store)
    for got, want in zip(store.batches, cold):
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(want.visited))
        assert got.fused_edge_visits == want.fused_edge_visits
        assert got.unfused_edge_visits == want.unfused_edge_visits
    # The in-place stack followed the donated scatter.
    np.testing.assert_array_equal(
        np.asarray(store.visited_stack()),
        np.stack([np.asarray(b.visited) for b in cold]))


def test_clean_slots_are_not_resampled(graph):
    store = _stream_store(graph, frontier="sparse", batches=8)
    tracker = DirtySlotTracker.for_store(store)
    rng = np.random.default_rng(31)
    delta = random_delta(store.graph, rng, num_deletes=2, num_inserts=0,
                         dst_rows=np.arange(64))
    before = list(store.batches)
    plan = plan_refresh(store, tracker, delta)
    apply_plan(store, plan)
    assert plan.dirty_slots, "a live-edge delete must dirty someone"
    for i, b in enumerate(before):
        if i not in plan.dirty_slots:
            assert store.batches[i] is b, \
                "clean slots must keep their batch OBJECT (no resample)"
    cold = cold_rebuild_batches(store)
    for got, want in zip(store.batches, cold):
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(want.visited))
        assert got.fused_edge_visits == want.fused_edge_visits


# ------------------------------------- values-only frontier-index patch
def test_patch_frontier_index_matches_fresh_build(graph):
    from repro.core import sparse
    g_rev0 = csr.transpose(graph)
    fidx = sparse.build_frontier_index(g_rev0, tile_rows=64)
    rng = np.random.default_rng(71)
    delta = random_delta(graph, rng, num_deletes=6, num_inserts=0)
    g_rev2, applied = apply_delta(g_rev0, delta.reversed())
    blocks = touched_row_blocks(applied.touched_rows, 64)
    assert len(blocks), "a live-edge delete must touch a row block"
    patched = sparse.patch_frontier_index(fidx, g_rev2, blocks)
    fresh = sparse.build_frontier_index(g_rev2, tile_rows=64)
    for name in ("blk_src", "blk_dst", "blk_prob", "blk_eid", "blk_valid",
                 "blk_rowblock"):
        np.testing.assert_array_equal(
            np.asarray(getattr(patched, name)),
            np.asarray(getattr(fresh, name)), err_msg=name)
    assert (patched.num_blocks, patched.edge_block, patched.tile_rows) == \
        (fresh.num_blocks, fresh.edge_block, fresh.tile_rows)


def test_values_only_delta_patches_sampler_in_place(graph):
    store = _stream_store(graph, frontier="sparse", batches=3)
    s0 = store.sampler
    tracker = DirtySlotTracker.for_store(store)
    rng = np.random.default_rng(73)
    incremental_refresh(store, tracker,
                        random_delta(store.graph, rng, num_deletes=3,
                                     num_inserts=0))
    assert store.sampler is s0, \
        "a tombstone-only delta must patch the frontier index in place"
    (sa, da), = _absent_pairs(store.graph, 1, seed=73)
    incremental_refresh(store, tracker,
                        EdgeDelta.inserts([sa], [da], [0.05]))
    assert store.sampler is not s0, \
        "an appending insert changes the edge layout → full rebuild"


# ------------------------------------------------------------- compaction
def test_compact_graph_drops_tombstones_bit_for_bit(graph):
    rng = np.random.default_rng(81)
    delta = random_delta(graph, rng, num_deletes=8, num_inserts=0)
    g1, _ = apply_delta(graph, delta)
    assert tombstone_fraction(graph) == 0.0
    assert tombstone_fraction(g1) == pytest.approx(8 / g1.num_edges)
    g2, g_rev2 = compact_graph(g1)
    assert g2.num_edges == g1.num_edges - 8
    assert tombstone_fraction(g2) == 0.0

    def live_edges(g, sel):
        e = g.num_edges
        s, d, p = (np.asarray(a)[:e][sel]
                   for a in (g.src, g.dst, g.prob))
        order = np.lexsort((d, s))
        return s[order], d[order], p[order]

    e1 = g1.num_edges
    live = np.asarray(g1.prob)[:e1] > 0
    # Live weights carry over bit-for-bit (no union-merge round-trip).
    for a, b in zip(live_edges(g1, live), live_edges(g2, slice(None))):
        np.testing.assert_array_equal(a, b)
    _assert_graph_identical(g_rev2, csr.transpose(g2))


def test_compact_store_matches_cold_build_on_compacted_graph(graph):
    store = _stream_store(graph, frontier="sparse", batches=4)
    tracker = DirtySlotTracker.for_store(store)
    rng = np.random.default_rng(83)
    incremental_refresh(store, tracker,
                        random_delta(store.graph, rng, num_deletes=6,
                                     num_inserts=2))
    frac = tombstone_fraction(store.graph)
    assert frac > 0
    reclaimed = compact_store(store)
    assert reclaimed == pytest.approx(frac)
    assert tombstone_fraction(store.graph) == 0.0
    cold = cold_rebuild_batches(store)
    for got, want in zip(store.batches, cold):
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(want.visited))
        assert got.fused_edge_visits == want.fused_edge_visits
    np.testing.assert_array_equal(
        np.asarray(store.visited_stack()),
        np.stack([np.asarray(b.visited) for b in cold]))


def test_tier_maybe_compact_policy_and_counter(graph):
    store = _stream_store(graph, frontier="sparse", batches=3)
    with ServingTier.build(store, replicas=2, quota_qps=None,
                           default_deadline=0.05) as tier:
        rng = np.random.default_rng(91)
        tier.apply_delta("ops", random_delta(store.graph, rng,
                                             num_deletes=5, num_inserts=0))
        r0 = tier.group.replicas[0].store
        frac = tombstone_fraction(r0.graph)
        assert frac > 0
        assert not tier.maybe_compact(threshold=0.5), \
            "below threshold → no rebuild"
        assert tier.maybe_compact(threshold=0.0)
        assert tombstone_fraction(r0.graph) == 0.0
        assert not tier.maybe_compact(threshold=0.0), \
            "a freshly compacted graph has nothing to reclaim"
        assert tier.group.consistent()
        cold = cold_rebuild_batches(r0)
        for got, want in zip(r0.batches, cold):
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(want.visited))
        snap = tier.snapshot()
        assert snap["stream"]["compactions"] == 1
        assert snap["stream"]["compacted_fraction"]["count"] == 1
        # Queries keep flowing on the renumbered edge ids.
        tier.gather([tier.submit_sigma("ops", [3, 17, 29])])


# ------------------------------------------------- version + persistence
def test_graph_epoch_in_version_clone_and_snapshot(graph, tmp_path):
    store = _stream_store(graph, batches=3)
    tracker = DirtySlotTracker.for_store(store)
    rng = np.random.default_rng(41)
    incremental_refresh(store, tracker,
                        random_delta(store.graph, rng, num_deletes=2,
                                     num_inserts=2))
    assert store.version[0] == 1
    assert store.clone().version == store.version

    store.save(str(tmp_path))
    back = SketchStore.restore(str(tmp_path), store.graph, store.config,
                               g_rev=store.g_rev)
    assert back.version == store.version
    for got, want in zip(back.batches, store.batches):
        np.testing.assert_array_equal(np.asarray(got.visited),
                                      np.asarray(want.visited))


def test_restore_of_pre_streaming_snapshot_defaults_graph_epoch(
        graph, tmp_path, monkeypatch):
    store = _stream_store(graph, batches=2)
    store.graph_epoch = 7
    orig_tree = SketchStore._tree

    def legacy_tree(self):
        tree = orig_tree(self)
        tree["counters"] = tree["counters"][:4]   # pre-streaming format
        return tree

    monkeypatch.setattr(SketchStore, "_tree", legacy_tree)
    store.save(str(tmp_path))
    monkeypatch.undo()
    back = SketchStore.restore(str(tmp_path), graph, store.config)
    assert back.graph_epoch == 0
    assert back.version == (0, store.epoch, len(store.batches))


# ------------------------------------------------------------------ tier
def test_tier_apply_delta_end_to_end(graph):
    store = _stream_store(graph, frontier="sparse", batches=4)
    with ServingTier.build(store, replicas=2, quota_qps=None,
                           default_deadline=0.05) as tier:
        pre = [tier.submit_sigma("ops", [3, 17, 29])]
        tier.gather(pre)
        rng = np.random.default_rng(51)
        delta = random_delta(store.graph, rng, num_deletes=3,
                             num_inserts=3)
        report = tier.apply_delta("ops", delta)
        assert report.inserted == 3 and report.deleted == 3
        versions = {r.version for r in tier.group.replicas}
        assert len(versions) == 1 and next(iter(versions))[0] == 1
        assert tier.group.consistent()
        # Replicas swept atomically under one plan → still bit-identical
        # to a cold rebuild on the mutated pair.
        r0 = tier.group.replicas[0].store
        cold = cold_rebuild_batches(r0)
        for got, want in zip(r0.batches, cold):
            np.testing.assert_array_equal(np.asarray(got.visited),
                                          np.asarray(want.visited))
        # Pre-delta futures can never mix with post-delta ones.
        post = [tier.submit_sigma("ops", [3, 17, 29])]
        with pytest.raises(EpochMixError):
            tier.gather(pre + post)
        tier.gather(post)

        tier.set_quota("vandal", rate=0.01, burst=1)
        tier.apply_delta("vandal", EdgeDelta.deletes([], []))
        with pytest.raises(ShedError):
            tier.apply_delta("vandal", EdgeDelta.deletes([], []))

        snap = tier.snapshot()
        assert snap["stream"]["deltas_applied"] == 2
        assert snap["stream"]["tracker"]["slots"] == 4
        assert snap["stream"]["tracker"]["deltas_seen"] == 2
        assert snap["stream"]["refresh_s"]["count"] == 2
