"""Statistical and determinism tests for the counter-based RNG."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import rng


def test_determinism():
    a = rng.hash_u32(1, 2, jnp.arange(100, dtype=jnp.uint32), 3)
    b = rng.hash_u32(1, 2, jnp.arange(100, dtype=jnp.uint32), 3)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2**31), st.integers(0, 63))
@settings(max_examples=20, deadline=None)
def test_counter_sensitivity(seed, level):
    """Changing any counter changes (almost surely) the output."""
    e = jnp.arange(64, dtype=jnp.uint32)
    base = np.asarray(rng.hash_u32(seed, level, e, 0))
    assert not (base == np.asarray(rng.hash_u32(seed + 1, level, e, 0))).all()
    assert not (base == np.asarray(rng.hash_u32(seed, level + 1, e, 0))).all()
    assert not (base == np.asarray(rng.hash_u32(seed, level, e, 1))).all()


def test_uniform_range_and_mean():
    bits = rng.hash_u32(7, 0, jnp.arange(200_000, dtype=jnp.uint32), 0)
    u = np.asarray(rng.uniform_from_u32(bits))
    assert (u >= 0).all() and (u < 1).all()
    assert abs(u.mean() - 0.5) < 5e-3
    assert abs(u.var() - 1 / 12) < 5e-3


def test_bernoulli_word_rate():
    """Each packed lane is Bernoulli(p) to within Monte-Carlo error."""
    e = jnp.arange(20_000, dtype=jnp.uint32)
    for p in (0.1, 0.5, 0.9):
        w = np.asarray(rng.bernoulli_word(3, 0, e, jnp.uint32(0),
                                          jnp.full((20_000,), p, jnp.float32)))
        rate = np.unpackbits(w.view(np.uint8)).mean()
        assert abs(rate - p) < 0.01, (p, rate)


def test_bernoulli_lane_independence():
    """Adjacent color lanes must be uncorrelated (each its own hash stream)."""
    e = jnp.arange(50_000, dtype=jnp.uint32)
    w = np.asarray(rng.bernoulli_word(3, 1, e, jnp.uint32(0),
                                      jnp.full((50_000,), 0.5, jnp.float32)))
    l0 = (w & 1).astype(np.float64)
    l1 = ((w >> 1) & 1).astype(np.float64)
    corr = np.corrcoef(l0, l1)[0, 1]
    assert abs(corr) < 0.02


def test_pack_bool_word():
    bits = jnp.asarray([[True, False, True]])
    assert int(rng.pack_bool_word(bits)[0]) == 0b101
