"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 real device;
only launch/dryrun.py forces 512 placeholder devices (and only in its own
process)."""
import importlib.util
import os
import sys

import numpy as np
import pytest

# Property tests import hypothesis at module scope; in sandboxes where the
# declared dependency can't be installed, collection must not die — install
# the deterministic fallback (same API subset, seeded examples) instead.
if importlib.util.find_spec("hypothesis") is None:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis"] = _mod


@pytest.fixture(scope="session")
def small_graph():
    from repro.graph import generators
    return generators.powerlaw_cluster(300, 6.0, prob=0.3, seed=7)


@pytest.fixture(scope="session")
def tiny_graph():
    """Deterministic 8-vertex graph mirroring the paper's Fig. 3 scale."""
    from repro.graph import csr
    src = np.array([0, 1, 1, 2, 3, 3, 4, 4, 5, 6, 7, 2])
    dst = np.array([1, 0, 2, 3, 2, 4, 6, 7, 4, 7, 8, 5])
    prob = np.full(len(src), 0.7, np.float32)
    return csr.from_edges(src, dst, prob, 9)
