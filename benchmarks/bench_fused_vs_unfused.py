"""Paper Figs. 7/8: fused vs unfused wall-time speedup grid.

Two execution models, mirroring the two GPU ports:
  * gIM-style   — many traversals resident at once: fused = one run with C
    colors; unfused = C independent single-color runs (batched as C runs of
    1 color through the same kernel for fairness).
  * Ripples-style — device-wide level-synchronous sweeps: identical math;
    fused raises per-sweep concurrency from 1 to C (the paper's "BPT
    concurrency" win) — we report both wall time and edge-visit counts.

CPU wall times are directionally meaningful only (interpret/TPU-target
kernels); edge-visit ratios are exact (coupled RNG).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import traversal
from repro.graph import csr, generators


def _time(fn, *args, reps=2):
    fn(*args)                                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(n=3000, deg=10.0, colors=(8, 32, 64), probs=(0.05, 0.1, 0.2),
        out=print):
    out("# Fig7/8: colors,prob,t_fused_s,t_unfused_s,speedup,"
        "visit_ratio")
    rows = []
    base = generators.powerlaw_cluster(n, deg, prob=0.3, seed=2)
    e = base.num_edges
    src = np.asarray(base.src)[:e]
    dst = np.asarray(base.dst)[:e]
    for p in probs:
        g = csr.from_edges(src, dst, np.full(e, p, np.float32), n)
        for c in colors:
            starts = traversal.random_starts(jax.random.key(0), n, c)
            t_fused = _time(
                lambda: traversal.run_fused(g, starts, c, jnp.uint32(1)))
            res = traversal.run_fused(g, starts, c, jnp.uint32(1))

            # unfused: C single-color runs (jit reused across colors)
            def unfused():
                outs = []
                for ci in range(c):
                    outs.append(traversal.run_single_color(
                        g, int(starts[ci]), ci, jnp.uint32(1)))
                jax.block_until_ready(outs[-1].visited)
                return outs
            t0 = time.perf_counter()
            unfused()
            t_unf = time.perf_counter() - t0

            ratio = (int(res.stats.fused_edge_visits.sum())
                     / max(int(res.stats.unfused_edge_visits.sum()), 1))
            row = (c, p, round(t_fused, 4), round(t_unf, 4),
                   round(t_unf / max(t_fused, 1e-9), 2), round(ratio, 4))
            rows.append(row)
            out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
