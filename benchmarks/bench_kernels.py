"""Kernel micro-benchmarks: tile expansion + coverage + flash attention.

Interpret-mode wall times are NOT TPU times; reported per-call to track
relative regressions, alongside the analytic VMEM working set and FLOPs
per tile that the §Roofline BlockSpec reasoning uses.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import tiles, traversal
from repro.graph import csr
from repro.kernels import coverage, flash_attention, fused_expand


def _time(fn, reps=3):
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps


def run(out=print):
    out("# kernels: name,config,us_per_call,notes")
    rng = np.random.default_rng(0)
    rows = []

    # fused_expand over a 300-tile graph, 64 colors
    n, e = 2000, 16000
    src = rng.integers(0, n, e)
    dst = (src + 1 + rng.integers(0, n - 1, e)) % n
    g = csr.from_edges(src, dst, np.full(e, 0.3, np.float32), n,
                       dedupe=True)
    tg = tiles.from_graph(g)
    starts = traversal.random_starts(jax.random.key(0), n, 64)
    fr = tiles.pad_mask_rows(traversal.init_frontier(n, 64, starts),
                             tg.padded_vertices)
    t = _time(lambda: fused_expand.fused_expand(
        tg.prob, tg.edge_id, tg.tile_src, tg.tile_dst, tg.first_of_dst,
        fr, fr, jnp.uint32(1), jnp.uint32(0), interpret=True))
    vmem_kb = (2 * 128 * 128 * 4 + 3 * 128 * 2 * 4) / 1024
    row = ("fused_expand", f"tiles={tg.num_tiles},W=2",
           round(1e6 * t, 1), f"vmem_tile={vmem_kb:.0f}KiB")
    rows.append(row)
    out(",".join(str(x) for x in row))

    vis = jnp.asarray(rng.integers(0, 2**32, (4096, 16), dtype=np.uint32))
    act = jnp.asarray(rng.integers(0, 2**32, (16,), dtype=np.uint32))
    t = _time(lambda: coverage.cover_counts(vis, act, interpret=True))
    row = ("cover_counts", "V=4096,W=16", round(1e6 * t, 1),
           "popcount-SWAR")
    rows.append(row)
    out(",".join(str(x) for x in row))

    q = jax.random.normal(jax.random.key(1), (512, 4, 64), jnp.float32)
    t = _time(lambda: flash_attention.flash_attention(
        q, q, q, causal=True, interpret=True))
    row = ("flash_attention", "L=512,H=4,D=64", round(1e6 * t, 1),
           f"flops={2*2*512*512*4*64/1e6:.0f}MF")
    rows.append(row)
    out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
