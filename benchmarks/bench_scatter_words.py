"""`bitmask.scatter_or_words` micro-bench: 32×-unpacked vs packed fast path.

The general scatter-OR must combine duplicate (row, word) targets, and OR
is not a native scatter combiner — so it unpacks every contribution to 32
bool lanes and scatters with ``max``: 32× the index traffic.  When the
caller's contributions are already OR-combined per target (every scattered
(row, word) pair distinct — e.g. segment-locally pre-OR'd compaction
output, or the distributed sparse-frontier reconstruction where shards own
disjoint row ranges), ``unique=True`` scatters whole uint32 words: 1×
traffic, bit-identical results.  This bench proves both claims — speedup
measured, equality asserted.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitmask


def run(rows=1 << 14, num_words=2, counts=(1 << 8, 1 << 11, 1 << 14),
        iters=20, out=print):
    out("# scatter_or_words: rows,words,updates,unpacked_ms,packed_ms,"
        "speedup")
    results = []
    rng = np.random.default_rng(3)
    slow = jax.jit(lambda d, r, w, v: bitmask.scatter_or_words(d, r, w, v))
    fast = jax.jit(lambda d, r, w, v: bitmask.scatter_or_words(
        d, r, w, v, unique=True))
    for k in counts:
        # Distinct (row, word) targets — the unique-path contract — drawn
        # without replacement over the row × word grid.
        flat = rng.choice(rows * num_words, size=k, replace=False)
        r = jnp.asarray(flat // num_words, jnp.int32)
        w = jnp.asarray(flat % num_words, jnp.int32)
        v = jnp.asarray(rng.integers(0, 2 ** 32, k, np.uint32))
        dst = jnp.asarray(rng.integers(0, 2 ** 32, (rows, num_words),
                                       np.uint32))
        a = slow(dst, r, w, v)
        b = fast(dst, r, w, v)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        def clock(fn):
            fn(dst, r, w, v).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn(dst, r, w, v).block_until_ready()
            return (time.perf_counter() - t0) / iters * 1e3

        ms_slow, ms_fast = clock(slow), clock(fast)
        row = (rows, num_words, k, round(ms_slow, 3), round(ms_fast, 3),
               round(ms_slow / max(ms_fast, 1e-9), 2))
        results.append(row)
        out(",".join(str(x) for x in row))
    return results


if __name__ == "__main__":
    run()
