"""SLO load generator: open-loop Poisson arrivals against the serving tier.

Drives `repro.serve.tier.ServingTier` (admission → replica router →
engines) with an **open-loop** arrival process: request times are drawn
from a Poisson process per tenant *in advance* and submitted on schedule
whether or not earlier requests have finished — the load a service
actually faces, where clients don't politely wait (closed-loop generators
hide queueing collapse by self-throttling; an open loop surfaces it as a
growing p999).

Tenant mix: ``tenants`` weight-splits ``offered_qps``; tenant0 is
additionally capped by the cell's ``quota_qps`` token bucket, so tight
cells measure the *shed* path (retry-after) while loose cells measure pure
latency.  Each admitted query records submit→resolve latency via a future
done-callback; sheds are counted, never retried (open loop).

One row per (replicas × deadline_ms × quota_qps) cell, with
p50/p99/p999/max latency, shed rate, and achieved vs offered qps, into the
standard ``BENCH_serve_load.json`` shape.  On one CPU the replicas share
silicon — the trajectory is the point: the same rows on a real device plot
replica read-scaling, and quota × deadline cells map the SLO envelope.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np


def _percentiles(lats_s: list[float]) -> dict:
    if not lats_s:
        return {"p50_ms": None, "p99_ms": None, "p999_ms": None,
                "max_ms": None}
    ms = np.sort(np.asarray(lats_s)) * 1e3
    return {"p50_ms": round(float(np.percentile(ms, 50)), 2),
            "p99_ms": round(float(np.percentile(ms, 99)), 2),
            "p999_ms": round(float(np.percentile(ms, 99.9)), 2),
            "max_ms": round(float(ms[-1]), 2)}


def _schedule(tenants: dict[str, float], arrivals: int, n: int, seed: int):
    """Merged per-tenant Poisson arrival schedule: [(t, tenant, query)]."""
    rng = np.random.default_rng(seed)
    events = []
    total = sum(tenants.values())
    for tenant, rate in tenants.items():
        share = max(1, round(arrivals * rate / total))
        gaps = rng.exponential(1.0 / rate, size=share)
        t = 0.0
        for g in gaps:
            t += g
            events.append((t, tenant, rng.integers(0, n, 3).tolist()))
    events.sort(key=lambda e: e[0])
    return events[:arrivals]


def _drive_cell(tier, events, shed_error) -> dict:
    """Submit ``events`` open-loop; returns latency/shed/served tallies."""
    lats, lock = [], threading.Lock()
    futs, shed = [], 0
    t0 = time.perf_counter()
    for t_arr, tenant, query in events:
        now = time.perf_counter() - t0
        if t_arr > now:
            time.sleep(t_arr - now)
        t_submit = time.monotonic()
        try:
            fut = tier.submit_sigma(tenant, query)
        except shed_error:
            shed += 1
            continue

        def record(f, t_submit=t_submit):
            if f.cancelled() or f.exception() is not None:
                return
            with lock:
                lats.append(time.monotonic() - t_submit)

        fut.add_done_callback(record)
        futs.append(fut)
    for f in futs:
        f.result(timeout=600)
    wall = time.perf_counter() - t0
    return {"lats": list(lats), "shed": shed, "admitted": len(futs),
            "wall_s": wall}


def run(n=500, deg=6.0, colors=64, batches=6, master_seed=0,
        replica_counts=(1, 2), deadlines_ms=(10,), quota_qps=(5.0, 50.0),
        offered_qps=60.0, arrivals=180, tenant_weights=(0.5, 0.3, 0.2),
        out=print, json_path="BENCH_serve_load.json"):
    from repro.graph import csr, generators
    from repro.sampling import SamplerSpec
    from repro.serve.influence import PoolConfig, SketchStore
    from repro.serve.tier import ServingTier, ShedError

    params = {"n": n, "deg": deg, "colors": colors, "batches": batches,
              "master_seed": master_seed,
              "replica_counts": list(replica_counts),
              "deadlines_ms": list(deadlines_ms),
              "quota_qps": list(quota_qps), "offered_qps": offered_qps,
              "arrivals": arrivals, "tenant_weights": list(tenant_weights)}
    g = csr.dedupe(generators.powerlaw_cluster(n, deg, prob=0.25, seed=29))
    base = SketchStore(g, PoolConfig(
        max_batches=max(batches, 8),
        spec=SamplerSpec(num_colors=colors, master_seed=master_seed)))
    t0 = time.perf_counter()
    base.ensure(batches)
    sample_s = time.perf_counter() - t0
    base.visited_stack()                    # compile/stage outside the sweep

    rows = []
    cell_seed = 0
    for replicas in replica_counts:
        for deadline_ms in deadlines_ms:
            for quota in quota_qps:
                cell_seed += 1
                tenants = {f"tenant{i}": offered_qps * w
                           for i, w in enumerate(tenant_weights)}
                events = _schedule(tenants, arrivals, n, seed=cell_seed)
                tier = ServingTier.build(
                    base.clone(), replicas=replicas, quota_qps=None,
                    default_deadline=deadline_ms / 1e3)
                # Cell quota meters tenant0 only: the cell's shed axis.
                tier.set_quota("tenant0", rate=quota, burst=quota)
                # Warm each replica's compiled σ program out of the path.
                tier.gather([tier.submit_sigma(f"warm{i}", [0])
                             for i in range(replicas)])
                cell = _drive_cell(tier, events, ShedError)
                snap = tier.snapshot()
                tier.close()
                offered = len(events)
                row = {
                    "replicas": replicas,
                    "deadline_ms": deadline_ms,
                    "quota_qps": quota,
                    "offered_qps": round(offered / events[-1][0], 1),
                    "arrivals": offered,
                    "admitted": cell["admitted"],
                    "shed": cell["shed"],
                    "shed_rate": round(cell["shed"] / offered, 3),
                    "achieved_qps": round(cell["admitted"] / cell["wall_s"],
                                          1),
                    "theta": base.num_samples,
                    "sample_s": round(sample_s, 3),
                    "flushes": sum(r["flushes"] for r in snap["replicas"]),
                    "cache_hit_rate": round(
                        float(np.mean([r["cache"]["hit_rate"]
                                       for r in snap["replicas"]])), 3),
                    **_percentiles(cell["lats"]),
                }
                rows.append(row)

    out("# serve_load: replicas,deadline_ms,quota_qps,offered_qps,"
        "achieved_qps,shed_rate,p50_ms,p99_ms,p999_ms")
    for r in rows:
        out(",".join(str(r[k]) for k in
                     ("replicas", "deadline_ms", "quota_qps", "offered_qps",
                      "achieved_qps", "shed_rate", "p50_ms", "p99_ms",
                      "p999_ms")))

    import jax
    record = {"bench": "serve_load", "schema": 1,
              "unix_time": int(time.time()),
              "env": {"backend": jax.default_backend(),
                      "devices": jax.device_count(),
                      "jax": jax.__version__},
              "params": params, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows)} rows)")
    return record


if __name__ == "__main__":
    run()
