"""§Roofline aggregation: read every dry-run JSON under results/ and print
the per-(arch × shape × mesh) table with the three terms, the dominant
bottleneck, and the useful-FLOPs fraction."""
from __future__ import annotations

import glob
import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def load_records(mesh_filter=None):
    recs = []
    for f in sorted(glob.glob(str(RESULTS / "dryrun_*.json"))):
        r = json.load(open(f))
        if mesh_filter and r.get("mesh") != mesh_filter:
            continue
        recs.append(r)
    return recs


def table(out=print, mesh_filter=None):
    recs = load_records(mesh_filter)
    out("# Roofline: arch,shape,mesh,status,compute_s,memory_s,"
        "collective_s,dominant,useful_frac,arg_GB,temp_GB")
    rows = []
    for r in recs:
        if r["status"] == "skipped":
            row = (r["arch"], r["shape"], r["mesh"], "skipped", "-", "-",
                   "-", "-", "-", "-", "-")
        elif r["status"] != "ok":
            row = (r["arch"], r["shape"], r["mesh"], "ERROR", "-", "-",
                   "-", "-", "-", "-", "-")
        else:
            rt = r["roofline"]
            mem = r.get("memory", {})
            uf = rt.get("useful_fraction")
            row = (r["arch"], r["shape"], r["mesh"], "ok",
                   f"{rt['compute_s']:.4f}", f"{rt['memory_s']:.4f}",
                   f"{rt['collective_s']:.4f}", rt["dominant"],
                   f"{uf:.3f}" if uf is not None else "-",
                   f"{(mem.get('argument_bytes') or 0)/1e9:.2f}",
                   f"{(mem.get('temp_bytes') or 0)/1e9:.2f}")
        rows.append(row)
        out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    table()
