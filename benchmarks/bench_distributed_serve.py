"""Distributed serving: query throughput / latency vs shard count + deadline.

Drives the full stack — `ShardedSketchStore` → `DistributedQueryEngine` →
`MicroBatcher` → `AsyncFrontEnd` — on a forced 8-device CPU host mesh (the
same trick the multi-device equivalence tests use), sweeping the pool's
shard count and the front-end flush deadline.  A burst of threaded clients
submits σ(S) queries; per-query latency is measured submit → future-done.

The sweep runs in a **subprocess** so the forced device count never leaks
into the parent (benchmarks share a process with single-device benches).

Emits the standard ``BENCH_<name>.json`` shape (this bench defines it —
the perf trajectory starts accumulating here)::

    {"bench": ..., "schema": 1, "unix_time": ..., "env": {...},
     "params": {...}, "rows": [{...}, ...]}

Shard count on a CPU host mesh does not speed anything up (all "devices"
share the same silicon) — the point is the *trajectory*: the same rows on
a real pod plot coverage-reduction scaling, and deadline vs p50/p99 shows
the batching-latency trade straight away.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_DEVICES = 8


# ------------------------------------------------------------------ worker
def _worker(args: dict) -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_DEVICES}").strip()
    import threading

    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.graph import generators
    from repro.serve.distributed import (AsyncFrontEnd,
                                         DistributedQueryEngine,
                                         ShardedSketchStore)
    from repro.serve.influence import MicroBatcher, PoolConfig, ResultCache

    g = generators.powerlaw_cluster(args["n"], args["deg"],
                                    prob=(0.0, 0.25), seed=11)
    n = g.num_vertices
    for shards in args["shard_counts"]:
        mesh = Mesh(np.array(jax.devices()[:shards]), ("data",))
        store = ShardedSketchStore(
            g, PoolConfig(num_colors=args["colors"],
                          max_batches=args["batches"]), mesh)
        t0 = time.perf_counter()
        store.ensure(args["batches"])
        sample_s = time.perf_counter() - t0
        engine = DistributedQueryEngine(store)
        engine.sigma([[0]])                     # compile outside the sweep
        for deadline_ms in args["deadlines_ms"]:
            fe = AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                               default_deadline=deadline_ms / 1e3)
            rng = np.random.default_rng(shards * 1000 + deadline_ms)
            queries = [rng.integers(0, n, 3).tolist()
                       for _ in range(args["clients"])]
            lats, lock = [], threading.Lock()

            def client(q):
                t0 = time.monotonic()
                fut = fe.submit_sigma(q)
                fut.result(timeout=600)
                with lock:
                    lats.append(time.monotonic() - t0)

            threads = [threading.Thread(target=client, args=(q,))
                       for q in queries]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            fe.close()
            lats_ms = np.sort(np.asarray(lats)) * 1e3
            row = {
                "shards": shards,
                "pool_batches": len(store.batches),
                "theta": store.num_samples,
                "sample_s": round(sample_s, 3),
                "deadline_ms": deadline_ms,
                "clients": args["clients"],
                "qps": round(len(lats) / wall, 1),
                "p50_ms": round(float(np.percentile(lats_ms, 50)), 2),
                "p99_ms": round(float(np.percentile(lats_ms, 99)), 2),
                "flushes": fe.stats.flushes,
                "deadline_flushes": fe.stats.deadline_flushes,
                "max_queue_wait_ms": round(fe.stats.max_queue_wait * 1e3, 1),
            }
            print("ROW " + json.dumps(row), flush=True)
    print("ENV " + json.dumps({"backend": jax.default_backend(),
                               "devices": _DEVICES,
                               "jax": jax.__version__}), flush=True)


# ------------------------------------------------------------------ driver
def run(n=800, deg=8.0, colors=64, batches=8, shard_counts=(1, 2, 4, 8),
        deadlines_ms=(5, 25), clients=48, out=print,
        json_path="BENCH_distributed_serve.json"):
    params = {"n": n, "deg": deg, "colors": colors, "batches": batches,
              "shard_counts": list(shard_counts),
              "deadlines_ms": list(deadlines_ms), "clients": clients}
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), json.dumps(params)],
        capture_output=True, text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed:\n{proc.stdout}\n{proc.stderr}")
    rows, bench_env = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            rows.append(json.loads(line[4:]))
        elif line.startswith("ENV "):
            bench_env = json.loads(line[4:])

    out("# distributed serve: shards,theta,deadline_ms,clients,qps,"
        "p50_ms,p99_ms,flushes,max_queue_wait_ms")
    for r in rows:
        out(",".join(str(r[k]) for k in
                     ("shards", "theta", "deadline_ms", "clients", "qps",
                      "p50_ms", "p99_ms", "flushes", "max_queue_wait_ms")))

    record = {"bench": "distributed_serve", "schema": 1,
              "unix_time": int(time.time()), "env": bench_env,
              "params": params, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows)} rows)")
    return record


if __name__ == "__main__":
    if len(sys.argv) > 1:                   # worker mode: params as argv[1]
        _worker(json.loads(sys.argv[1]))
    else:
        run()
