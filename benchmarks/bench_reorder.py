"""Paper Fig. 5: vertex reordering → color occupancy (+ TPU tile metrics).

Runs each reordering heuristic on a clustered graph, then measures (a) the
paper's color occupancy during a 32-color fused traversal and (b) our
TPU-side cost model: non-empty 128×128 tile count and tile occupancy
(DESIGN.md §2 — reordering == tile densification on TPU).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import tiles, traversal
from repro.graph import generators, reorder


def run(n=4000, deg=12.0, colors=32, prob=0.25, out=print):
    g = generators.powerlaw_cluster(n, deg, prob=prob, seed=3,
                                    mixing=0.15)
    out("# Fig5: heuristic,occupancy,levels,num_tiles,tile_fill,"
        "edges_per_tile")
    rows = []
    for name in ("random", "identity", "degree", "rcm", "cluster"):
        g2, perm = reorder.apply(g, name)
        starts = traversal.random_starts(jax.random.key(1),
                                         g2.num_vertices, colors,
                                         sort=True)
        res = traversal.run_fused(g2, starts, colors, jnp.uint32(7))
        lv = int(res.stats.levels_run)
        occ = float(res.stats.occupancy_num[:lv].mean()) if lv else 0.0
        e = g2.num_edges
        from repro.graph import csr
        g2d = csr.dedupe(g2)
        tg = tiles.from_graph(g2d)
        st = tiles.tile_stats(tg)
        row = (name, round(occ, 4), lv, st["num_tiles"],
               round(st["tile_fill_fraction"], 4),
               round(g2d.num_edges / st["num_tiles"], 1))
        rows.append(row)
        out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
