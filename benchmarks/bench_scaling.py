"""Paper Figs. 10/11 + Fig. 1: scaling of fused sampling.

Real multi-node timing is out of reach in this container; we report what is
measurable and what the dry-run proves:

  * measured: single-process wall time of sample-parallel batches as the
    number of forced host devices grows (subprocess sweep, 1→8 devices) —
    the shape of the paper's Fig. 11 single-node curve;
  * derived: per-level collective bytes of the graph-parallel path and the
    zero-collective property of the sample-parallel path (from the dry-run
    records), which is the mechanism behind Fig. 10's strong scaling;
  * the per-batch idempotence + driver stats that make elastic/straggler
    behavior safe at 4K-node scale.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

_HERE = pathlib.Path(__file__).resolve().parent
_SRC = str(_HERE.parent / "src")

_CHILD = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np, jax, jax.numpy as jnp
from repro.core import traversal
from repro.distributed import traversal as dtrav
from repro.graph import generators

n_dev = int(sys.argv[1])
g = generators.powerlaw_cluster(3000, 10.0, prob=0.2, seed=1)
mesh = jax.make_mesh((n_dev,), ("data",))
B, C = 16, 64
starts = jnp.stack([
    traversal.random_starts(jax.random.key(b), g.num_vertices, C)
    for b in range(B)])
seeds = jnp.arange(B, dtype=jnp.uint32)
vis = dtrav.sample_parallel_visited(g, starts, seeds, C, mesh)  # compile
jax.block_until_ready(vis)
t0 = time.perf_counter()
for _ in range(3):
    jax.block_until_ready(
        dtrav.sample_parallel_visited(g, starts, seeds, C, mesh))
print(json.dumps({"devices": n_dev,
                  "seconds": (time.perf_counter() - t0) / 3}))
"""


def run(device_counts=(1, 2, 4, 8), out=print):
    out("# Fig10/11: devices,seconds,speedup_vs_1")
    rows = []
    base = None
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    for n in device_counts:
        proc = subprocess.run([sys.executable, "-c", _CHILD, str(n)],
                              capture_output=True, text=True, env=env,
                              timeout=900)
        if proc.returncode != 0:
            out(f"{n},ERROR,{proc.stderr[-200:]}")
            continue
        rec = json.loads(proc.stdout.strip().splitlines()[-1])
        if base is None:
            base = rec["seconds"]
        row = (n, round(rec["seconds"], 4),
               round(base / rec["seconds"], 2))
        rows.append(row)
        out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
