"""Butterfly frontier-exchange micro-bench: log(M) pairwise stages vs the
flat model-axis all-gather, at dialed frontier densities.

The graph-parallel backend's per-level exchange has two legs
(`repro.distributed.traversal._frontier_gather_loop`): the flat
``all_gather`` always ships ``S·(S−1)·rows·W`` packed words, while the
ButterFly-BFS-style leg (arXiv 2103.13577) compacts the frontier to
``(word_idx, word)`` pairs and disseminates them over ``⌈log₂ S⌉``
``ppermute`` stages — traffic proportional to what's actually lit.  This
bench isolates ONE exchange (no traversal around it) on a forced
8-device host mesh: for each (shard count, active-word count) cell both
legs reconstruct the same global frontier (asserted bit-identical), and
the rows record measured wall time next to the analytic words moved —
the crossover the `gather_capacity_words` auto-capacity targets.

S = 6 exercises the non-power-of-two dissemination schedule (stage
overlap deduped by the ``have`` bitmap).  Runs in a subprocess so the
forced device count never leaks into the parent.  Emits the standard
``BENCH_<name>.json`` shape.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_DEVICES = 8


# ------------------------------------------------------------------ worker
def _worker(args: dict) -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_DEVICES}").strip()
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.distributed import traversal
    from repro.distributed.compat import shard_map

    rows, num_words, iters = args["rows"], args["num_words"], args["iters"]
    n = rows * num_words
    rng = np.random.default_rng(5)

    for s in args["shard_counts"]:
        mesh = Mesh(np.array(jax.devices()[:s]), ("model",))
        cap = traversal.gather_capacity_words(rows, num_words, 0)

        def dense_leg(fr):
            return jax.lax.all_gather(fr, "model", tiled=True)

        def butterfly_leg(fr):
            buf_i, buf_w, sent = traversal._butterfly_exchange(
                fr, "model", s, n, cap)
            full = traversal._scatter_pairs(buf_i, buf_w, rows,
                                            num_words, s)
            return full, jax.lax.psum(sent, "model")

        dense = jax.jit(shard_map(dense_leg, mesh, in_specs=P("model"),
                                  out_specs=P(), check=False))
        bf = jax.jit(shard_map(butterfly_leg, mesh, in_specs=P("model"),
                               out_specs=(P(), P()), check=False))

        for active in args["active_words"]:
            if active > cap:
                continue        # the loop's lax.cond takes the dense leg
            # `active` lit words per shard, distinct positions, nonzero
            # payloads — the compaction's worst case for that density.
            fr = np.zeros((s, n), np.uint32)
            for i in range(s):
                pos = rng.choice(n, size=active, replace=False)
                fr[i, pos] = rng.integers(1, 2 ** 32, active,
                                          dtype=np.uint64).astype(np.uint32)
            fr = jnp.asarray(fr.reshape(s * rows, num_words))

            ref = dense(fr)
            got, sent = bf(fr)
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))

            def clock(fn):
                jax.block_until_ready(fn(fr))
                t0 = time.perf_counter()
                for _ in range(iters):
                    jax.block_until_ready(fn(fr))
                return (time.perf_counter() - t0) / iters * 1e3

            dense_words = s * (s - 1) * n
            row = {
                "shards": s, "rows": rows, "num_words": num_words,
                "capacity_words": cap, "active_words": active,
                "dense_words": dense_words,
                "butterfly_words": int(sent),
                "traffic_ratio": round(dense_words / max(int(sent), 1), 2),
                "dense_ms": round(clock(dense), 3),
                "butterfly_ms": round(clock(lambda x: bf(x)[0]), 3),
            }
            print("ROW " + json.dumps(row), flush=True)
    print("ENV " + json.dumps({"backend": jax.default_backend(),
                               "devices": _DEVICES,
                               "jax": jax.__version__}), flush=True)


# ------------------------------------------------------------------ driver
def run(rows=4096, num_words=2, shard_counts=(8, 6),
        active_words=(64, 256, 1024), iters=10, out=print,
        json_path="BENCH_butterfly_exchange.json"):
    params = {"rows": rows, "num_words": num_words,
              "shard_counts": list(shard_counts),
              "active_words": list(active_words), "iters": iters}
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), json.dumps(params)],
        capture_output=True, text=True, env=env, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed:\n{proc.stdout}\n{proc.stderr}")
    rows_out, bench_env = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            rows_out.append(json.loads(line[4:]))
        elif line.startswith("ENV "):
            bench_env = json.loads(line[4:])

    out("# butterfly exchange: shards,active_words,dense_words,"
        "butterfly_words,traffic_ratio,dense_ms,butterfly_ms")
    for r in rows_out:
        out(",".join(str(r[k]) for k in
                     ("shards", "active_words", "dense_words",
                      "butterfly_words", "traffic_ratio", "dense_ms",
                      "butterfly_ms")))

    record = {"bench": "butterfly_exchange", "schema": 1,
              "unix_time": int(time.time()), "env": bench_env,
              "params": params, "rows": rows_out}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows_out)} rows)")
    return record


if __name__ == "__main__":
    if len(sys.argv) > 1:                   # worker mode: params as argv[1]
        _worker(json.loads(sys.argv[1]))
    else:
        run()
