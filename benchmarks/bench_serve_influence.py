"""Online serving: query throughput/latency vs sketch-pool size.

For each pool size the bench times (a) a cold mixed micro-batched flush
(top-k + σ(S) + marginal — includes jit compile on the first size), (b) a
warm flush of fresh σ(S)/marginal queries reusing the compiled programs,
and (c) a fully cached re-flush, reporting per-query latency and
queries/sec.  Shows the amortization story: pool sampling is paid once,
per-query cost stays flat as the pool (and estimate quality) grows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.graph import generators
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


def _mixed_load(batcher, rng, n, k, num_queries):
    batcher.submit_top_k(k)
    for _ in range(num_queries):
        batcher.submit_sigma(rng.integers(0, n, rng.integers(1, 5)).tolist())
        batcher.submit_marginal(rng.integers(0, n, 2).tolist())
    return 1 + 2 * num_queries


def run(n=1500, deg=8.0, colors=64, pool_sizes=(2, 4, 8, 16), k=4,
        num_queries=12, out=print):
    out("# serve: pool_batches,theta,sample_s,cold_flush_s,warm_flush_s,"
        "warm_q_per_s,cached_flush_s,dispatches")
    g = generators.powerlaw_cluster(n, deg, prob=(0.0, 0.25), seed=11)
    store = SketchStore(g, PoolConfig(num_colors=colors,
                                      max_batches=max(pool_sizes)))
    engine = QueryEngine(store)
    rows = []
    for size in pool_sizes:
        t0 = time.perf_counter()
        store.ensure(size)
        sample_s = time.perf_counter() - t0

        batcher = MicroBatcher(engine, cache=ResultCache())
        rng = np.random.default_rng(size)
        nq = _mixed_load(batcher, rng, n, k, num_queries)
        t0 = time.perf_counter()
        batcher.flush()
        cold_s = time.perf_counter() - t0

        rng2 = np.random.default_rng(size + 1000)
        nq = _mixed_load(batcher, rng2, n, k, num_queries)
        t0 = time.perf_counter()
        batcher.flush()
        warm_s = time.perf_counter() - t0

        _mixed_load(batcher, np.random.default_rng(size + 1000), n, k,
                    num_queries)
        t0 = time.perf_counter()
        batcher.flush()
        cached_s = time.perf_counter() - t0

        row = (size, store.num_samples, round(sample_s, 3), round(cold_s, 3),
               round(warm_s, 3), round(nq / max(warm_s, 1e-9), 1),
               round(cached_s, 5), batcher.dispatches)
        rows.append(row)
        out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
