"""Paper Fig. 4: edge-access savings of fused vs unfused BPTs, and average
color occupancy, swept over degree × colors × traversal probability.

LFR-like power-law graphs (10k vertices, degrees 4/11/16 as in §3.2);
statistics from the coupled-RNG instrumentation of core/traversal.py, so
fused and unfused counts come from the SAME realizations (no sampling gap).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import traversal
from repro.graph import generators


def run(n=2000, degrees=(4, 11, 16), colors=(32, 64, 128),
        probs=(0.05, 0.1, 0.2, 0.3, 0.5), seeds=(0, 1, 2), out=print):
    out("# Fig4: degree,colors,prob,fused_visits,unfused_visits,"
        "savings_pct,occupancy,levels,us_per_bpt")
    rows = []
    for deg in degrees:
        for seed in seeds:
            g = generators.powerlaw_cluster(n, deg, prob=0.3, seed=seed)
            for c in colors:
                for p in probs:
                    e = g.num_edges
                    src = np.asarray(g.src)[:e]
                    dst = np.asarray(g.dst)[:e]
                    from repro.graph import csr
                    gp = csr.from_edges(src, dst,
                                        np.full(e, p, np.float32),
                                        g.num_vertices)
                    starts = traversal.random_starts(
                        jax.random.key(seed), g.num_vertices, c)
                    t0 = time.perf_counter()
                    res = traversal.run_fused(gp, starts, c,
                                              jnp.uint32(seed))
                    jax.block_until_ready(res.visited)
                    dt = time.perf_counter() - t0
                    fused = int(res.stats.fused_edge_visits.sum())
                    unfused = int(res.stats.unfused_edge_visits.sum())
                    sav = 100 * (1 - fused / max(unfused, 1))
                    lv = int(res.stats.levels_run)
                    occ = float(res.stats.occupancy_num[:lv].mean()) if lv \
                        else 0.0
                    row = (deg, c, p, fused, unfused, round(sav, 2),
                           round(occ, 4), lv, round(1e6 * dt / c, 1))
                    rows.append(row)
                    out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
