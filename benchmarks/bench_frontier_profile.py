"""Paper Fig. 9: frontier occupancy per traversal level.

GPU metric was wavefronts queued vs 440 SIMD units; the TPU analogue
(DESIGN.md §2) is the fraction of 128-row tiles containing ≥1 active
vertex — the dense-sweep utilization of the expansion kernel — plus the
frontier width (active vertices / colors) per level.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import traversal
from repro.graph import generators


def run(n=4000, deg=12.0, colors=(1, 8, 32), probs=(0.05, 0.2), out=print):
    out("# Fig9: colors,prob,level,frontier_vertices,frontier_colors,"
        "active_tile_frac")
    rows = []
    for p in probs:
        g = generators.powerlaw_cluster(n, deg, prob=p, seed=5)
        for c in colors:
            starts = traversal.random_starts(jax.random.key(2), n, c)
            res = traversal.run_fused(g, starts, c, jnp.uint32(3))
            lv = int(res.stats.levels_run)
            for level in range(lv):
                row = (c, p, level,
                       int(res.stats.frontier_vertices[level]),
                       int(res.stats.frontier_colors[level]),
                       round(float(res.stats.active_tile_frac[level]), 4))
                rows.append(row)
                out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
