"""Paper Fig. 9: frontier occupancy per traversal level, plus the
bucket-occupancy histogram the sparse-frontier capacity knob needs.

GPU metric was wavefronts queued vs 440 SIMD units; the TPU analogue
(DESIGN.md §2) is the fraction of 128-row tiles containing ≥1 active
vertex — the dense-sweep utilization of the expansion kernel — plus the
frontier width (active vertices / colors) per level.

The second section drives `core.sparse.profile_traversal` (the REAL
compacted execution, host-paced) and histograms, per level, which rung of
the capacity-bucket ladder the level lands in and how full that bucket
runs.  That histogram is the evidence `SamplerSpec.frontier_capacity`
wants: if most levels land in (and mostly fill) one small bucket, pin the
knob there for a two-rung ladder; a spread across rungs says keep the
auto ladder.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import rrr, sparse, traversal
from repro.graph import csr, generators


def run(n=4000, deg=12.0, colors=(1, 8, 32), probs=(0.05, 0.2), out=print):
    out("# Fig9: colors,prob,level,frontier_vertices,frontier_colors,"
        "active_tile_frac")
    rows = []
    for p in probs:
        g = generators.powerlaw_cluster(n, deg, prob=p, seed=5)
        for c in colors:
            starts = traversal.random_starts(jax.random.key(2), n, c)
            res = traversal.run_fused(g, starts, c, jnp.uint32(3))
            lv = int(res.stats.levels_run)
            for level in range(lv):
                row = (c, p, level,
                       int(res.stats.frontier_vertices[level]),
                       int(res.stats.frontier_colors[level]),
                       round(float(res.stats.active_tile_frac[level]), 4))
                rows.append(row)
                out(",".join(str(x) for x in row))
    bucket_histogram(n=n, deg=deg, out=out)
    return rows


def bucket_histogram(n=4000, deg=12.0, colors=64, probs=(0.05, 0.2),
                     tile_rows=64, batches=4, master_seed=7, out=print):
    """Bucket-occupancy histogram over the ladder's rungs.

    For each prob: run ``batches`` real compacted traversals
    (`sparse.profile_traversal`), bin every level by the ladder rung it
    picks, and report per rung: level count, mean active-edge-block
    occupancy (active / rung capacity), and the share of total
    fused-edge work done at that rung.
    """
    out("# bucket histogram: prob,bucket,levels,mean_occupancy,work_share")
    rows = []
    for p in probs:
        g = csr.dedupe(generators.powerlaw_cluster(n, deg, prob=(0.0, p),
                                                   seed=5))
        fidx = sparse.build_frontier_index(csr.transpose(g),
                                           tile_rows=tile_rows)
        ladder = sparse.bucket_ladder(fidx.num_blocks)
        levels = []
        for bi in range(batches):
            starts = rrr.batch_starts(g.num_vertices, colors, master_seed, bi)
            levels += sparse.profile_traversal(
                fidx, starts, colors, rrr.batch_seed(master_seed, bi))
        total_work = max(sum(r["fused_edge_visits"] for r in levels), 1)
        for rung in ladder:
            hit = [r for r in levels if r["bucket"] == rung]
            if not hit:
                continue
            row = (p, rung, len(hit),
                   round(float(np.mean([r["active_edge_blocks"] / rung
                                        for r in hit])), 3),
                   round(sum(r["fused_edge_visits"] for r in hit)
                         / total_work, 3))
            rows.append(row)
            out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
