"""Streaming-delta refresh cost: churn fraction × backend →
incremental refresh seconds vs cold pool rebuild seconds.

The claim under test is the `repro.stream` design premise: after a graph
delta, refresh cost should scale with **churn** (the fraction of
`FrontierIndex` row-blocks the delta touches, which bounds the dirty
slot set) — not with |V| + |E| like the cold rebuild a static-topology
pool forces.  Two sweeps on a forced 8-device CPU host:

* ``churn`` — one graph, one warm pool per cell, deltas dialed to touch
  2% … 25% of the row-blocks (delta endpoints confined to a chosen
  block subset), under the ``dense`` single-device and
  ``data_parallel`` sharded backends.  Churn here is *row-block*
  fraction, not edge fraction: the dirty-set math is over row-blocks, so
  this is the axis the subsystem's cost curve is defined on (an
  edge-fraction dial would touch nearly every block of a power-law
  graph long before 10%).
* ``scale`` — fixed ~5% churn while |V| grows ×4: incremental seconds
  should track the (roughly constant) dirty slot count, while the cold
  rebuild grows with the graph.

Timing protocol: the initial ``ensure`` + stack staging warm every
traced program, an untimed tombstone delta warms the incremental path
(post-delta sampler build + dirty-slot resample) and supplies
resurrection targets, then the measured delta is shape-preserving
(resurrect + tombstone — the steady-state churn shape), so per churn
level the timers see

* ``incr_s`` — `stream.incremental_refresh`: graph swap + sampler
  rebuild + dirty-slot resample through the donated slot scatter;
* ``cold_s`` — a fresh store's ``ensure`` of the same batch count on
  the SAME mutated graph pair (+ its stack staging, the serving asset).

Each cell asserts the incremental pool is bit-identical — masks and
instrumented work counters — to the cold rebuild before its row is
emitted, so every recorded speedup is a *verified-equal* result.

Runs in a subprocess so the forced device count never leaks into the
parent.  Emits the standard ``BENCH_<name>.json`` shape.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_DEVICES = 8


# ------------------------------------------------------------------ worker
def _pick_edges(store, rows, count, rng, margin=64):
    """``count`` live forward-edge positions with dst in ``rows``,
    non-trailing in BOTH orientations (a tail delete in either the
    forward graph or ``g_rev`` would trim, changing the static array
    shapes the steady-state measurement wants stable)."""
    import numpy as np

    g, gr = store.graph, store.g_rev
    e, er = g.num_edges, gr.num_edges
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    prob = np.asarray(g.prob)[:e]
    allowed = np.zeros(g.num_vertices, bool)
    allowed[rows] = True
    cand = np.nonzero((prob > 0) & allowed[dst])[0]
    cand = cand[cand < e - margin]
    rkeys = ((np.asarray(gr.src)[:er].astype(np.int64) << 32)
             | np.asarray(gr.dst)[:er].astype(np.int64))
    order = np.argsort(rkeys, kind="stable")
    want = ((dst[cand].astype(np.int64) << 32)
            | src[cand].astype(np.int64))
    rpos = order[np.searchsorted(rkeys[order], want)]
    cand = cand[rpos < er - margin]
    return rng.choice(cand, size=min(count, len(cand)), replace=False)


def _run_cell(g, cfg, mesh, churn, delta_edges, rng, make_store):
    """One (backend, churn) measurement on a fresh warm pool.

    The measured delta is the steady-state shape: edges flipping out
    (tombstone) and back in (resurrect) within the churn window.  Both
    ops keep ``num_edges``/``padded_edges``, so the timed incremental
    refresh is pure dirty-slot work — no jit recompile rides along (an
    untimed tombstone-making delta warms that path AND supplies the
    resurrection targets).  Fresh-insert deltas pay one extra recompile
    by design (static shape change) — a cost both paths share.
    """
    import numpy as np

    from repro import stream

    store = make_store(g, cfg, mesh)
    store.ensure(cfg.max_batches)
    store.visited_stack()
    tracker = stream.DirtySlotTracker.for_store(store)

    nrb = tracker.num_row_blocks
    blocks = rng.choice(nrb, size=max(1, round(churn * nrb)), replace=False)
    rows = np.concatenate([np.arange(b * tracker.tile_rows,
                                     min((b + 1) * tracker.tile_rows,
                                         g.num_vertices))
                           for b in blocks])

    # Untimed warm delta: tombstone half the churn set (also warms the
    # incremental path: post-delta sampler build + dirty-slot resample).
    k = delta_edges // 2
    out_pos = _pick_edges(store, rows, k, rng)
    src0 = np.asarray(store.graph.src)[out_pos].copy()
    dst0 = np.asarray(store.graph.dst)[out_pos].copy()
    w0 = np.asarray(store.graph.prob)[out_pos].copy()
    stream.incremental_refresh(store, tracker,
                               stream.EdgeDelta.deletes(src0, dst0))

    # Measured delta: resurrect those edges + tombstone k fresh ones.
    shapes = (store.graph.num_edges, store.graph.padded_edges,
              store.g_rev.num_edges, store.g_rev.padded_edges)
    next_pos = _pick_edges(store, rows, k, rng)
    delta = stream.EdgeDelta.concat(
        stream.EdgeDelta.inserts(src0, dst0, w0),
        stream.EdgeDelta.deletes(np.asarray(store.graph.src)[next_pos],
                                 np.asarray(store.graph.dst)[next_pos]))
    # Warm the exact dirty-slot count: the block samplers trace per block
    # SIZE (lax.map length / shard pad), so resampling this plan's slots
    # on the un-mutated graph (a semantic no-op — same streams, same
    # graph) compiles what the timed refresh will run.
    plan = stream.plan_refresh(store, tracker, delta)
    store.resample_slots(plan.dirty_slots)
    report = stream.incremental_refresh(store, tracker, delta)
    assert (store.graph.num_edges, store.graph.padded_edges,
            store.g_rev.num_edges, store.g_rev.padded_edges) == shapes

    t0 = time.perf_counter()
    cold = make_store(store.graph, cfg, mesh, g_rev=store.g_rev)
    cold.ensure(cfg.max_batches)
    cold.visited_stack()
    cold_s = time.perf_counter() - t0

    for bi, bc in zip(store.batches, cold.batches):
        np.testing.assert_array_equal(np.asarray(bi.visited),
                                      np.asarray(bc.visited))
        assert bi.fused_edge_visits == bc.fused_edge_visits
    return report, cold_s


def _worker(args: dict) -> None:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_DEVICES}").strip()
    import jax
    import numpy as np

    from repro import sampling
    from repro.graph import csr, generators
    from repro.serve.distributed import ShardedSketchStore
    from repro.serve.influence import PoolConfig, SketchStore

    def make_store(g, cfg, mesh, g_rev=None):
        if mesh is None:
            return SketchStore(g, cfg, g_rev=g_rev)
        return ShardedSketchStore(g, cfg, mesh, g_rev=g_rev)

    for sweep in args["sweeps"]:
        g = csr.dedupe(generators.powerlaw_cluster(
            sweep["n"], sweep["deg"], prob=tuple(sweep["prob"]), seed=11))
        for backend, shards in sweep["backends"]:
            mesh = (jax.make_mesh((shards,), ("data",))
                    if backend == "data_parallel" else None)
            spec = sampling.SamplerSpec(
                diffusion="ic", backend=backend,
                num_colors=sweep["colors"], master_seed=7,
                tile_size=sweep["tile"], frontier=sweep["frontier"])
            cfg = PoolConfig(max_batches=sweep["batches"], spec=spec)
            for churn in sweep["churn"]:
                rng = np.random.default_rng(5)
                report, cold_s = _run_cell(g, cfg, mesh, churn,
                                           sweep["delta_edges"], rng,
                                           make_store)
                row = {
                    "sweep": sweep["name"],
                    "backend": backend,
                    "n": sweep["n"],
                    "edges": g.num_edges,
                    "churn": churn,
                    "batches": sweep["batches"],
                    "colors": sweep["colors"],
                    "delta_edges": report.inserted + report.deleted,
                    "touched_row_blocks": report.touched_row_blocks,
                    "row_blocks": -(-sweep["n"] // sweep["tile"]),
                    "dirty_slots": report.dirty_slots,
                    "total_slots": report.total_slots,
                    "dirty_fraction": round(report.dirty_fraction, 4),
                    "incr_s": round(report.refresh_s, 3),
                    "cold_s": round(cold_s, 3),
                    "speedup": round(cold_s / max(report.refresh_s, 1e-9),
                                     2),
                }
                print("ROW " + json.dumps(row), flush=True)
    print("ENV " + json.dumps({"backend": jax.default_backend(),
                               "devices": _DEVICES,
                               "jax": jax.__version__}), flush=True)


# ------------------------------------------------------------------ driver
def standard_sweeps(churn_n=12000, scale_ns=(6000, 12000, 24000),
                    batches=16) -> list[dict]:
    """The two recorded sweeps (scaled down by callers like run.py).

    The cells sit in the pool's LOCALITY regime: few colors per slot and
    collapsing traversals (tiny edge probabilities), so each slot's
    visited-row-block footprint is a small fraction of the graph and a
    confined delta dirties a churn-proportional slot subset.  A
    64-colors-per-slot pool on a well-connected graph is the opposite
    regime — the union of 64 traversals covers most blocks, every delta
    dirties every slot, and incremental ≈ cold by construction (the
    subsystem is honest about that: `dirty_fraction` says so)."""
    return [
        dict(name="churn", n=churn_n, deg=16.0, prob=(0.0, 0.03),
             colors=8, tile=64, batches=batches, frontier="sparse",
             delta_edges=16, churn=[0.02, 0.05, 0.10, 0.25],
             backends=[["dense", 1], ["data_parallel", 4]]),
    ] + [
        dict(name="scale", n=n, deg=16.0, prob=(0.0, 0.03),
             colors=8, tile=64, batches=batches, frontier="sparse",
             delta_edges=16, churn=[0.05], backends=[["dense", 1]])
        for n in scale_ns
    ]


def run(sweeps=None, out=print, json_path="BENCH_stream_updates.json"):
    params = {"sweeps": [dict(s, prob=list(s["prob"]))
                         for s in (sweeps or standard_sweeps())]}
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), json.dumps(params)],
        capture_output=True, text=True, env=env, timeout=2400)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed:\n{proc.stdout}\n{proc.stderr}")
    rows, bench_env = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            rows.append(json.loads(line[4:]))
        elif line.startswith("ENV "):
            bench_env = json.loads(line[4:])

    out("# stream updates: sweep,backend,n,churn,touched_row_blocks,"
        "dirty_slots,total_slots,incr_s,cold_s,speedup")
    for r in rows:
        out(",".join(str(r[k]) for k in
                     ("sweep", "backend", "n", "churn",
                      "touched_row_blocks", "dirty_slots", "total_slots",
                      "incr_s", "cold_s", "speedup")))

    record = {"bench": "stream_updates", "schema": 1,
              "unix_time": int(time.time()), "env": bench_env,
              "params": params, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows)} rows)")
    return record


if __name__ == "__main__":
    if len(sys.argv) > 1:                   # worker mode: params as argv[1]
        _worker(json.loads(sys.argv[1]))
    else:
        run()
