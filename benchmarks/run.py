"""Benchmark driver: one bench per paper table/figure + kernel micros +
the roofline table from dry-run records.  ``python -m benchmarks.run``.

Sizes are scaled for CPU wall-clock sanity; every bench accepts kwargs for
full-size runs on real hardware.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import (bench_work_savings, bench_reorder,
                            bench_fused_vs_unfused, bench_frontier_profile,
                            bench_kernels, bench_imm, bench_scaling,
                            bench_serve_influence, bench_distributed_serve,
                            bench_serve_load, bench_pool_build,
                            bench_stream_updates, bench_scatter_words,
                            bench_butterfly_exchange, roofline)

    sections = [
        ("Fig4 work savings / occupancy", lambda: bench_work_savings.run(
            n=1200, degrees=(4, 11), colors=(32, 64),
            probs=(0.1, 0.3), seeds=(0,))),
        ("Fig5 reordering", lambda: bench_reorder.run(n=2000)),
        ("Fig7/8 fused vs unfused", lambda: bench_fused_vs_unfused.run(
            n=1500, colors=(8, 32), probs=(0.1, 0.2))),
        ("Fig9 frontier profile", lambda: bench_frontier_profile.run(
            n=2000, colors=(1, 32), probs=(0.2,))),
        ("kernel micros", bench_kernels.run),
        ("scatter_or_words packed fast path",
         lambda: bench_scatter_words.run(rows=1 << 12,
                                         counts=(1 << 8, 1 << 11))),
        ("Butterfly frontier exchange vs flat all-gather "
         "(8 forced CPU devices)",
         lambda: bench_butterfly_exchange.run(
             rows=1 << 11, shard_counts=(8, 6),
             active_words=(64, 256), iters=5)),
        ("IMM end-to-end", lambda: bench_imm.run(theta_cap=2048)),
        ("Online serving: throughput vs pool size",
         lambda: bench_serve_influence.run(n=1000, pool_sizes=(2, 4, 8))),
        ("Distributed serving: shards × deadline (8 forced CPU devices)",
         lambda: bench_distributed_serve.run(
             n=600, batches=8, shard_counts=(1, 4, 8),
             deadlines_ms=(5, 25), clients=32)),
        ("Serving tier SLO: open-loop load vs replicas × quota",
         lambda: bench_serve_load.run(n=400, batches=4, arrivals=120,
                                      offered_qps=60.0)),
        ("Pool build: backend × frontier × diffusion (8 forced CPU devices)",
         lambda: bench_pool_build.run(
             sweeps=bench_pool_build.standard_sweeps(low_n=1500, gp_n=600,
                                                     batches=8))),
        ("Streaming deltas: incremental vs cold refresh × churn "
         "(8 forced CPU devices)",
         lambda: bench_stream_updates.run(
             sweeps=bench_stream_updates.standard_sweeps(
                 churn_n=3000, scale_ns=(3000,), batches=8))),
        ("Fig10/11 device scaling", lambda: bench_scaling.run(
            device_counts=(1, 2, 4, 8))),
        ("Roofline table (from dry-run records)", roofline.table),
    ]
    for name, fn in sections:
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception as e:          # keep the suite going
            print(f"BENCH-ERROR {name}: {type(e).__name__}: {e}")
    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
