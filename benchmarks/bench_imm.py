"""End-to-end influence maximization (the paper's application, Table-1
style): θ sampling via fused BPTs + greedy max-k-cover on SNAP-scale-down
clones, reporting seed quality (vs forward simulation) and edge-visit
savings."""
from __future__ import annotations

import time

import numpy as np

from repro.core import imm
from repro.graph import generators


# name → (n, avg_deg) scale-downs of Table 1 (full sizes in graph/datasets)
GRAPHS = {
    "web-BerkStan-mini": (3000, 11.0),
    "soc-pokec-mini": (4000, 18.0),
    "com-Orkut-mini": (2500, 30.0),
}


def run(k=8, eps=0.5, colors=64, theta_cap=4096, out=print):
    out("# IMM: graph,theta,coverage,sigma_est,sigma_fwd,visit_savings_pct,"
        "seconds")
    rows = []
    for name, (n, deg) in GRAPHS.items():
        g = generators.powerlaw_cluster(n, deg, prob=(0.0, 0.3),
                                        seed=hash(name) % 997)
        t0 = time.perf_counter()
        res = imm.run_imm(g, k=k, eps=eps, num_colors=colors,
                          theta_cap=theta_cap)
        dt = time.perf_counter() - t0
        fwd = imm.simulate_influence(g, res.seeds, num_trials=256)
        sav = 100 * (1 - res.fused_edge_visits
                     / max(res.unfused_edge_visits, 1))
        row = (name, res.theta, round(res.coverage, 4),
               round(res.sigma_estimate, 1), round(fwd, 1),
               round(sav, 2), round(dt, 2))
        rows.append(row)
        out(",".join(str(x) for x in row))
    return rows


if __name__ == "__main__":
    run()
