"""Pool build throughput: sampler backend × shard count / mesh shape →
batches/sec.

Sweeps the unified Sampler API's backends over a sketch-pool build on a
forced 8-device CPU host mesh (the multi-device test-suite trick):

* ``dense``          — one batch at a time on the default device (the
                       pre-refactor `SketchStore` path);
* ``data_parallel``  — whole batch blocks via shard_map, each shard
                       traversing its own contiguous slot slice, swept over
                       shard counts;
* ``graph_parallel`` — 2-D (data × model) meshes: destination rows sharded
                       over ``model`` (frontier all-gather per level),
                       batches over ``data``, swept over mesh shapes — the
                       collective-bound regime for graphs too big for one
                       device.

Each cell builds the SAME pool (bit-identical per slot — asserted) so the
rows measure pure build mechanics.  Shard counts on one CPU share silicon,
so CPU speedups are modest; the trajectory on a real pod is the point.

Runs in a **subprocess** so the forced device count never leaks into the
parent.  Emits the standard ``BENCH_<name>.json`` shape::

    {"bench": ..., "schema": 1, "unix_time": ..., "env": {...},
     "params": {...}, "rows": [{...}, ...]}
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_DEVICES = 8


# ------------------------------------------------------------------ worker
def _worker(args: dict) -> None:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_DEVICES}").strip()
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro import sampling
    from repro.graph import csr, generators
    from repro.serve.distributed import ShardedSketchStore
    from repro.serve.influence import PoolConfig, SketchStore

    # Dedupe once for every backend: the graph_parallel tile layout needs
    # parallel edges merged, and bit-identity needs one shared edge list.
    g = csr.dedupe(generators.powerlaw_cluster(args["n"], args["deg"],
                                               prob=(0.0, 0.25), seed=11))

    def build(backend: str, mesh_shape: tuple[int, int]):
        d, m = mesh_shape
        spec = sampling.SamplerSpec(diffusion=args["diffusion"],
                                    backend=backend,
                                    num_colors=args["colors"], master_seed=7)
        cfg = PoolConfig(max_batches=args["batches"], spec=spec)
        if backend == "dense":
            store = SketchStore(g, cfg)
        else:
            devs = np.array(jax.devices()[: d * m])
            mesh = Mesh(devs.reshape(d, m), ("data", "model")) if m > 1 \
                else Mesh(devs, ("data",))
            store = ShardedSketchStore(g, cfg, mesh)
        store.ensure(1)                          # compile outside the timing
        t0 = time.perf_counter()
        store.ensure(args["batches"])
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        store.refresh(0.5)
        refresh_s = time.perf_counter() - t0
        return store, build_s, refresh_s

    cells = ([("dense", (1, 1))]
             + [("data_parallel", (s, 1)) for s in args["shard_counts"]]
             + [("graph_parallel", tuple(dm))
                for dm in args["gp_mesh_shapes"]])
    ref_store = None
    for backend, (d, m) in cells:
        store, build_s, refresh_s = build(backend, (d, m))
        if ref_store is None:
            ref_store = store        # the measured dense row IS the reference
        for a, b in zip(ref_store.batches, store.batches):   # bit identity
            np.testing.assert_array_equal(np.asarray(a.visited),
                                          np.asarray(b.visited))
        built = args["batches"] - 1              # ensure(1) pre-built one
        row = {
            "backend": backend,
            "mesh": f"{d}x{m}",
            # Slot-shard count (== store.num_shards): the pool's batch
            # parallelism.  A graph_parallel (d, m) cell has d-way batch
            # parallelism — its m-way row partition lives in "mesh".
            "shards": getattr(store, "num_shards", 1),
            "batches": args["batches"],
            "colors": args["colors"],
            "build_s": round(build_s, 3),
            "batches_per_s": round(built / max(build_s, 1e-9), 2),
            "refresh_s": round(refresh_s, 3),
        }
        print("ROW " + json.dumps(row), flush=True)
    print("ENV " + json.dumps({"backend": jax.default_backend(),
                               "devices": _DEVICES,
                               "jax": jax.__version__}), flush=True)


# ------------------------------------------------------------------ driver
def run(n=600, deg=8.0, colors=64, batches=8, shard_counts=(1, 4, 8),
        gp_mesh_shapes=((4, 2), (2, 4)), diffusion="ic", out=print,
        json_path="BENCH_pool_build.json"):
    params = {"n": n, "deg": deg, "colors": colors, "batches": batches,
              "shard_counts": list(shard_counts),
              "gp_mesh_shapes": [list(dm) for dm in gp_mesh_shapes],
              "diffusion": diffusion}
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), json.dumps(params)],
        capture_output=True, text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed:\n{proc.stdout}\n{proc.stderr}")
    rows, bench_env = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            rows.append(json.loads(line[4:]))
        elif line.startswith("ENV "):
            bench_env = json.loads(line[4:])

    out("# pool build: backend,mesh,shards,batches,build_s,"
        "batches_per_s,refresh_s")
    for r in rows:
        out(",".join(str(r[k]) for k in
                     ("backend", "mesh", "shards", "batches", "build_s",
                      "batches_per_s", "refresh_s")))

    record = {"bench": "pool_build", "schema": 1,
              "unix_time": int(time.time()), "env": bench_env,
              "params": params, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows)} rows)")
    return record


if __name__ == "__main__":
    if len(sys.argv) > 1:                   # worker mode: params as argv[1]
        _worker(json.loads(sys.argv[1]))
    else:
        run()
