"""Pool build throughput: backend × frontier mode × diffusion →
batches/sec, with the work counters that make sparse-frontier savings
measurable (not vibes).

Two sweeps over a sketch-pool build on a forced 8-device CPU host mesh
(the multi-device test-suite trick):

* ``low_occupancy`` — the standard sparse-frontier sweep: a graph whose
  unified frontier collapses after the first couple of levels (paper
  Fig. 9), where the dense sweep's every-edge-every-level cost is pure
  waste.  Backends ``dense`` and ``data_parallel``, each under
  ``frontier="dense"`` and ``"sparse"`` — same bits, different work.
* ``graph_parallel`` — the 2-D (data × model) mesh cells on a smaller
  graph (per-level frontier all-gathers on forced host devices are
  collective-bound, so the big graph would measure the CPU's psum, not
  the build mechanics), with its dense-backend reference alongside.
* ``kernel_interpret`` — the Pallas-kernel cells: single-device
  ``kernel`` backend rows and ``graph_parallel_kernel`` rows (the
  ``graph_parallel`` backend with ``REPRO_GP_KERNEL=1``, i.e. each
  shard's tile expansion through the kernels).  On CPU CI the kernels
  run in **interpret mode**, which emulates the grid tile-by-tile — the
  timings record the mechanics (and the bit-identity assertion versus
  the dense reference), not accelerator throughput, so this sweep is
  sized small enough for emulation.  On a real TPU/GPU host the same
  rows record compiled-kernel numbers.

Timing protocol (steady state, the serving regime): the cold ``ensure``
+ stack staging warm every program, then

* ``build_s``    — ``refresh(1.0)``: a WARM full-pool block resample
                   (every slot redrawn at fresh batch indices + the whole
                   stack rewritten in place);
* ``refresh_s``  — ``refresh(0.25)``: the launcher's default epoch
                   refresh, after one warm-up at that block size.  The
                   donated-buffer slot scatter (`sketch_store._set_slots`)
                   keeps the pool allocation — refresh cost is the
                   fraction's sampling, not a pool re-stage (the old
                   ``refresh_s ≈ build_s`` pathology).

Every cell runs the SAME ensure/refresh sequence, so all cells of a
(sweep, diffusion) hold bit-identical pools at the end — asserted.

Per row: ``fused_edge_visits`` (summed over the final pool's instrumented
batches; -1 where the backend doesn't instrument), ``active_tile_frac``
(mean per-level fraction of active source row-blocks from
`core.sparse.profile_traversal` — the Fig. 9 quantity sparse execution
exploits; identical for dense and sparse rows by construction), the 2-D
residency observables ``visited_rows_device`` / ``pool_mib_device``
(V/M rows per device when the pool is row-sharded over the model axis),
and — graph_parallel cells only — ``gather_words_level``: the packed
words the last refresh block moved over the model axis per traversal
level.  Dense-frontier rows record the flat all-gather's
``S·(S−1)·rows·W`` per level; sparse rows record the ButterFly-style
log(M) pairwise exchange where the compacted frontier fits
(`gather_capacity_words`) and the dense fallback where it doesn't —
the words saved per collapsed tail level, measured not claimed.

``active_grid_frac`` is the tile-backend analogue: for ``kernel`` (and
``tiled``) rows, the last sampled batch's kernel grid steps over the
dense grid's ``levels · num_tiles`` — exactly 1.0 under
``frontier="dense"``, strictly below 1.0 when the sparse frontier
compacts the grid to the active source tiles (-1 where the backend does
not run a tile grid).

Runs in a **subprocess** so the forced device count never leaks into the
parent.  Emits the standard ``BENCH_<name>.json`` shape.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_DEVICES = 8


# ------------------------------------------------------------------ worker
def _mean_active_tile_frac(g, diffusion: str, colors: int, tile: int,
                           master_seed: int) -> float:
    """Mean per-level active source row-block fraction of batch 0."""
    import numpy as np

    from repro.core import lt, rrr, sparse
    from repro.graph import csr

    g_rev = csr.transpose(g)
    cb = None
    if diffusion == "lt":
        g_rev = lt.normalize_lt_weights(g_rev)
        cb = lt.selection_cum_before(g_rev)
    fidx = sparse.build_frontier_index(g_rev, tile_rows=tile, cb=cb)
    starts = rrr.batch_starts(g.num_vertices, colors, master_seed, 0)
    prof = sparse.profile_traversal(fidx, starts, colors,
                                    rrr.batch_seed(master_seed, 0),
                                    diffusion=diffusion)
    fracs = [r["active_row_blocks"] / fidx.num_row_blocks for r in prof]
    return float(np.mean(fracs)) if fracs else 0.0


def _worker(args: dict) -> None:
    # One-stop accelerator config: latency-hiding XLA flags (GPU) plus the
    # forced host-device shim (CPU CI) — before jax's backend materializes.
    from repro.launch import accel
    accel.configure(host_devices=_DEVICES)
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro import sampling
    from repro.graph import csr, generators
    from repro.serve.distributed import ShardedSketchStore
    from repro.serve.influence import PoolConfig, SketchStore

    for sweep in args["sweeps"]:
        # Dedupe once per sweep: tile layouts need parallel edges merged,
        # and bit-identity needs one shared edge list across backends.
        g = csr.dedupe(generators.powerlaw_cluster(
            sweep["n"], sweep["deg"], prob=tuple(sweep["prob"]), seed=11))
        # (row label, SamplerSpec backend, mesh shape, REPRO_GP_KERNEL):
        # graph_parallel_kernel is the same backend as graph_parallel with
        # the per-shard Pallas kernel leg armed via the env knob.
        cells = [("dense", "dense", (1, 1), False)]
        if sweep.get("kernel_cells"):
            cells.append(("kernel", "kernel", (1, 1), False))
        cells += [("data_parallel", "data_parallel", (s, 1), False)
                  for s in sweep["shard_counts"]]
        for dm in sweep["gp_mesh_shapes"]:
            cells.append(("graph_parallel", "graph_parallel",
                          tuple(dm), False))
            if sweep.get("gp_kernel"):
                cells.append(("graph_parallel_kernel", "graph_parallel",
                              tuple(dm), True))

        for diffusion in sweep["diffusions"]:
            tile_frac = _mean_active_tile_frac(
                g, diffusion, sweep["colors"], sweep["tile"], 7)
            ref_store = None
            for label, backend, (d, m), gp_kernel in cells:
                for frontier in sweep["frontiers"]:
                    if gp_kernel:
                        os.environ["REPRO_GP_KERNEL"] = "1"
                    else:
                        os.environ.pop("REPRO_GP_KERNEL", None)
                    spec = sampling.SamplerSpec(
                        diffusion=diffusion, backend=backend,
                        num_colors=sweep["colors"], master_seed=7,
                        tile_size=sweep["tile"], frontier=frontier)
                    cfg = PoolConfig(max_batches=sweep["batches"], spec=spec)
                    if backend == "dense":
                        store = SketchStore(g, cfg)
                    else:
                        devs = np.array(jax.devices()[: d * m])
                        mesh = (Mesh(devs.reshape(d, m), ("data", "model"))
                                if m > 1 else Mesh(devs, ("data",)))
                        store = ShardedSketchStore(g, cfg, mesh)
                    # Cold build compiles every program; stack staging
                    # arms the in-place refresh path.
                    store.ensure(sweep["batches"])
                    store.visited_stack()
                    t0 = time.perf_counter()
                    store.refresh(1.0)               # warm full resample
                    build_s = time.perf_counter() - t0
                    store.refresh(0.25)              # warm the 1/4 block
                    t0 = time.perf_counter()
                    store.refresh(0.25)              # steady-state epoch
                    refresh_s = time.perf_counter() - t0

                    if ref_store is None:
                        ref_store = store    # dense/dense row IS the ref
                    for a, b in zip(ref_store.batches, store.batches):
                        np.testing.assert_array_equal(
                            np.asarray(a.visited), np.asarray(b.visited))
                    visits = [b.fused_edge_visits for b in store.batches]
                    # 2-D observables: per-device visited-row residency
                    # (V/M rows when the pool is row-sharded over the
                    # model axis) and, for graph_parallel cells, the
                    # packed words the LAST refresh block moved over the
                    # model axis per level (dense rows record the flat
                    # all-gather, sparse rows the butterfly/dense mix —
                    # same refresh sequence, so rows are comparable).
                    m_rows = getattr(store, "row_shards", 1)
                    vis_rows = (getattr(store, "padded_vertices",
                                        g.num_vertices) // m_rows)
                    pool_mib = (store.bytes_per_batch
                                * getattr(store, "padded_batches",
                                          sweep["batches"])
                                / getattr(store, "num_shards", 1)
                                / m_rows / 2 ** 20)
                    gw = getattr(store.sampler, "last_gather_words", None)
                    if gw is not None:
                        lv = np.asarray(gw).sum(0)
                        last = (int(np.max(np.nonzero(lv)[0])) + 1
                                if lv.any() else 0)
                        gw_levels = [int(x) for x in lv[:last]]
                    else:
                        gw_levels = []
                    # Kernel/tiled rows: last batch's grid steps over the
                    # dense grid (1.0 dense frontier, < 1.0 sparse).
                    smp = store.sampler
                    agf = -1.0
                    if getattr(smp, "last_levels", 0) and \
                            hasattr(smp, "last_grid_steps"):
                        agf = (smp.last_grid_steps
                               / (smp.last_levels * smp.tg_rev.num_tiles))
                    row = {
                        "sweep": sweep["name"],
                        "diffusion": diffusion,
                        "backend": label,
                        "frontier": frontier,
                        "mesh": f"{d}x{m}",
                        "shards": getattr(store, "num_shards", 1),
                        "batches": sweep["batches"],
                        "colors": sweep["colors"],
                        "build_s": round(build_s, 3),
                        "batches_per_s": round(
                            sweep["batches"] / max(build_s, 1e-9), 2),
                        "refresh_s": round(refresh_s, 3),
                        "fused_edge_visits": (sum(visits)
                                              if min(visits) >= 0 else -1),
                        "active_tile_frac": round(tile_frac, 4),
                        "active_grid_frac": round(agf, 4),
                        "visited_rows_device": vis_rows,
                        "pool_mib_device": round(pool_mib, 3),
                        "gather_words_level": gw_levels,
                        "gather_words": sum(gw_levels),
                    }
                    print("ROW " + json.dumps(row), flush=True)
    print("ENV " + json.dumps({"backend": jax.default_backend(),
                               "devices": _DEVICES,
                               "jax": jax.__version__}), flush=True)


# ------------------------------------------------------------------ driver
def standard_sweeps(low_n=6000, gp_n=1200, batches=16) -> list[dict]:
    """The two recorded sweeps (scaled down by callers like run.py).

    ``batches`` is 4× the data_parallel shard count so a quarter-refresh
    still fills every shard (a 2-batch refresh padded to 4 shards would
    do build-half work for a quarter of the slots and skew the ratio)."""
    return [
        dict(name="low_occupancy", n=low_n, deg=16.0, prob=(0.0, 0.05),
             colors=64, tile=64, batches=batches,
             diffusions=["ic", "lt"], frontiers=["dense", "sparse"],
             shard_counts=[4], gp_mesh_shapes=[]),
        dict(name="graph_parallel", n=gp_n, deg=8.0, prob=(0.0, 0.1),
             colors=64, tile=64, batches=max(batches // 2, 8),
             diffusions=["ic", "lt"], frontiers=["dense", "sparse"],
             shard_counts=[], gp_mesh_shapes=[(2, 4)]),
        # Sized for CPU interpret-mode kernel emulation (~170 tiles): the
        # kernel rows record mechanics + bit-identity there, compiled
        # numbers on a real accelerator.
        dict(name="kernel_interpret", n=max(gp_n * 2 // 3, 400), deg=6.0,
             prob=(0.0, 0.1), colors=64, tile=64,
             batches=max(batches // 2, 8),
             diffusions=["ic", "lt"], frontiers=["dense", "sparse"],
             shard_counts=[], gp_mesh_shapes=[(2, 2)],
             kernel_cells=True, gp_kernel=True),
    ]


def run(sweeps=None, out=print, json_path="BENCH_pool_build.json"):
    params = {"sweeps": [dict(s, prob=list(s["prob"]),
                              gp_mesh_shapes=[list(dm) for dm
                                              in s["gp_mesh_shapes"]])
                         for s in (sweeps or standard_sweeps())]}
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), json.dumps(params)],
        capture_output=True, text=True, env=env, timeout=2400)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed:\n{proc.stdout}\n{proc.stderr}")
    rows, bench_env = [], {}
    for line in proc.stdout.splitlines():
        if line.startswith("ROW "):
            rows.append(json.loads(line[4:]))
        elif line.startswith("ENV "):
            bench_env = json.loads(line[4:])

    out("# pool build: sweep,diffusion,backend,frontier,mesh,build_s,"
        "batches_per_s,refresh_s,fused_edge_visits,active_tile_frac,"
        "active_grid_frac,visited_rows_device,pool_mib_device,gather_words")
    for r in rows:
        out(",".join(str(r[k]) for k in
                     ("sweep", "diffusion", "backend", "frontier", "mesh",
                      "build_s", "batches_per_s", "refresh_s",
                      "fused_edge_visits", "active_tile_frac",
                      "active_grid_frac", "visited_rows_device",
                      "pool_mib_device", "gather_words")))

    record = {"bench": "pool_build", "schema": 4,
              "unix_time": int(time.time()), "env": bench_env,
              "params": params, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(record, f, indent=1)
        out(f"# wrote {json_path} ({len(rows)} rows)")
    return record


if __name__ == "__main__":
    if len(sys.argv) > 1:                   # worker mode: params as argv[1]
        _worker(json.loads(sys.argv[1]))
    else:
        run()
