"""Mamba2 / SSD (state-space duality) sequence mixer.

Chunked SSD algorithm (Dao & Gu 2024): the sequence splits into chunks of
``cfg.ssm_chunk``; within a chunk the recurrence is evaluated as a masked
attention-like matmul (MXU-friendly), across chunks a short ``lax.scan``
carries the (H, S, P) state.  Decode is the O(1) recurrence on a cached
state — this is why the SSM/hybrid archs own the ``long_500k`` cell: the
"KV cache" is a fixed (H, S, P) state + a (w-1)-step conv tail, independent
of context length.

Layout: d_inner = expand·d_model, heads H = d_inner/64, head dim P = 64,
single B/C group (n_groups=1), scalar decay per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import batch_axes, shard
from repro.models import common
from repro.models.config import ModelConfig


def init_mamba(key, cfg: ModelConfig):
    ks = common.keygen(key)
    d, di, s, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt = common.dtype_of(cfg.dtype)
    conv_ch = di + 2 * s
    return {
        "in_proj": common.dense_init(next(ks), d,
                                     (2 * di + 2 * s + h,), dt),
        "conv": (jax.random.normal(next(ks), (cfg.conv_width, conv_ch),
                                   jnp.float32) * 0.1).astype(dt),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": common.dense_init(next(ks), di, (d,), dt),
    }


def _split(zxbcdt, cfg):
    di, s, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * s]
    dt = zxbcdt[..., di + di + 2 * s:]
    return z, xbc, dt


def _causal_conv(xbc, conv, cfg, tail=None):
    """Depthwise causal conv width w over channels.  tail: (B, w-1, C) from
    a previous segment (decode/prefill continuation)."""
    w = cfg.conv_width
    if tail is None:
        tail = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([tail, xbc], 1)               # (B, L+w-1, C)
    out = sum(padded[:, i: i + xbc.shape[1]] * conv[i] for i in range(w))
    return jax.nn.silu(out), padded[:, -(w - 1):]


def mamba_forward(p, x, cfg: ModelConfig, conv_tail=None, init_state=None):
    """x: (B, L, D) → (B, L, D), (final ssm state, conv tail) for caching."""
    b, L, d = x.shape
    di, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    cs = min(cfg.ssm_chunk, L)
    nc = L // cs
    assert L % cs == 0, "pad sequence to chunk multiple"

    z, xbc, dt = _split(x @ p["in_proj"], cfg)
    xbc, tail = _causal_conv(xbc, p["conv"], cfg, conv_tail)
    xs, Bc, Cc = jnp.split(xbc, [di, di + S], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,L,H)
    A = -jnp.exp(p["a_log"])                                      # (H,)

    xh = xs.reshape(b, nc, cs, H, P).astype(jnp.float32)
    xh = shard(xh, batch_axes(), None, None, "model", None)
    Bcc = Bc.reshape(b, nc, cs, S).astype(jnp.float32)
    Ccc = Cc.reshape(b, nc, cs, S).astype(jnp.float32)
    dtc = dt.reshape(b, nc, cs, H)
    dA = dtc * A                                                  # (B,nc,cs,H)
    cum = jnp.cumsum(dA, axis=2)                                  # (B,nc,cs,H)

    # ---- intra-chunk (masked attention-like) ----
    cb = jnp.einsum("bnis,bnjs->bnij", Ccc, Bcc)                  # (B,nc,cs,cs)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    scores = jnp.where(mask[None, None, :, :, None],
                       cb[..., None] * decay * dtc[:, :, None], 0.0)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores, xh)

    # ---- chunk states + inter-chunk recurrence ----
    w_j = jnp.exp(cum[:, :, -1:, :] - cum) * dtc                  # (B,nc,cs,H)
    state_c = jnp.einsum("bnjs,bnjh,bnjhp->bnhsp", Bcc, w_j, xh)  # (B,nc,H,S,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    s0 = (init_state if init_state is not None
          else jnp.zeros((b, H, S, P), jnp.float32))

    def scan_fn(s_prev, inp):
        dec, sc = inp                                            # (B,H),(B,H,S,P)
        s_new = s_prev * dec[..., None, None] + sc
        return s_new, s_prev                                     # emit BEFORE

    s_final, s_before = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(state_c, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)                      # (B,nc,H,S,P)

    y_inter = jnp.einsum("bnis,bnhsp,bnih->bnihp", Ccc, s_before,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, L, H, P)
    y = y + p["d_skip"][None, None, :, None] * xs.reshape(b, L, H, P
                                                          ).astype(jnp.float32)
    y = y.reshape(b, L, di)
    y = common.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))
                         ).astype(x.dtype), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], (s_final, tail)


def mamba_decode(p, x, cache, cfg: ModelConfig):
    """One-token decode.  x: (B, 1, D); cache {state (B,H,S,P) fp32,
    conv (B, w-1, di+2S)} → (out (B,1,D), new cache)."""
    b = x.shape[0]
    di, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    z, xbc, dt = _split(x @ p["in_proj"], cfg)
    xbc, tail = _causal_conv(xbc, p["conv"], cfg, cache["conv"])
    xs, Bc, Cc = jnp.split(xbc[:, 0], [di, di + S], axis=-1)      # (B, ·)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["a_log"])
    dA = jnp.exp(dt * A)                                          # (B,H)
    xh = xs.reshape(b, H, P).astype(jnp.float32)
    upd = jnp.einsum("bs,bh,bhp->bhsp", Bc.astype(jnp.float32), dt, xh)
    state = cache["state"] * dA[..., None, None] + upd
    y = jnp.einsum("bs,bhsp->bhp", Cc.astype(jnp.float32), state)
    y = y + p["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = common.rms_norm((y * jax.nn.silu(z.astype(jnp.float32))
                         ).astype(x.dtype), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"state": state, "conv": tail}


def init_mamba_cache(cfg: ModelConfig, batch):
    di, S, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    return {"state": jnp.zeros((batch, H, S, P), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * S),
                              common.dtype_of(cfg.dtype))}
