"""Single-token decode with static-shape caches (serve_step lowering).

Cache layout mirrors the stack structure: one pytree per stack, each leaf
stacked over scan groups, so decode is the same ``lax.scan`` as training —
params and caches are consumed together and the updated caches are emitted.

Cache kinds:  GQA {k, v}: (G, B, Lmax, KVH, hd) seq-sharded on "model";
MLA latent {c, k_rope}: (G, B, Lmax, kr|rd) — the absorbed-decode memory
win; mamba {state, conv}: O(1) in context length (long_500k's enabler);
``mamba_attn`` pairs a mamba cache with the shared block's own KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import batch_axes, shard
from repro.models import attention, common, mlp, ssm
from repro.models.config import ModelConfig
from repro.models.model import _logits, stacks_of


def _block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int):
    dt = common.dtype_of(cfg.dtype)
    if kind == "mamba":
        return ssm.init_mamba_cache(cfg, batch)
    if kind == "mamba_attn":
        return (ssm.init_mamba_cache(cfg, batch),
                attention.init_gqa_cache(cfg, batch, max_len, dt))
    if cfg.attention == "mla":
        return attention.init_mla_cache(cfg, batch, max_len, dt)
    return attention.init_gqa_cache(cfg, batch, max_len, dt)


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    caches = []
    for pattern, groups in stacks_of(cfg):
        stack = {}
        for i, kind in enumerate(pattern):
            one = _block_cache(kind, cfg, batch, max_len)
            stack[f"block{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (groups, *x.shape)), one)
        caches.append(stack)
    return caches


def _decode_one(kind, p, cache, h, cur_len, cfg, shared):
    if kind in ("mamba", "mamba_attn"):
        mc = cache[0] if kind == "mamba_attn" else cache
        out, mc = ssm.mamba_decode(
            p["mamba"], common.rms_norm(h, p["norm1"], cfg.norm_eps), mc, cfg)
        h = h + out
        if kind == "mamba_attn":
            sp = shared
            a_out, ac = attention.gqa_decode(
                sp["attn"], common.rms_norm(h, sp["norm1"], cfg.norm_eps),
                cache[1], cur_len, cfg)
            h = h + a_out
            h = h + mlp.mlp_forward(
                sp["mlp"], common.rms_norm(h, sp["norm2"], cfg.norm_eps), cfg)
            return h, (mc, ac)
        return h, mc
    dec = (attention.mla_decode if cfg.attention == "mla"
           else attention.gqa_decode)
    a_out, cache = dec(p["attn"],
                       common.rms_norm(h, p["norm1"], cfg.norm_eps),
                       cache, cur_len, cfg)
    h = h + a_out
    x2 = common.rms_norm(h, p["norm2"], cfg.norm_eps)
    if kind == "moe":
        m_out, _ = mlp.moe_forward(p["moe"], x2, cfg)
    else:
        m_out = mlp.mlp_forward(p["mlp"], x2, cfg)
    return h + m_out, cache


def decode_step(params, cfg: ModelConfig, caches, tokens, cur_len):
    """One decode step.  tokens: (B, 1) (audio: (B, K, 1)); cur_len: the
    write position (new token attends positions ≤ cur_len).  Returns
    (logits (B, 1, V[, K]), new caches)."""
    if cfg.num_codebooks:
        h = sum(params["embedding"][k][tokens[:, k]]
                for k in range(cfg.num_codebooks))
    else:
        h = params["embedding"][tokens]
    h = shard(h, batch_axes(), None, None)
    shared = params.get("shared_attn")
    new_caches = []
    for (pattern, groups), stack_p, cache in zip(
            stacks_of(cfg), params["stacks"], caches):

        def group_fn(h, inp, pattern=pattern):
            gp, gc = inp
            nc = {}
            for i, kind in enumerate(pattern):
                h, c = _decode_one(kind, gp[f"block{i}"], gc[f"block{i}"],
                                   h, cur_len, cfg, shared)
                nc[f"block{i}"] = c
            return h, nc

        h, new_cache = jax.lax.scan(group_fn, h, (stack_p, cache))
        new_caches.append(new_cache)
    return _logits(params, cfg, h), new_caches
