"""Attention variants: GQA (llama/qwen/nemotron/command-r/musicgen/phi3) and
MLA (deepseek-v3), in blocked memory-efficient form.

Prefill/train never materialize (L, L): an outer ``lax.map`` over query
blocks runs an inner online-softmax scan over KV blocks (the pure-XLA twin
of kernels/flash_attention.py — used for lowering/dry-run so cost_analysis
sees real HLO; the Pallas kernel is the TPU execution path).

MLA keeps the latent cache: prefill projects K/V per *block* from the
compressed c_kv inside the scan (never a full (L, H, hd) K tensor); decode
uses the absorbed formulation (q projected into latent space) so the cache
is (B, L, kv_rank + rope_dim) — the paper-exact memory win of MLA.

Decode shards the KV cache's *sequence* axis over "model" (sequence-parallel
flash-decode): softmax over a sharded axis lowers to partial max/sum +
all-reduce under GSPMD — collective-light and HBM-balanced.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import batch_axes, shard, shard_first
from repro.models import common
from repro.models.config import ModelConfig

_NEG = -1e30


# ------------------------------------------------------------------- init
def init_gqa(key, cfg: ModelConfig):
    ks = common.keygen(key)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = common.dtype_of(cfg.dtype)
    p = {
        "wq": common.dense_init(next(ks), d, (h, hd), dt),
        "wk": common.dense_init(next(ks), d, (kvh, hd), dt),
        "wv": common.dense_init(next(ks), d, (kvh, hd), dt),
        "wo": common.dense_init(next(ks), h * hd, (d,), dt).reshape(h, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((kvh, hd), dt)
        p["bv"] = jnp.zeros((kvh, hd), dt)
    return p


def init_mla(key, cfg: ModelConfig):
    ks = common.keygen(key)
    d, h = cfg.d_model, cfg.num_heads
    hd, rd = cfg.head_dim, cfg.rope_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    vd = cfg.v_head_dim or hd
    dt = common.dtype_of(cfg.dtype)
    return {
        "w_dq": common.dense_init(next(ks), d, (qr,), dt),
        "q_norm": jnp.ones((qr,), dt),
        "w_uq": common.dense_init(next(ks), qr, (h, hd + rd), dt),
        "w_dkv": common.dense_init(next(ks), d, (kr + rd,), dt),
        "kv_norm": jnp.ones((kr,), dt),
        "w_uk": common.dense_init(next(ks), kr, (h, hd), dt),
        "w_uv": common.dense_init(next(ks), kr, (h, vd), dt),
        "wo": common.dense_init(next(ks), h * vd, (d,), dt).reshape(h, vd, d),
    }


# ------------------------------------------------- blocked online softmax
def _blocked_attn(q, kv_block_fn, num_kv_blocks, block_k, q_pos0, scale,
                  kv_offset):
    """q: (B, bq, KVH, G, hd).  kv_block_fn(j) → (k_blk, v_blk) with shapes
    (B, bk, KVH, hd), (B, bk, KVH, vd).  Returns (B, bq, KVH, G, vd)."""
    b, bq, kvh, g, hd = q.shape
    qf = q.astype(jnp.float32) * scale
    q_pos = q_pos0 + jnp.arange(bq) + kv_offset            # (bq,)

    def step(carry, j):
        k_blk, v_blk = kv_block_fn(j)
        k_pos = j * block_k + jnp.arange(block_k)          # (bk,)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k_blk.astype(jnp.float32))
        mask = (k_pos[None, :] <= q_pos[:, None])          # (bq, bk)
        s = jnp.where(mask[None, None, None], s, _NEG)
        # carry shapes: (B, KVH, G, bq) / (..., vd)
        vb = v_blk.astype(jnp.float32)                     # (B, bk, KVH, vd)
        m, l, acc = carry
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[..., None])                  # (B,KVH,G,bq,bk)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, -1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l, acc), None

    vd = kv_block_fn(0)[1].shape[-1]
    init = (jnp.full((b, kvh, g, bq), _NEG, jnp.float32),
            jnp.zeros((b, kvh, g, bq), jnp.float32),
            jnp.zeros((b, kvh, g, bq, vd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  jnp.arange(num_kv_blocks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (B,KVH,G,bq,vd)
    return jnp.transpose(out, (0, 3, 1, 2, 4))             # (B,bq,KVH,G,vd)


def _run_q_blocks(q, kv_block_fn, cfg, L, vd, kv_offset=0):
    """Outer loop over query blocks.  q: (B, L, KVH, G, hd)."""
    b, _, kvh, g, hd = q.shape
    bq = min(cfg.attn_block_q, L)
    bk = min(cfg.attn_block_k, L + kv_offset)
    nq = L // bq
    nk = (L + kv_offset) // bk
    scale = (hd if cfg.attention != "mla"
             else cfg.head_dim + cfg.rope_head_dim) ** -0.5
    qb = q.reshape(b, nq, bq, kvh, g, hd)
    # Distribute attention over "model": KV heads when they divide the
    # axis, else the query-group axis, else the query rows *within* each
    # block (sequence-parallel attention — the scan axis nq must stay
    # unsharded, it is temporal).
    dp = batch_axes()
    qb = shard_first(qb, [
        (dp, None, None, "model", None, None),     # shard KV heads
        (dp, None, None, None, "model", None),     # shard q groups
        (dp, None, "model", None, None, None),     # shard q rows per block
    ])

    def per_q_block(args):
        qi, q_blk = args
        return _blocked_attn(q_blk, kv_block_fn, nk, bk, qi * bq, scale,
                             kv_offset)

    out = jax.lax.map(per_q_block, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    return jnp.moveaxis(out, 0, 1).reshape(b, L, kvh, g, vd)


# ----------------------------------------------------------------- GQA
def gqa_forward(p, x, positions, cfg: ModelConfig):
    """Full-sequence GQA (train / prefill).  x: (B, L, D) → (B, L, D), and
    returns (k, v) for cache construction in prefill."""
    b, L, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    # NOTE: no head constraint here — _run_q_blocks owns the attention
    # layout (heads or q-rows); double constraints caused SPMD involuntary
    # remat copies between layouts (EXPERIMENTS.md §Perf).
    qg = q.reshape(b, L, kvh, g, hd)

    def kv_block(j):
        bk = min(cfg.attn_block_k, L)
        k_blk = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, 1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, 1)
        return k_blk, v_blk

    out = _run_q_blocks(qg, kv_block, cfg, L, hd)
    out = out.reshape(b, L, h, hd).astype(x.dtype)
    return jnp.einsum("blhk,hkd->bld", out, p["wo"]), (k, v)


def gqa_decode(p, x, cache, cur_len, cfg: ModelConfig):
    """One-token decode.  x: (B, 1, D); cache = {k, v}: (B, Lc, KVH, hd),
    sequence axis sharded on "model" (sequence-parallel flash-decode)."""
    b, _, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k_new = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v_new = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if cfg.qkv_bias:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    q = common.apply_rope(q, pos, cfg.rope_theta)
    k_new = common.apply_rope(k_new, pos, cfg.rope_theta)

    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, cur_len, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, cur_len, 1)
    ck = shard(ck, batch_axes(), "model", None, None)
    cv = shard(cv, batch_axes(), "model", None, None)

    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,blhd->bhgl", qg, ck.astype(jnp.float32))
    Lc = ck.shape[1]
    valid = jnp.arange(Lc)[None, None, None] <= cur_len     # (1,1,1,Lc)
    s = jnp.where(valid, s, _NEG)
    att = jax.nn.softmax(s, axis=-1)                        # GSPMD: psum pair
    out = jnp.einsum("bhgl,blhd->bhgd", att, cv.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(x.dtype)
    return (jnp.einsum("blhk,hkd->bld", out, p["wo"]),
            {"k": ck, "v": cv})


def init_gqa_cache(cfg: ModelConfig, batch, max_len, dtype):
    z = jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    return {"k": z, "v": z}


# ----------------------------------------------------------------- MLA
def _mla_qkv(p, x, positions, cfg):
    b, L, _ = x.shape
    h, hd, rd = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    q_lat = common.rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("blr,rhk->blhk", q_lat, p["w_uq"])       # (B,L,H,hd+rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = common.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["w_dkv"]                                    # (B,L,kr+rd)
    c, k_rope = ckv[..., : cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = common.rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = common.apply_rope(k_rope[..., None, :], positions,
                               cfg.rope_theta)[..., 0, :]   # shared head
    return q_nope, q_rope, c, k_rope


def mla_forward(p, x, positions, cfg: ModelConfig):
    """MLA train/prefill: latent-blocked attention (module docstring)."""
    b, L, d = x.shape
    h, hd, rd = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    vd = cfg.v_head_dim or hd
    q_nope, q_rope, c, k_rope = _mla_qkv(p, x, positions, cfg)
    q_cat = jnp.concatenate([q_nope, q_rope], -1)           # (B,L,H,hd+rd)
    q_cat = shard(q_cat, batch_axes(), None, "model", None)
    qg = q_cat[:, :, :, None, :]                            # KVH=H, G=1

    bk = min(cfg.attn_block_k, L)

    def kv_block(j):
        c_blk = jax.lax.dynamic_slice_in_dim(c, j * bk, bk, 1)
        kr_blk = jax.lax.dynamic_slice_in_dim(k_rope, j * bk, bk, 1)
        k_blk = jnp.einsum("blr,rhk->blhk", c_blk, p["w_uk"])
        k_blk = jnp.concatenate(
            [k_blk, jnp.broadcast_to(kr_blk[:, :, None, :],
                                     (*k_blk.shape[:3], rd))], -1)
        v_blk = jnp.einsum("blr,rhv->blhv", c_blk, p["w_uv"])
        return k_blk, v_blk

    out = _run_q_blocks(qg, kv_block, cfg, L, vd)
    out = out.reshape(b, L, h, vd).astype(x.dtype)
    return (jnp.einsum("blhv,hvd->bld", out, p["wo"]),
            (c, k_rope))                                    # latent cache


def mla_decode(p, x, cache, cur_len, cfg: ModelConfig):
    """Absorbed-MLA decode: cache {c: (B,Lc,kr), k_rope: (B,Lc,rd)}."""
    b, _, d = x.shape
    h, hd, rd = cfg.num_heads, cfg.head_dim, cfg.rope_head_dim
    vd = cfg.v_head_dim or hd
    pos = jnp.full((b, 1), cur_len, jnp.int32)
    q_nope, q_rope, c_new, kr_new = _mla_qkv(p, x, pos, cfg)
    cc = jax.lax.dynamic_update_slice_in_dim(cache["c"], c_new, cur_len, 1)
    ckr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new,
                                              cur_len, 1)
    cc = shard(cc, batch_axes(), "model", None)
    ckr = shard(ckr, batch_axes(), "model", None)

    # Absorb W_uk into the query: q_lat = q_nope · W_uk  → latent space.
    q_lat = jnp.einsum("blhk,rhk->bhr", q_nope, p["w_uk"])  # (B,H,kr)
    scale = (hd + rd) ** -0.5
    s = (jnp.einsum("bhr,blr->bhl", q_lat.astype(jnp.float32),
                    cc.astype(jnp.float32))
         + jnp.einsum("bhk,blk->bhl", q_rope[:, 0].astype(jnp.float32),
                      ckr.astype(jnp.float32))) * scale
    Lc = cc.shape[1]
    valid = jnp.arange(Lc)[None, None] <= cur_len
    s = jnp.where(valid, s, _NEG)
    att = jax.nn.softmax(s, axis=-1)                        # (B,H,Lc)
    o_lat = jnp.einsum("bhl,blr->bhr", att, cc.astype(jnp.float32))
    out = jnp.einsum("bhr,rhv->bhv", o_lat, p["w_uv"].astype(jnp.float32))
    out = out[:, None].astype(x.dtype)                      # (B,1,H,vd)
    return (jnp.einsum("blhv,hvd->bld", out, p["wo"]),
            {"c": cc, "k_rope": ckr})


def init_mla_cache(cfg: ModelConfig, batch, max_len, dtype):
    return {"c": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype)}
