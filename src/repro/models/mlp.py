"""Dense MLP and Mixture-of-Experts layers.

MoE uses capacity-bounded scatter dispatch (Switch-style, expressed with
cumsum ranking + scatter-add instead of the (N, E, C) one-hot tensor, which
would not fit at DeepSeek scale).  Experts are sharded over "model" (expert
parallelism); the (E, C, D) buffers shard capacity over the batch axes, so
GSPMD lowers dispatch/combine to the EP all-to-all pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding_rules import batch_axes, shard
from repro.models import common
from repro.models.config import ModelConfig


# ------------------------------------------------------------------- dense
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None):
    ks = common.keygen(key)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = common.dtype_of(cfg.dtype)
    p = {"w1": common.dense_init(next(ks), d, (f,), dt),
         "w2": common.dense_init(next(ks), f, (d,), dt)}
    if cfg.gated_mlp:
        p["w3"] = common.dense_init(next(ks), d, (f,), dt)
    return p


def mlp_forward(p, x, cfg: ModelConfig):
    act = common.activation_fn(cfg.activation)
    h = act(x @ p["w1"])
    if cfg.gated_mlp:
        h = h * (x @ p["w3"])
    h = shard(h, batch_axes(), None, "model")
    return h @ p["w2"]


# --------------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig):
    ks = common.keygen(key)
    d, e = cfg.d_model, cfg.num_experts
    fe = cfg.moe_d_ff or cfg.d_ff
    dt = common.dtype_of(cfg.dtype)
    p = {
        "router": common.dense_init(next(ks), d, (e,), jnp.float32),
        "experts_w1": common.dense_init(next(ks), d, (e, fe), dt
                                        ).transpose(1, 0, 2),
        "experts_w2": common.dense_init(next(ks), fe, (e, d), dt
                                        ).transpose(1, 0, 2),
    }
    if cfg.gated_mlp:
        p["experts_w3"] = common.dense_init(next(ks), d, (e, fe), dt
                                            ).transpose(1, 0, 2)
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(key, cfg, cfg.moe_d_ff * cfg.num_shared_experts
                               if cfg.moe_d_ff else cfg.d_ff)
    return p


def moe_forward(p, x, cfg: ModelConfig):
    """MoE dispatcher: picks the implementation (module docstring).

    * ``a2a``     — shard_map expert parallelism with explicit
      ``all_to_all`` dispatch/combine (§Perf iteration D1: the GSPMD
      scatter lowered to full-buffer all-reduces, ~160× more collective
      bytes).  Requires a mesh with a "model" axis that divides L and E.
    * ``scatter`` — the GSPMD capacity-scatter formulation (baseline).
    """
    from repro.distributed.sharding_rules import get_mesh
    mesh = get_mesh()
    if (cfg.moe_impl == "a2a" and mesh is not None
            and "model" in mesh.axis_names):
        s = mesh.shape["model"]
        if (x.shape[1] % s == 0 and cfg.num_experts % s == 0 and s > 1):
            return _moe_forward_a2a(p, x, cfg, mesh)
    return _moe_forward_scatter(p, x, cfg)


def _moe_forward_scatter(p, x, cfg: ModelConfig):
    """x: (B, L, D) → (B, L, D), aux load-balance loss.

    Dispatch: rank tokens per expert by routing order (cumsum over the
    flattened (token, slot) stream); tokens past an expert's capacity are
    dropped (their combine weight is 0) — the standard bounded-buffer MoE.
    """
    b, L, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    fe = cfg.moe_d_ff or cfg.d_ff
    n = b * L
    cap = max(int(n * k / e * cfg.capacity_factor), 1)
    xt = x.reshape(n, d)

    logits = (xt.astype(jnp.float32) @ p["router"])         # (N, E)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)                     # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): e · Σ_e f_e · P_e
    token_frac = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1), 0)
    prob_frac = jnp.mean(probs, 0)
    aux = e * jnp.sum(token_frac * prob_frac)

    flat_e = idx.reshape(-1)                                # (N·k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, 0) - onehot                    # rank in expert
    pos = jnp.sum(pos * onehot, -1)                         # (N·k,)
    keep = pos < cap
    tok = jnp.repeat(jnp.arange(n), k)

    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = shard(buf, "model", batch_axes(), None)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[tok], 0))
    buf = shard(buf, "model", batch_axes(), None)

    act = common.activation_fn(cfg.activation)
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["experts_w1"]))
    if cfg.gated_mlp:
        h = h * jnp.einsum("ecd,edf->ecf", buf, p["experts_w3"])
    h = shard(h, "model", batch_axes(), None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["experts_w2"])
    out_buf = shard(out_buf, "model", batch_axes(), None)

    gathered = out_buf[flat_e, jnp.where(keep, pos, 0)]     # (N·k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w = (gate.reshape(-1) * keep).astype(gathered.dtype)
    out = jnp.zeros((n, d), gathered.dtype).at[tok].add(gathered * w[:, None])

    if cfg.num_shared_experts:
        out = out + mlp_forward(p["shared"], xt, cfg)
    return out.reshape(b, L, d).astype(x.dtype), aux


# ----------------------------------------------------- shard_map EP (a2a)
def _local_dispatch(xt, gate, idx, e, cap):
    """Capacity-bounded local dispatch (per-device).  xt: (T, D);
    gate/idx: (T, k).  Returns (buf (E, cap, D), flat_e, pos, keep, tok)."""
    t, d = xt.shape
    k = idx.shape[1]
    flat_e = idx.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, 0) - onehot
    pos = jnp.sum(pos * onehot, -1)
    keep = pos < cap
    tok = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[tok], 0))
    return buf, flat_e, pos, keep, tok


def _moe_forward_a2a(p, x, cfg: ModelConfig, mesh):
    """Expert parallelism with explicit all_to_all (classic EP — what the
    paper's Frontier codes would call the MPI_Alltoallv step).

    Layout inside shard_map: tokens sharded over (data…, model) — sequence
    split across the model axis for dispatch balance; experts over model;
    expert weights all-gathered over the FSDP axes on entry (ZeRO).
    dispatch: local (E, capₗ, D) buffers → all_to_all(model) → each shard
    holds (E/S, S·capₗ, D) for ITS experts; combine is the transpose.
    """
    from repro.distributed.compat import shard_map
    from jax.sharding import PartitionSpec as P

    b, L, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    s = mesh.shape["model"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    act = common.activation_fn(cfg.activation)
    t_loc = (b * L) // (s * dp_size)
    cap = max(int(t_loc * k / e * cfg.capacity_factor), 1)

    weights = {"router": p["router"], "w1": p["experts_w1"],
               "w2": p["experts_w2"]}
    w_specs = {"router": P(), "w1": P("model"), "w2": P("model")}
    if cfg.gated_mlp:
        weights["w3"] = p["experts_w3"]
        w_specs["w3"] = P("model")
    if cfg.num_shared_experts:
        weights["shared"] = p["shared"]
        w_specs["shared"] = jax.tree.map(lambda _: P(), p["shared"])

    def body(xs, w):
        # xs: (B_loc, L/S, D); router: (D, E); w1/w2/w3: (E/S, D|Fe, Fe|D)
        bl, ll, _ = xs.shape
        xt = xs.reshape(bl * ll, d)
        logits = xt.astype(jnp.float32) @ w["router"]
        probs = jax.nn.softmax(logits, -1)
        gate, idx = jax.lax.top_k(probs, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        token_frac = jnp.mean(
            jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(1), 0)
        aux = e * jnp.sum(token_frac * jnp.mean(probs, 0))
        aux = jax.lax.pmean(aux, ("model",) + dp)

        buf, flat_e, pos, keep, tok = _local_dispatch(xt, gate, idx, e, cap)
        # (E, cap, D) → (S, E/S, cap, D) → a2a → recv[j] = shard j's rows
        # for MY experts → (E/S, S·cap, D)
        buf = buf.reshape(s, e // s, cap, d)
        recv = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        recv = jnp.moveaxis(recv, 0, 1).reshape(e // s, s * cap, d)

        h = act(jnp.einsum("ecd,edf->ecf", recv, w["w1"]))
        if cfg.gated_mlp:
            h = h * jnp.einsum("ecd,edf->ecf", recv, w["w3"])
        out_buf = jnp.einsum("ecf,efd->ecd", h, w["w2"])  # (E/S, S·cap, D)

        # combine: transpose route back to source shards
        out_buf = jnp.moveaxis(
            out_buf.reshape(e // s, s, cap, d), 1, 0)   # (S, E/S, cap, D)
        back = jax.lax.all_to_all(out_buf, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        back = back.reshape(e, cap, d)                  # == buf layout
        gathered = back[flat_e, jnp.where(keep, pos, 0)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        gw = (gate.reshape(-1) * keep).astype(gathered.dtype)
        out = jnp.zeros_like(xt).at[tok].add(gathered * gw[:, None])
        if cfg.num_shared_experts:
            sh = w["shared"]
            sh_out = act(xt @ sh["w1"])
            if "w3" in sh:
                sh_out = sh_out * (xt @ sh["w3"])
            out = out + sh_out @ sh["w2"]
        return out.reshape(bl, ll, d), aux

    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(dp_spec, "model", None), w_specs),
                   out_specs=(P(dp_spec, "model", None), P()),
                   check=False)
    out, aux = fn(x, weights)
    return out.astype(x.dtype), aux
