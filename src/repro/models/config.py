"""Model configuration for the assigned architecture pool.

One dataclass covers every family (dense / MoE / SSM / hybrid / VLM / audio);
family-specific fields default to inert values.  Configs are plain data — the
model code (models/model.py) interprets them; launch code looks them up via
``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // num_heads

    # --- attention flavor ---
    attention: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP flavor ---
    activation: str = "silu"         # silu | gelu | relu2 (squared ReLU)
    gated_mlp: bool = True           # SwiGLU-style gate (False: plain MLP)

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert FFN width
    capacity_factor: float = 1.25
    first_dense_layers: int = 0      # leading dense layers (deepseek: 3)
    moe_every: int = 1               # MoE block every N layers (llama4: 1)
    moe_impl: str = "a2a"            # a2a (shard_map EP) | scatter (GSPMD)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0               # v-heads of SSD (0 ⇒ d_model // 64)
    ssm_chunk: int = 256
    conv_width: int = 4
    ssm_expand: int = 2

    # --- hybrid (zamba2): shared attention block every N mamba layers ---
    hybrid_attn_every: int = 0

    # --- modality stubs ---
    num_patches: int = 0             # VLM: prefix patch embeddings
    num_codebooks: int = 0           # audio: EnCodec codebooks

    # --- training/runtime knobs ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    fsdp_per_layer_gather: bool = True   # constrain per-layer param slices
    # inside the scan so FSDP gathers stream layer-by-layer (§Perf N1)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    optimizer_state_dtype: str = "float32"   # bf16 for the ≥100B configs
    attention_impl: str = "blocked_scan"     # blocked_scan | pallas | naive
    attn_block_q: int = 512
    attn_block_k: int = 1024

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm", "hybrid") and self.ssm_heads == 0:
            object.__setattr__(self, "ssm_heads",
                               (self.d_model * self.ssm_expand) // 64)

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = v * d                                    # embed
        if not self.tie_embeddings:
            n += v * d                               # unembed
        for layer in range(self.num_layers):
            n += self._layer_params(layer)
        n += d                                       # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        d, v = self.d_model, self.vocab_size
        n = 2 * v * d if not self.tie_embeddings else v * d
        for layer in range(self.num_layers):
            n += self._layer_params(layer, active_only=True)
        return n + d

    def _layer_params(self, layer: int, active_only: bool = False) -> int:
        d, f = self.d_model, self.d_ff
        n = 2 * d                                    # norms
        if self.family in ("ssm",) or (
                self.family == "hybrid" and True):
            # mamba2 block params
            di, s = self.d_inner, self.ssm_state
            heads = self.ssm_heads
            n_m = d * (2 * di + 2 * s * 1 + heads)   # in_proj(z,x)+B,C+dt
            n_m += di * d                            # out_proj
            n_m += self.conv_width * (di + 2 * s)    # conv
            n_m += 2 * heads                         # A, D
            if self.family == "ssm":
                return n + n_m
            # hybrid: mamba every layer + shared attn params counted once
            n += n_m
            if self.hybrid_attn_every and layer == 0:
                hd = self.head_dim
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                n += self.num_heads * hd * d
                n += 3 * d * f                       # shared MLP
            return n
        # attention
        hd = self.head_dim
        if self.attention == "mla":
            qr, kr, rd, vd = (self.q_lora_rank, self.kv_lora_rank,
                              self.rope_head_dim, self.v_head_dim or hd)
            n += d * qr + qr * self.num_heads * (hd + rd)
            n += d * (kr + rd) + kr * self.num_heads * (hd + vd)
            n += self.num_heads * vd * d
            n += qr + kr                             # latent norms
        else:
            n += d * hd * (self.num_heads + 2 * self.num_kv_heads)
            n += self.num_heads * hd * d
            if self.qkv_bias:
                n += hd * (self.num_heads + 2 * self.num_kv_heads)
        # mlp / moe
        is_moe = (self.num_experts > 0 and layer >= self.first_dense_layers
                  and (layer % self.moe_every == 0 or self.moe_every == 1))
        if is_moe:
            fe = self.moe_d_ff or f
            per_expert = (3 if self.gated_mlp else 2) * d * fe
            n += d * self.num_experts                # router
            n += self.num_shared_experts * (3 if self.gated_mlp else 2) * d * f
            experts = (self.top_k if active_only else self.num_experts)
            n += experts * per_expert
        else:
            n += (3 if self.gated_mlp else 2) * d * f
        return n


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what to lower and at what size."""
    name: str                        # train_4k | prefill_32k | ...
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatch: Optional[int] = None  # grad-accum microbatch (train only)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# long_500k requires sub-quadratic sequence mixing (DESIGN.md §5): only the
# SSM/hybrid archs run it; pure-attention archs record an explicit skip.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")
