"""Composable decoder-only LM covering all 10 assigned architectures.

A config maps to a list of *stacks*; each stack is a repeating *pattern* of
block kinds scanned over its groups (``lax.scan`` + optional remat), so HLO
size is independent of depth:

    dense LMs       [( ["dense"], num_layers )]
    deepseek-v3     [( ["dense"], 3 ), ( ["moe"], 58 )]
    llama4          [( ["dense", "moe"], 24 )]          # interleaved
    mamba2          [( ["mamba"], 48 )]
    zamba2          [( ["mamba"]*5 + ["mamba_attn"], 9 )]  # shared attn blk

``mamba_attn`` applies the *shared* transformer block (zamba2's weight-tied
attention+MLP) after its mamba mixer; its params live once at the top level
and each invocation keeps its own KV cache.

Modality stubs (assignment): VLM prepends pre-computed patch embeddings via
a learned projection; audio sums EnCodec-codebook embeddings and emits one
head per codebook.  Frontends themselves are out of scope.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding_rules import batch_axes, shard
from repro.models import attention, common, mlp, ssm
from repro.models.config import ModelConfig

PATCH_EMBED_DIM = 1024          # CLIP-style stub feature width


# ------------------------------------------------------------------ pattern
def stacks_of(cfg: ModelConfig) -> list[tuple[list[str], int]]:
    if cfg.family == "ssm":
        return [(["mamba"], cfg.num_layers)]
    if cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        return [(["mamba"] * (e - 1) + ["mamba_attn"], cfg.num_layers // e)]
    if cfg.family == "moe":
        out = []
        if cfg.first_dense_layers:
            out.append((["dense"], cfg.first_dense_layers))
        rest = cfg.num_layers - cfg.first_dense_layers
        if cfg.moe_every > 1:
            pat = ["dense"] * (cfg.moe_every - 1) + ["moe"]
            out.append((pat, rest // cfg.moe_every))
        else:
            out.append((["moe"], rest))
        return out
    return [(["dense"], cfg.num_layers)]


# --------------------------------------------------------------------- init
def _init_block(key, kind: str, cfg: ModelConfig):
    ks = common.keygen(key)
    dt = common.dtype_of(cfg.dtype)
    d = cfg.d_model
    if kind in ("mamba", "mamba_attn"):
        return {"norm1": jnp.ones((d,), dt),
                "mamba": ssm.init_mamba(next(ks), cfg)}
    attn_init = (attention.init_mla if cfg.attention == "mla"
                 else attention.init_gqa)
    p = {"norm1": jnp.ones((d,), dt), "attn": attn_init(next(ks), cfg),
         "norm2": jnp.ones((d,), dt)}
    if kind == "moe":
        p["moe"] = mlp.init_moe(next(ks), cfg)
    else:
        p["mlp"] = mlp.init_mlp(next(ks), cfg)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    ks = common.keygen(key)
    dt = common.dtype_of(cfg.dtype)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict[str, Any] = {}
    if cfg.num_codebooks:
        params["embedding"] = jnp.stack([
            common.embed_init(next(ks), v, d, dt)
            for _ in range(cfg.num_codebooks)])
        params["unembed"] = common.dense_init(next(ks), d,
                                              (cfg.num_codebooks * v,), dt)
    else:
        params["embedding"] = common.embed_init(next(ks), v, d, dt)
        params["unembed"] = common.dense_init(next(ks), d, (v,), dt)
    if cfg.num_patches:
        params["patch_proj"] = common.dense_init(next(ks), PATCH_EMBED_DIM,
                                                 (d,), dt)
    if cfg.family == "hybrid":
        k = next(ks)
        params["shared_attn"] = {
            "norm1": jnp.ones((d,), dt),
            "attn": attention.init_gqa(jax.random.fold_in(k, 0), cfg),
            "norm2": jnp.ones((d,), dt),
            "mlp": mlp.init_mlp(jax.random.fold_in(k, 1), cfg),
        }
    stacks = []
    for pattern, groups in stacks_of(cfg):
        gkeys = jax.random.split(next(ks), groups)
        blocks = {}
        for i, kind in enumerate(pattern):
            blocks[f"block{i}"] = jax.vmap(
                lambda kk, kind=kind: _init_block(
                    jax.random.fold_in(kk, i), kind, cfg))(gkeys)
        stacks.append(blocks)
    params["stacks"] = stacks
    params["final_norm"] = jnp.ones((d,), dt)
    return params


def param_shapes(cfg: ModelConfig):
    """Dry-run parameter skeleton (no allocation)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ------------------------------------------------------------------- embed
def embed_inputs(params, cfg: ModelConfig, batch: dict):
    """batch → (h (B, L, D), positions (B, L))."""
    tokens = batch["tokens"]
    if cfg.num_codebooks:                      # audio: (B, K, L)
        h = sum(params["embedding"][k][tokens[:, k]]
                for k in range(cfg.num_codebooks))
        b, L = tokens.shape[0], tokens.shape[2]
    else:
        h = params["embedding"][tokens]        # (B, L, D)
        b, L = tokens.shape
    if cfg.num_patches and "patch_embeds" in batch:
        patches = batch["patch_embeds"] @ params["patch_proj"]
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
        L = L + cfg.num_patches
    positions = jnp.broadcast_to(jnp.arange(L), (b, L))
    return shard(h, batch_axes(), None, None), positions


def _logits(params, cfg, h):
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["unembed"]
    logits = shard(logits, batch_axes(), None, "model")
    if cfg.num_codebooks:
        b, L, _ = logits.shape
        logits = logits.reshape(b, L, cfg.num_codebooks, cfg.vocab_size)
    return logits


# ------------------------------------------------------------------ blocks
def _apply_block(kind, p, h, positions, cfg, shared):
    """Returns (h, aux_loss, cache_out) — cache_out only meaningful in
    prefill (k/v or ssm state) and is None in plain training."""
    aux = jnp.float32(0)
    cache = None
    if kind in ("mamba", "mamba_attn"):
        out, cache = ssm.mamba_forward(p["mamba"],
                                       common.rms_norm(h, p["norm1"],
                                                       cfg.norm_eps), cfg)
        h = h + out
        if kind == "mamba_attn":
            sp = shared
            a_out, kv = attention.gqa_forward(
                sp["attn"], common.rms_norm(h, sp["norm1"], cfg.norm_eps),
                positions, cfg)
            h = h + a_out
            h = h + mlp.mlp_forward(
                sp["mlp"], common.rms_norm(h, sp["norm2"], cfg.norm_eps), cfg)
            cache = (cache, kv)
        return h, aux, cache
    attn_fwd = (attention.mla_forward if cfg.attention == "mla"
                else attention.gqa_forward)
    a_out, kv = attn_fwd(p["attn"],
                         common.rms_norm(h, p["norm1"], cfg.norm_eps),
                         positions, cfg)
    h = h + a_out
    x2 = common.rms_norm(h, p["norm2"], cfg.norm_eps)
    if kind == "moe":
        m_out, aux = mlp.moe_forward(p["moe"], x2, cfg)
    else:
        m_out = mlp.mlp_forward(p["mlp"], x2, cfg)
    return h + m_out, aux, kv


# ----------------------------------------------------------------- forward
def forward(params, cfg: ModelConfig, batch: dict, *, collect_cache=False):
    """Training/prefill forward.  Returns (logits, aux_loss, caches)."""
    h, positions = embed_inputs(params, cfg, batch)
    shared = params.get("shared_attn")
    caches = []
    total_aux = jnp.float32(0)
    for (pattern, groups), stack_p in zip(stacks_of(cfg), params["stacks"]):

        def group_fn(h, gp, pattern=pattern):
            if cfg.fsdp_per_layer_gather:
                from repro.distributed.sharding_rules import constrain_params
                gp = constrain_params(gp)
            aux = jnp.float32(0)
            cache_out = {}
            for i, kind in enumerate(pattern):
                h = shard(h, batch_axes(), "model", None)   # SP boundary
                h, a, c = _apply_block(kind, gp[f"block{i}"], h, positions,
                                       cfg, shared)
                aux += a
                if collect_cache:
                    cache_out[f"block{i}"] = c
            return h, (aux, cache_out)

        body = (jax.checkpoint(group_fn,
                               policy=jax.checkpoint_policies.nothing_saveable)
                if cfg.remat else group_fn)
        h, (auxs, cache) = jax.lax.scan(body, h, stack_p)
        caches.append(cache)
        total_aux = total_aux + jnp.sum(auxs)
    logits = _logits(params, cfg, h)
    return logits, total_aux, (caches if collect_cache else None)


def loss_fn(params, cfg: ModelConfig, batch: dict, aux_coef: float = 0.01):
    logits, aux, _ = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.num_codebooks:                       # (B, K, L) → (B, L, K)
        labels = jnp.swapaxes(labels, 1, 2)
    if cfg.num_patches and "patch_embeds" in batch:
        pad = jnp.full((*labels.shape[:-1], cfg.num_patches), -1,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=-1)
    loss = common.cross_entropy_loss(logits, labels)
    return loss + aux_coef * aux, {"ce": loss, "aux": aux}
