"""Shared neural-net primitives (hand-rolled; no flax in this environment).

Parameters are nested dicts of jnp arrays.  Every ``init_*`` has a matching
``spec_*``-style sharding entry produced by ``distributed.sharding_rules``;
initializers are pure functions of a key so ``jax.eval_shape`` gives the
dry-run parameter skeleton without allocating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ------------------------------------------------------------------ layers
def rms_norm(x, scale, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            ).astype(x.dtype) * scale


def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                      # nemotron: squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., L, H, D) rotary over D; positions: (..., L)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., L, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (...,L,1,D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- inits
def dense_init(key, in_dim, out_dims, dtype, scale=None):
    """Fan-in scaled normal; out_dims may be a tuple for fused projections."""
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, *out_dims), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab, dim, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32)).astype(dtype)


def keygen(key):
    """Infinite fold-in key generator for sequential init calls."""
    i = 0
    while True:
        yield jax.random.fold_in(key, i)
        i += 1


def cross_entropy_loss(logits, labels, *, z_loss: float = 1e-4,
                       ignore_id: int = -1):
    """Token cross-entropy with optional z-loss; logits (..., V) fp32 math.

    Computed via logsumexp so a vocab-sharded logits tensor reduces with one
    collective (GSPMD) instead of materializing a replicated softmax.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - label_logit
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    mask = (labels != ignore_id)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
