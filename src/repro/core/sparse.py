"""Sparse-frontier traversal engine: active-tile compaction over edge blocks.

The paper's premise (Fig. 9) is that the unified frontier COLLAPSES after
the first couple of levels — yet the dense sweep (`traversal.fused_step`)
gathers and Bernoulli-samples every padded edge at every level.  This
module makes per-level work proportional to the *active* part of the graph
instead:

  * Host-side, ONCE per graph: edges are grouped by their source row-block
    (``tile_rows`` rows per block, the same 128-row tiles `_tile_activity`
    measures) and padded into fixed-size **edge blocks** of ``edge_block``
    slots each (`FrontierIndex`) — the tile-id → edge-block index.
  * Per level, traced: compute the active row-blocks from the packed
    frontier, compact the ids of their edge blocks into a padded capacity
    buffer, gather ONLY those blocks' edges, and run expansion +
    `rng.bernoulli_word` over the gathered edges — per-level FLOPs and RNG
    traffic scale with ``active_blocks × edge_block`` instead of ``E``.

Capacity buffers need static shapes, so the compaction runs on a **ladder
of power-of-two buckets** (`bucket_ladder`): a nested ``lax.cond`` picks
the smallest bucket that fits the level's active-block count at runtime,
and the top rung always equals the total block count, so no level can
overflow — there is no separate dense fallback to keep bit-equal.  The
ladder is a static tuple, so recompiles are bounded by its length (≤ ~5),
and the whole step stays traceable: it runs unchanged inside
``lax.while_loop``, ``lax.map`` batch blocks, and ``shard_map`` bodies.

Bit-identity with the dense sweep is structural, not statistical: the
counter RNG is keyed by CSR edge id, so a gathered edge draws the exact
word the dense sweep would, and every *skipped* edge has no active source
color — its dense contribution is zero.  The same argument covers the
per-level work counters (`TraversalStats.fused_edge_visits` counts edges
whose source row carries any active color — all of which are gathered), so
sparse and dense agree on the counters exactly, which `scripts/ci.sh`
asserts as a deterministic no-flake guard.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, rng
from repro.core.traversal import (TraversalResult, TraversalStats,
                                  _scatter_or, _tile_activity, init_frontier)
from repro.graph.csr import Graph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FrontierIndex:
    """Edge blocks grouped by source row-block (host-built, device-resident).

    All per-edge arrays are ``(NB + 1, EB)`` — the extra trailing block is
    an all-invalid null block that compaction's ``fill_value`` indexes, so
    padded capacity slots gather inert edges (prob 0, valid False).
    ``blk_rowblock`` is ``(NB,)`` — the source row-block of each REAL
    block, the key the per-level activity gather compacts on.
    """
    blk_src: jnp.ndarray       # (NB+1, EB) int32   edge source vertex
    blk_dst: jnp.ndarray       # (NB+1, EB) int32   edge destination vertex
    blk_prob: jnp.ndarray      # (NB+1, EB) float32 IC prob / LT in-weight
    blk_eid: jnp.ndarray       # (NB+1, EB) uint32  CSR edge id (RNG counter)
    blk_valid: jnp.ndarray     # (NB+1, EB) bool    real CSR slot (incl. CSR
    #                            padding edges — the dense sweep counts them)
    blk_cb: jnp.ndarray | None  # (NB+1, EB) f32 LT selection-CDF prefix
    blk_rowblock: jnp.ndarray  # (NB,) int32 source row-block per real block
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_blocks: int = dataclasses.field(metadata=dict(static=True))
    edge_block: int = dataclasses.field(metadata=dict(static=True))
    tile_rows: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_row_blocks(self) -> int:
        return -(-self.num_vertices // self.tile_rows)


def build_frontier_index(g_rev: Graph, tile_rows: int = 128,
                         edge_block: int = 128,
                         cb: np.ndarray | None = None) -> FrontierIndex:
    """Group the reversed graph's edges by source row-block (host-side).

    Every CSR array slot rides along — including the prob-0 CSR padding
    edges (src 0), because the dense sweep's work counters include them
    whenever their source row is active and the sparse counters must agree
    exactly.  ``cb`` attaches the LT selection-CDF prefixes
    (`lt.selection_cum_before`) in the same block layout.
    """
    e_pad = g_rev.padded_edges
    src = np.asarray(g_rev.src)[:e_pad]
    dst = np.asarray(g_rev.dst)[:e_pad]
    prob = np.asarray(g_rev.prob)[:e_pad]
    eid = np.arange(e_pad, dtype=np.uint32)
    cb = None if cb is None else np.asarray(cb, np.float32)[:e_pad]

    rb = src // tile_rows
    order = np.argsort(rb, kind="stable")
    nrb = -(-g_rev.num_vertices // tile_rows)
    counts = np.bincount(rb, minlength=nrb)
    blocks_per = -(-counts // edge_block)          # 0 for empty row-blocks
    nb = int(blocks_per.sum())

    def alloc(dtype, fill=0):
        return np.full((nb + 1, edge_block), fill, dtype)

    S, D = alloc(np.int32), alloc(np.int32)
    P, E = alloc(np.float32), alloc(np.uint32)
    V = alloc(bool, False)
    C = alloc(np.float32) if cb is not None else None
    rowblock = np.zeros(nb, np.int32)

    pos = 0          # read cursor into the rb-sorted edge order
    blk = 0
    for r in range(nrb):
        n = int(counts[r])
        if not n:
            continue
        sel = order[pos:pos + n]
        pos += n
        k = int(blocks_per[r])
        flat = slice(blk * edge_block, blk * edge_block + n)
        S.reshape(-1)[flat] = src[sel]
        D.reshape(-1)[flat] = dst[sel]
        P.reshape(-1)[flat] = prob[sel]
        E.reshape(-1)[flat] = eid[sel]
        V.reshape(-1)[flat] = True
        if C is not None:
            C.reshape(-1)[flat] = cb[sel]
        rowblock[blk:blk + k] = r
        blk += k

    return FrontierIndex(
        blk_src=jnp.asarray(S), blk_dst=jnp.asarray(D),
        blk_prob=jnp.asarray(P), blk_eid=jnp.asarray(E),
        blk_valid=jnp.asarray(V),
        blk_cb=None if C is None else jnp.asarray(C),
        blk_rowblock=jnp.asarray(rowblock),
        num_vertices=g_rev.num_vertices, num_blocks=nb,
        edge_block=edge_block, tile_rows=tile_rows)


def patch_frontier_index(fidx: FrontierIndex, g_rev: Graph,
                         touched_row_blocks,
                         cb: np.ndarray | None = None) -> FrontierIndex:
    """Re-derive ONLY the edge blocks of ``touched_row_blocks`` from a
    values-mutated graph — the churn-priced alternative to the O(|E|)
    host rebuild after a streaming delta.

    Precondition (the caller's to check — `Sampler.rebind` compares the
    edge arrays): ``g_rev`` has the SAME ``(src, dst)`` layout and padded
    length as the graph ``fidx`` was built from, i.e. the delta only
    changed probabilities in place (tombstone / resurrect / LT renorm).
    Then block membership, edge ids, and validity are all unchanged, and
    the patch is a pure gather: for every selected block,
    ``prob = where(valid, g_rev.prob[eid], 0)`` — exactly what
    `build_frontier_index` writes — plus the same for the LT
    selection-CDF prefixes when the index carries them.  Bit-identical
    to a fresh build by construction; cost scales with the touched
    blocks, not E.
    """
    if (fidx.blk_cb is None) != (cb is None):
        raise ValueError("cb must be given iff the index carries blk_cb")
    sel = np.isin(np.asarray(fidx.blk_rowblock),
                  np.asarray(touched_row_blocks, np.int64))
    ids = np.flatnonzero(sel)
    if not len(ids):
        return fidx
    ids_j = jnp.asarray(ids, jnp.int32)
    eid = fidx.blk_eid[ids_j]                       # (k, EB) uint32
    valid = fidx.blk_valid[ids_j]
    vals = jnp.where(valid, jnp.asarray(g_rev.prob)[eid], jnp.float32(0))
    fields = {"blk_prob": fidx.blk_prob.at[ids_j].set(vals)}
    if cb is not None:
        cbv = jnp.where(valid, jnp.asarray(cb, jnp.float32)[eid],
                        jnp.float32(0))
        fields["blk_cb"] = fidx.blk_cb.at[ids_j].set(cbv)
    return dataclasses.replace(fidx, **fields)


def bucket_ladder(num_blocks: int, capacity: int = 0) -> tuple[int, ...]:
    """Static capacity buckets for the compaction buffer.

    The top rung always equals ``num_blocks`` (compaction can never
    overflow — correctness never depends on the knob).  ``capacity = 0``
    (auto) builds a geometric ladder 8, 64, 512, … so a level pays for the
    smallest bucket that fits its active count; an explicit ``capacity``
    gives a two-rung ladder {pow2(capacity), num_blocks} for callers that
    profiled their workload (`benchmarks/bench_frontier_profile.py` prints
    the occupancy histogram this knob wants).
    """
    n = max(int(num_blocks), 1)
    if capacity and capacity > 0:
        top = 1
        while top < min(capacity, n):
            top *= 2
        rungs = {min(top, n), n}
    else:
        rungs = {n}
        r = 8
        while r < n:
            rungs.add(r)
            r *= 8
    return tuple(sorted(rungs))


def row_block_activity(frontier: jnp.ndarray, tile_rows: int) -> jnp.ndarray:
    """(n_row_blocks,) bool — row blocks holding ≥ 1 active vertex."""
    v = frontier.shape[0]
    act = bitmask.count_colors(frontier) > 0
    act = jnp.pad(act, (0, (-v) % tile_rows))
    return act.reshape(-1, tile_rows).any(axis=1)


def cond_ladder(count, ladder: tuple[int, ...], step_at):
    """Run ``step_at(K)`` for the smallest ladder rung with ``count ≤ K``
    via nested ``lax.cond`` — the last rung runs unconditionally (ladders
    from `bucket_ladder` end at the total block count, so it always fits).
    ``step_at(K)`` must return a one-operand callable; all rungs must
    agree on output shapes."""
    def chain(rungs):
        if len(rungs) == 1:
            return step_at(rungs[0])
        return lambda op: jax.lax.cond(count <= rungs[0], step_at(rungs[0]),
                                       chain(rungs[1:]), op)
    return chain(list(ladder))(None)


def _sparse_step(fidx: FrontierIndex, frontier, visited, level, seed,
                 ladder: tuple[int, ...], u=None):
    """One compacted expansion level.  ``visited`` must already include the
    current frontier (level-sync semantics).  Returns
    ``(next_frontier, fused_visits, unfused_visits, grid_steps)`` — the
    visit counters are bit-equal to the dense sweep's (`fused_step` info
    dict); ``grid_steps`` is the capacity rung that ran (the compacted
    work-list length the level paid for).

    ``u = None`` selects the IC per-(edge, color, level) Bernoulli gate;
    an ``(V, W·32)`` LT uniform table (`kernels.ref.lt_selection_uniforms`)
    selects the fixed live-edge gate instead (level-independent, computed
    once per traversal by the caller).
    """
    num_words = frontier.shape[1]
    act = row_block_activity(frontier, fidx.tile_rows)
    blk_act = act[fidx.blk_rowblock]                     # (NB,)
    count = jnp.sum(blk_act.astype(jnp.int32))

    def step_at(cap: int):
        def run(_):
            ids = jnp.nonzero(blk_act, size=cap,
                              fill_value=fidx.num_blocks)[0]
            s, d = fidx.blk_src[ids], fidx.blk_dst[ids]
            p, valid = fidx.blk_prob[ids], fidx.blk_valid[ids]
            fr_src = frontier[s]                         # (K, EB, W)
            if u is None:
                word_ids = jnp.arange(num_words, dtype=jnp.uint32)
                eid = fidx.blk_eid[ids]
                gate = jax.vmap(
                    lambda wd: rng.bernoulli_word(seed, level, eid, wd, p),
                    out_axes=-1)(word_ids)               # (K, EB, W)
            else:
                cbt = fidx.blk_cb[ids]
                ug = u[d]                                # (K, EB, W·32)
                sel = jnp.logical_and(ug >= cbt[..., None],
                                      ug < (cbt + p)[..., None])
                gate = rng.pack_bool_word(
                    sel.reshape(*p.shape, -1, 32))       # (K, EB, W)
            contrib = fr_src & gate & ~visited[d]
            nf = _scatter_or(jnp.zeros_like(visited), d.reshape(-1),
                             contrib.reshape(-1, num_words)) & ~visited
            active_src = bitmask.count_colors(fr_src)    # (K, EB)
            fused = jnp.sum(jnp.where(valid, (active_src > 0)
                                      .astype(jnp.int32), 0))
            unfused = jnp.sum(jnp.where(valid, active_src, 0))
            return nf, fused, unfused, jnp.int32(cap)
        return run

    return cond_ladder(count, ladder, step_at)


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "ladder"))
def run_fused_sparse(fidx: FrontierIndex, starts, num_colors: int, seed,
                     max_levels: int = 64,
                     ladder: tuple[int, ...] | None = None) -> TraversalResult:
    """`traversal.run_fused` on the sparse-frontier engine — visited mask
    AND every `TraversalStats` field bit-equal to the dense sweep."""
    if ladder is None:
        ladder = bucket_ladder(fidx.num_blocks)
    v = fidx.num_vertices
    frontier = init_frontier(v, num_colors, starts)
    visited = bitmask.make_mask(v, num_colors)
    zeros_i = jnp.zeros((max_levels,), jnp.int32)
    zeros_f = jnp.zeros((max_levels,), jnp.float32)
    stats = TraversalStats(jnp.int32(0), zeros_i, zeros_i, zeros_i, zeros_i,
                           zeros_f, zeros_f, zeros_i)

    def cond(carry):
        frontier, _, level, _ = carry
        return jnp.logical_and(bitmask.any_set(frontier), level < max_levels)

    def body(carry):
        frontier, visited, level, stats = carry
        tile_frac = _tile_activity(frontier)
        per_row = bitmask.count_colors(frontier)
        fr_vertices = jnp.sum((per_row > 0).astype(jnp.int32))
        fr_colors = jnp.sum(per_row)
        visited = visited | frontier                     # Listing 1 line 8
        nf, fused, unfused, gs = _sparse_step(
            fidx, frontier, visited, level.astype(jnp.uint32),
            jnp.asarray(seed, jnp.uint32), ladder)
        occ = jnp.where(fr_vertices > 0,
                        fr_colors.astype(jnp.float32)
                        / jnp.maximum(fr_vertices, 1)
                        / jnp.float32(num_colors), 0.0)
        stats = TraversalStats(
            levels_run=stats.levels_run + 1,
            fused_edge_visits=stats.fused_edge_visits.at[level].set(fused),
            unfused_edge_visits=stats.unfused_edge_visits.at[level].set(
                unfused),
            frontier_vertices=stats.frontier_vertices.at[level].set(
                fr_vertices),
            frontier_colors=stats.frontier_colors.at[level].set(fr_colors),
            occupancy_num=stats.occupancy_num.at[level].set(occ),
            active_tile_frac=stats.active_tile_frac.at[level].set(tile_frac),
            grid_steps=stats.grid_steps.at[level].set(gs),
        )
        return nf, visited, level + 1, stats

    frontier, visited, _, stats = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0), stats))
    visited = visited | frontier
    return TraversalResult(visited=visited, stats=stats)


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "ladder"))
def run_fused_lt_sparse(fidx: FrontierIndex, starts, num_colors: int, seed,
                        max_levels: int = 64,
                        ladder: tuple[int, ...] | None = None) -> jnp.ndarray:
    """`lt.run_fused_lt` on the sparse-frontier engine (visited (V, W)).

    The LT live-edge selection is recomputed per gathered edge from the
    level-independent uniform table — the same (seed, 0x17, dst, color)
    counters as `lt.selection_mask_from_cb`, so the result is bit-identical
    to the dense LT sweep without ever materializing the (E, W) mask.
    """
    from repro.kernels import ref as kref

    if ladder is None:
        ladder = bucket_ladder(fidx.num_blocks)
    if fidx.blk_cb is None:
        raise ValueError("LT needs a FrontierIndex built with cb="
                         "lt.selection_cum_before(g_rev)")
    seed = jnp.asarray(seed, jnp.uint32)
    u = kref.lt_selection_uniforms(seed, fidx.num_vertices, num_colors)
    frontier = init_frontier(fidx.num_vertices, num_colors, starts)
    visited = jnp.zeros_like(frontier)

    def cond(carry):
        fr, _, level = carry
        return jnp.logical_and(bitmask.any_set(fr), level < max_levels)

    def body(carry):
        fr, vis, level = carry
        vis = vis | fr
        nf, _, _, _ = _sparse_step(fidx, fr, vis, level.astype(jnp.uint32),
                                   seed, ladder, u=u)
        return nf, vis, level + 1

    fr, vis, _ = jax.lax.while_loop(cond, body,
                                    (frontier, visited, jnp.int32(0)))
    return vis | fr


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "ladder",
                                   "diffusion"))
def sparse_block(fidx: FrontierIndex, starts, seeds, num_colors: int,
                 max_levels: int, ladder: tuple[int, ...],
                 diffusion: str = "ic"):
    """Fused multi-batch pool build on the sparse engine: ONE dispatch
    traverses a whole block of batches via ``lax.map`` (one batch's
    transients at a time on the device).

    starts (B, C) int32, seeds (B,) uint32 → (visited (B, V, W),
    fused (B,), unfused (B,)) — LT carries the -1 "not instrumented"
    sentinel like the dense LT path.
    """
    def one(args):
        st, sd = args
        if diffusion == "lt":
            vis = run_fused_lt_sparse(fidx, st, num_colors, sd,
                                      max_levels=max_levels, ladder=ladder)
            return vis, jnp.int32(-1), jnp.int32(-1)
        res = run_fused_sparse(fidx, st, num_colors, sd,
                               max_levels=max_levels, ladder=ladder)
        return (res.visited, res.stats.fused_edge_visits.sum(),
                res.stats.unfused_edge_visits.sum())

    return jax.lax.map(one, (starts, seeds))


def profile_traversal(fidx: FrontierIndex, starts, num_colors: int, seed,
                      max_levels: int = 64,
                      ladder: tuple[int, ...] | None = None,
                      diffusion: str = "ic") -> list[dict]:
    """Host-paced level loop for profiling: per level, the active
    row-block / edge-block counts, the ladder bucket that level would pick,
    and the work counters — the data `bench_frontier_profile` histograms
    so the ``frontier_capacity`` knob can be set from evidence.

    Runs the SAME traced `_sparse_step` as the production while_loop (at
    the level's chosen bucket), so the profile is the real execution, not
    a model of it.
    """
    from repro.kernels import ref as kref

    if ladder is None:
        ladder = bucket_ladder(fidx.num_blocks)
    seed = jnp.asarray(seed, jnp.uint32)
    u = (kref.lt_selection_uniforms(seed, fidx.num_vertices, num_colors)
         if diffusion == "lt" else None)
    fr = init_frontier(fidx.num_vertices, num_colors, starts)
    vis = jnp.zeros_like(fr)
    rowblocks = np.asarray(fidx.blk_rowblock)

    @partial(jax.jit, static_argnames=("cap",))
    def step(fr, vis, level, cap: int):
        return _sparse_step(fidx, fr, vis, level, seed, (cap,), u=u)

    out = []
    level = 0
    while level < max_levels and bool(bitmask.any_set(fr)):
        act = np.asarray(row_block_activity(fr, fidx.tile_rows))
        n_blk = int(act[rowblocks].sum())
        bucket = next(k for k in ladder if n_blk <= k)
        vis = vis | fr
        fr, fused, unfused, _ = step(fr, vis, jnp.uint32(level), bucket)
        out.append(dict(
            level=level,
            active_row_blocks=int(act.sum()),
            active_edge_blocks=n_blk,
            bucket=bucket,
            fused_edge_visits=int(fused),
            unfused_edge_visits=int(unfused),
        ))
        level += 1
    return out
