"""IMM influence maximization (Tang, Shi, Xiao 2015) on fused-BPT samples.

Pipeline (paper §2): sample θ RRR sets by fused reverse BPTs, then greedy
max-k-cover over the collection; the cover fraction × n estimates σ(S), and
the martingale bound on θ guarantees (1 − 1/e − ε) approximation.

Seed selection is matmul-shaped on TPU: the uncovered-color marginal gains
are popcount reductions over the columnar bitmask (the coverage kernel), not
atomic list walks — no GPU-style RRR linked lists anywhere.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, rrr
from repro.graph import csr
from repro.kernels import ops


# --------------------------------------------------------------- θ bound
def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def theta_bound(n: int, k: int, eps: float, ell: float = 1.0) -> int:
    """IMM λ*/LB worst-case sample count with LB = 1 (Tang et al. Thm 1).

    The driver uses the iterative LB estimation (``estimate_theta``); this
    closed form is the hard ceiling.
    """
    ell = ell * (1 + math.log(2) / math.log(n))
    alpha = math.sqrt(ell * math.log(n) + math.log(2))
    beta = math.sqrt((1 - 1 / math.e)
                     * (_log_comb(n, k) + ell * math.log(n) + math.log(2)))
    lam_star = 2 * n * ((1 - 1 / math.e) * alpha + beta) ** 2 / eps ** 2
    return int(math.ceil(lam_star))


def estimate_theta(g: csr.Graph, k: int, eps: float, ell: float = 1.0,
                   num_colors: int = 64, master_seed: int = 0,
                   max_batches_per_phase: int = 64) -> tuple[int, list]:
    """IMM sampling phase: iterative-halving lower bound on OPT → θ.

    Returns (θ, batches generated so far) — generated batches are *reused*
    by the selection phase (IMM's trick to avoid resampling).
    """
    n = g.num_vertices
    ell = ell * (1 + math.log(2) / math.log(n))
    eps_prime = math.sqrt(2) * eps
    lam_prime = ((2 + 2 * eps_prime / 3)
                 * (_log_comb(n, k) + ell * math.log(n)
                    + math.log(math.log2(max(n, 4))))
                 * n / eps_prime ** 2)
    g_rev = csr.transpose(g)
    batches: list[rrr.RRRBatch] = []
    lb = 1.0
    for i in range(1, max(int(math.log2(n)), 1)):
        x = n / (2 ** i)
        theta_i = int(math.ceil(lam_prime / x))
        want = min(-(-theta_i // num_colors), max_batches_per_phase)
        while len(batches) < want:
            batches.append(rrr.sample_batch(g_rev, num_colors, master_seed,
                                            len(batches)))
        theta_cur = len(batches) * num_colors
        seeds, cov = greedy_max_cover(rrr.stack_visited(batches), k,
                                      num_colors)
        if n * cov >= (1 + eps_prime) * x:
            lb = n * cov / (1 + eps_prime)
            break
    alpha = math.sqrt(ell * math.log(n) + math.log(2))
    beta = math.sqrt((1 - 1 / math.e)
                     * (_log_comb(n, k) + ell * math.log(n) + math.log(2)))
    lam_star = 2 * n * ((1 - 1 / math.e) * alpha + beta) ** 2 / eps ** 2
    return int(math.ceil(lam_star / lb)), batches


# ------------------------------------------------------ greedy max-k-cover
def greedy_max_cover(visited: jnp.ndarray, k: int, num_colors: int,
                     use_kernel: bool = True):
    """Greedy max-k-cover over a (B, V, W) RRR collection.

    Returns (seeds (k,) int32, covered fraction float).  Marginal gains are
    per-batch popcount reductions (`kernels.coverage`), summed over batches.
    """
    b, v, w = visited.shape
    theta = b * num_colors
    active = jnp.broadcast_to(
        jnp.asarray(bitmask.color_tail_mask(num_colors)), (b, w)).copy()
    count_fn = (jax.vmap(lambda vis, act: ops.cover_counts(vis, act))
                if use_kernel else
                jax.vmap(lambda vis, act: jnp.sum(
                    bitmask.popcount(vis & act[None, :]), -1).astype(jnp.int32)))

    seeds = []
    for _ in range(k):
        counts = count_fn(visited, active).sum(0)           # (V,)
        sel = int(jnp.argmax(counts))
        seeds.append(sel)
        active = active & ~visited[:, sel, :]
    covered = theta - int(jnp.sum(bitmask.popcount(active)))
    return np.asarray(seeds, np.int32), covered / theta


def coverage_of(visited: jnp.ndarray, seeds, num_colors: int) -> float:
    """Fraction of RRR sets hit by ``seeds`` (σ(S) ≈ n × this)."""
    b, v, w = visited.shape
    active = jnp.broadcast_to(
        jnp.asarray(bitmask.color_tail_mask(num_colors)), (b, w))
    for s in np.asarray(seeds):
        active = active & ~visited[:, int(s), :]
    theta = b * num_colors
    return (theta - int(jnp.sum(bitmask.popcount(active)))) / theta


# --------------------------------------------------------------- end-to-end
@dataclasses.dataclass(frozen=True)
class IMMResult:
    seeds: np.ndarray
    sigma_estimate: float       # expected influence of the seed set
    theta: int
    coverage: float
    num_batches: int
    fused_edge_visits: int
    unfused_edge_visits: int


def run_imm(g: csr.Graph, k: int, eps: float = 0.3, *, ell: float = 1.0,
            num_colors: int = 64, master_seed: int = 0,
            theta_cap: int | None = 100_000, **sample_kw) -> IMMResult:
    """Full IMM: θ estimation → top-up sampling → greedy selection."""
    theta, batches = estimate_theta(g, k, eps, ell, num_colors, master_seed)
    if theta_cap:
        theta = min(theta, theta_cap)
    g_rev = csr.transpose(g)
    while len(batches) * num_colors < theta:
        batches.append(rrr.sample_batch(g_rev, num_colors, master_seed,
                                        len(batches), **sample_kw))
    visited = rrr.stack_visited(batches)
    seeds, cov = greedy_max_cover(visited, k, num_colors)
    return IMMResult(
        seeds=seeds, sigma_estimate=cov * g.num_vertices,
        theta=len(batches) * num_colors, coverage=cov,
        num_batches=len(batches),
        fused_edge_visits=sum(b.fused_edge_visits for b in batches),
        unfused_edge_visits=sum(b.unfused_edge_visits for b in batches))


def simulate_influence(g: csr.Graph, seeds, num_trials: int = 512,
                       master_seed: int = 77) -> float:
    """σ(S) by forward IC: one color per trial, frontier starts at all of S.

    Under IC, activations from multiple seeds in one realization are exactly
    a BFS from the seed *set* on the realized subgraph — so a single-color
    traversal seeded at every s ∈ S is the correct per-trial sample. Trials
    ride in parallel as colors (distinct counters ⇒ independent subgraphs).
    """
    n = g.num_vertices
    colors = min(num_trials, 256)
    total, trials_done = 0, 0
    while trials_done < num_trials:
        c = min(colors, num_trials - trials_done)
        fr = bitmask.make_mask(n, c)
        for s in np.asarray(seeds):
            fr = bitmask.set_color(fr, jnp.full((c,), int(s), jnp.int32),
                                   jnp.arange(c, dtype=jnp.int32))
        res = _run_from_frontier(g, fr, c,
                                 jnp.uint32(master_seed + trials_done))
        total += int(jnp.sum(bitmask.popcount(res)))
        trials_done += c
    return total / num_trials


def _run_from_frontier(g: csr.Graph, frontier, num_colors: int, seed,
                       max_levels: int = 64):
    """Fused traversal from an arbitrary initial frontier; returns visited."""
    from repro.core import traversal as trav

    visited = jnp.zeros_like(frontier)

    def cond(carry):
        fr, _, lvl = carry
        return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

    def body(carry):
        fr, vis, lvl = carry
        nf, nv, _ = trav.fused_step(g, fr, vis, lvl, seed)
        return nf, nv, lvl + 1

    fr, vis, _ = jax.lax.while_loop(cond, body,
                                    (frontier, visited, jnp.int32(0)))
    return vis | fr
