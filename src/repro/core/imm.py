"""IMM influence maximization (Tang, Shi, Xiao 2015) on fused-BPT samples.

Pipeline (paper §2): sample θ RRR sets by fused reverse BPTs, then greedy
max-k-cover over the collection; the cover fraction × n estimates σ(S), and
the martingale bound on θ guarantees (1 − 1/e − ε) approximation.

Seed selection is matmul-shaped on TPU: the uncovered-color marginal gains
are popcount reductions over the columnar bitmask (the coverage kernel), not
atomic list walks — no GPU-style RRR linked lists anywhere.

The greedy inner loop is a single jit-compiled ``lax.fori_loop`` program
(``greedy_extend``): argmax selection and active-mask update stay on device,
with no per-iteration host round-trip.  The same program serves offline
``run_imm`` and the online query engine (`repro.serve.influence`), which
resumes it from arbitrary active masks for marginal-gain-with-exclusion
queries.

Sampling is pluggable through the *sketch pool* protocol: any object with
``num_colors``, ``master_seed`` and ``ensure(num_batches) -> list[RRRBatch]``
(e.g. ``serve.influence.sketch_store.SketchStore``) can back
``estimate_theta`` / ``run_imm``, making offline IMM just one client of a
long-lived sampled-sketch asset.
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, rrr
from repro.graph import csr
from repro.kernels import ops


# --------------------------------------------------------------- θ bound
def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1))


def _adjusted_ell(n: int, ell: float) -> float:
    return ell * (1 + math.log(2) / math.log(n))


def _lam_star_coeff(n: int, k: int, ell_adj: float) -> float:
    """λ*(ε) = coeff / ε² (Tang et al. Thm 1); ``ell_adj`` pre-adjusted."""
    alpha = math.sqrt(ell_adj * math.log(n) + math.log(2))
    beta = math.sqrt((1 - 1 / math.e)
                     * (_log_comb(n, k) + ell_adj * math.log(n) + math.log(2)))
    return 2 * n * ((1 - 1 / math.e) * alpha + beta) ** 2


def theta_bound(n: int, k: int, eps: float, ell: float = 1.0) -> int:
    """IMM λ*/LB worst-case sample count with LB = 1 (Tang et al. Thm 1).

    The driver uses the iterative LB estimation (``estimate_theta``); this
    closed form is the hard ceiling.
    """
    return int(math.ceil(
        _lam_star_coeff(n, k, _adjusted_ell(n, ell)) / eps ** 2))


def eps_bound_for_theta(n: int, k: int, theta: int, ell: float = 1.0,
                        opt_lb: float = 1.0) -> float:
    """Coverage-error bound a pool of ``theta`` RRR samples certifies.

    Exact inverse of the ``estimate_theta`` sample-count bound
    (θ = ⌈λ*(ε)/LB⌉ with λ* ∝ 1/ε²): the smallest ε whose required θ the
    pool already meets.  ``opt_lb`` is a lower bound on OPT (e.g. the
    greedy σ̂ from a top-k query, which the serving tier's autoscaler
    feeds in); the default 1 is the worst case.  Monotone in θ, so a
    controller can grow/shrink a pool against a target ε without
    re-running the sampling phase.
    """
    theta = max(int(theta), 1)
    return math.sqrt(_lam_star_coeff(n, k, _adjusted_ell(n, ell))
                     / (theta * max(opt_lb, 1.0)))


def estimate_theta(g: csr.Graph, k: int, eps: float, ell: float = 1.0,
                   num_colors: int | None = None,
                   master_seed: int | None = None,
                   max_batches_per_phase: int = 64,
                   g_rev: csr.Graph | None = None,
                   pool=None, spec=None, mesh=None,
                   sampler=None) -> tuple[int, list]:
    """IMM sampling phase: iterative-halving lower bound on OPT → θ.

    Returns (θ, batches generated so far) — generated batches are *reused*
    by the selection phase (IMM's trick to avoid resampling).

    ``g_rev``: prebuilt transpose(g); handed to the sampler so one reversal
    serves both the halving phase and the selection top-up.
    ``pool``: optional sketch pool (see module docstring); when given, the
    pool owns sampling and this function never builds a sampler itself.
    ``spec``/``mesh``: `repro.sampling.SamplerSpec` + mesh for the pool-less
    path (``sampling.resolve_spec`` policy: explicit num_colors/master_seed
    that disagree with the spec raise); ``sampler``: prebuilt
    `repro.sampling.Sampler` (overrides spec).
    """
    from repro import sampling

    spec = sampling.resolve_spec(spec, num_colors=num_colors,
                                 master_seed=master_seed)
    num_colors, master_seed = spec.num_colors, spec.master_seed
    n = g.num_vertices
    ell = _adjusted_ell(n, ell)
    eps_prime = math.sqrt(2) * eps
    lam_prime = ((2 + 2 * eps_prime / 3)
                 * (_log_comb(n, k) + ell * math.log(n)
                    + math.log(math.log2(max(n, 4))))
                 * n / eps_prime ** 2)
    if pool is None and sampler is None:
        sampler = sampling.make_sampler(g, spec, mesh=mesh, g_rev=g_rev)
    batches: list[rrr.RRRBatch] = []

    def grow(want: int) -> list[rrr.RRRBatch]:
        if pool is not None:
            return _pool_take(pool, want)
        if len(batches) < want:
            batches.extend(
                sampler.sample_many(range(len(batches), want)))
        return batches

    lb = 1.0
    for i in range(1, max(int(math.log2(n)), 1)):
        x = n / (2 ** i)
        theta_i = int(math.ceil(lam_prime / x))
        want = min(-(-theta_i // num_colors), max_batches_per_phase)
        cur = grow(want)
        vis = (pool.visited_stack()[:len(cur)] if pool is not None
               else rrr.stack_visited(cur))
        seeds, cov = greedy_max_cover(vis, k, num_colors)
        if n * cov >= (1 + eps_prime) * x:
            lb = n * cov / (1 + eps_prime)
            break
    lam_star = _lam_star_coeff(n, k, ell) / eps ** 2
    return int(math.ceil(lam_star / lb)), (batches if pool is None
                                           else pool.ensure(0))


def _pool_take(pool, want: int) -> list:
    """Exactly ``want`` batches from a sketch pool, as the sample prefix.

    Slicing keeps ``theta_cap`` meaningful against a pre-populated serving
    pool, and raising (rather than silently under-sampling) preserves the
    IMM θ bound when the pool's budget can't supply the batches.
    """
    got = pool.ensure(want)
    if len(got) < want:
        raise ValueError(
            f"sketch pool capacity {len(got)} < {want} batches required by "
            "IMM sampling — raise the pool's max_batches / memory budget, "
            "or lower θ (larger eps, smaller theta_cap)")
    return got[:want]


# ------------------------------------------------------ greedy max-k-cover
def _count_fn(use_kernel: bool):
    """(B, V, W) visited × (B, W) active → (B, V) marginal-gain counts."""
    if use_kernel:
        return ops.cover_counts_batched
    return jax.vmap(lambda vis, act: jnp.sum(
        bitmask.popcount(vis & act[None, :]), -1).astype(jnp.int32))


def greedy_extend_program(visited, active, k: int, use_kernel: bool,
                          all_reduce=None, embed_counts=None, fetch_row=None,
                          final_reduce=None):
    """k rounds of greedy selection as one on-device ``lax.fori_loop``.

    Each round computes all-vertex marginal gains with the coverage kernel,
    argmaxes on device, and strips the winner's colors from the active mask —
    no host synchronization until the caller fetches the result.

    ``all_reduce`` merges per-shard partial reductions when the batch dim is
    sharded (pass ``partial(lax.psum, axis_name=...)`` inside a shard_map;
    identity on one device).  Because the argmax runs on the *merged* counts
    — replicated after the collective — every shard selects the same seed
    with no second collective, and integer summation makes the sharded
    result bit-identical to the single-device one.

    The remaining hooks extend the same program to a pool whose VERTEX
    rows are additionally sharded over a model axis (`ShardedSketchStore`
    row sharding — each shard's ``visited`` is (B_loc, V/M, W)):

    * ``embed_counts`` places a shard's (V_loc,) local counts at its row
      offset in the global (Vp,) vector BEFORE ``all_reduce`` (which then
      psums over data AND model — disjoint offsets make the sum exact and
      the merged counts replicated, so the argmax stays collective-free);
    * ``fetch_row`` maps the selected GLOBAL vertex to its (B_loc, W)
      visited row (owning shard contributes, others zero, one psum over
      model) — the default is the local ``dynamic_index_in_dim``;
    * ``final_reduce`` merges the uncovered popcount — over the data axis
      ONLY when rows are sharded (``active`` is replicated across model
      shards; reusing ``all_reduce`` would overcount M×).  Defaults to
      ``all_reduce``.

    This is a trace-time program, not a jitted function: single-device
    callers go through ``greedy_extend``; the distributed query engine
    (`repro.serve.distributed.engine`) stages it inside a shard_map.
    """
    count = _count_fn(use_kernel)
    merge = all_reduce if all_reduce is not None else (lambda x: x)
    embed = embed_counts if embed_counts is not None else (lambda x: x)
    if fetch_row is None:
        def fetch_row(sel):
            return jax.lax.dynamic_index_in_dim(visited, sel, axis=1,
                                                keepdims=False)   # (B, W)
    final = final_reduce if final_reduce is not None else merge

    def body(i, carry):
        seeds, act = carry
        counts = merge(embed(count(visited, act).sum(0)))       # (Vp,)
        sel = jnp.argmax(counts).astype(jnp.int32)
        seeds = seeds.at[i].set(sel)
        return seeds, act & ~fetch_row(sel)

    seeds0 = jnp.zeros((k,), jnp.int32)
    seeds, active = jax.lax.fori_loop(0, k, body, (seeds0, active))
    uncovered = final(jnp.sum(bitmask.popcount(active)).astype(jnp.int32))
    return seeds, active, uncovered


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def _greedy_extend_jit(visited, active, k: int, use_kernel: bool):
    return greedy_extend_program(visited, active, k, use_kernel)


def initial_active(num_batches: int, num_colors: int) -> jnp.ndarray:
    """(B, W) all-colors-uncovered mask (tail bits past num_colors zeroed)."""
    w = bitmask.num_words(num_colors)
    return jnp.broadcast_to(
        jnp.asarray(bitmask.color_tail_mask(num_colors)), (num_batches, w))


def greedy_extend(visited: jnp.ndarray, active: jnp.ndarray, k: int,
                  use_kernel: bool = True):
    """Extend a partial cover by ``k`` greedy picks from ``active``.

    Returns (seeds (k,) int32 device array, new active (B, W), uncovered
    color count int32 scalar).  This is the shared incremental kernel: pass
    ``initial_active(...)`` for offline selection, or an exclusion-filtered
    mask for online marginal-gain queries.
    """
    return _greedy_extend_jit(visited, active, k, use_kernel)


def greedy_max_cover(visited: jnp.ndarray, k: int, num_colors: int,
                     use_kernel: bool = True):
    """Greedy max-k-cover over a (B, V, W) RRR collection.

    Returns (seeds (k,) int32, covered fraction float).  Thin host wrapper
    over ``greedy_extend`` — one device program, two fetches.
    """
    b, v, w = visited.shape
    theta = b * num_colors
    seeds, _, uncovered = greedy_extend(
        visited, initial_active(b, num_colors), k, use_kernel)
    return np.asarray(seeds), (theta - int(uncovered)) / theta


def greedy_max_cover_ref(visited: jnp.ndarray, k: int, num_colors: int,
                         use_kernel: bool = True):
    """Reference host-loop greedy (pre-refactor semantics) for equivalence
    tests: per-iteration host argmax, same tie-breaking as the jit path."""
    b, v, w = visited.shape
    theta = b * num_colors
    active = np.asarray(initial_active(b, num_colors)).copy()
    count = _count_fn(use_kernel)
    seeds = []
    for _ in range(k):
        counts = count(visited, jnp.asarray(active)).sum(0)     # (V,)
        sel = int(jnp.argmax(counts))
        seeds.append(sel)
        active &= ~np.asarray(visited[:, sel, :])
    covered = theta - int(np.unpackbits(active.view(np.uint8)).sum())
    return np.asarray(seeds, np.int32), covered / theta


def coverage_of(visited: jnp.ndarray, seeds, num_colors: int) -> float:
    """Fraction of RRR sets hit by ``seeds`` (σ(S) ≈ n × this)."""
    b, v, w = visited.shape
    active = initial_active(b, num_colors)
    for s in np.asarray(seeds):
        active = active & ~visited[:, int(s), :]
    theta = b * num_colors
    return (theta - int(jnp.sum(bitmask.popcount(active)))) / theta


# --------------------------------------------------------------- end-to-end
@dataclasses.dataclass(frozen=True)
class IMMResult:
    seeds: np.ndarray
    sigma_estimate: float       # expected influence of the seed set
    theta: int
    coverage: float
    num_batches: int
    fused_edge_visits: int
    unfused_edge_visits: int


def run_imm(g: csr.Graph, k: int, eps: float = 0.3, *, ell: float = 1.0,
            num_colors: int | None = None, master_seed: int | None = None,
            theta_cap: int | None = 100_000, pool=None,
            spec=None, mesh=None, **sample_kw) -> IMMResult:
    """Full IMM: θ estimation → top-up sampling → greedy selection.

    ``pool``: optional sketch pool (module docstring); batches come from and
    stay in the pool, so a serving process can reuse them for online queries.
    Because batch ``b`` is a pure function of ``(graph, master_seed, b)``,
    routing through a *fresh* (never-refreshed) pool with the same
    ``master_seed``/``num_colors`` reproduces the pool-less result exactly;
    selection always uses the first ``⌈θ/colors⌉`` pool slots, so a larger
    pre-populated pool still respects ``theta_cap``.  A pool whose capacity
    cannot supply θ raises rather than silently weakening the bound.

    ``spec``: `repro.sampling.SamplerSpec` choosing diffusion/backend for
    the pool-less path (``sampling.resolve_spec`` policy: explicit
    num_colors/master_seed that disagree with the spec raise); ``mesh``
    backs the ``data_parallel`` backend.  Legacy ``sample_batch`` kwargs
    are converted with a DeprecationWarning.
    """
    from repro import sampling

    explicit_spec = spec is not None
    spec = sampling.resolve_spec(spec, sample_kw, num_colors=num_colors,
                                 master_seed=master_seed)
    num_colors, master_seed = spec.num_colors, spec.master_seed
    if pool is not None:
        if explicit_spec and getattr(pool, "spec", None) is not None \
                and pool.spec.diffusion != spec.diffusion:
            raise ValueError(f"pool diffusion {pool.spec.diffusion!r} != "
                             f"requested {spec.diffusion!r}")
        if pool.num_colors != num_colors:
            raise ValueError(f"pool colors {pool.num_colors} != {num_colors}")
    sampler = None
    if pool is None:
        sampler = sampling.make_sampler(g, spec, mesh=mesh)
    theta, batches = estimate_theta(g, k, eps, ell, spec=spec,
                                    pool=pool, sampler=sampler)
    if theta_cap:
        theta = min(theta, theta_cap)
    want = -(-theta // num_colors)
    if pool is not None:
        batches = _pool_take(pool, want)
        visited = pool.visited_stack()[:want]
    else:
        if len(batches) < want:
            batches.extend(sampler.sample_many(range(len(batches), want)))
        # Selection uses exactly ⌈θ/colors⌉ batches even when the halving
        # phase oversampled — mirrors the pool path's [:want] slice, so
        # pool-routed and pool-less runs agree for every diffusion.
        batches = batches[:want]
        visited = rrr.stack_visited(batches)
    seeds, cov = greedy_max_cover(visited, k, num_colors)
    return IMMResult(
        seeds=seeds, sigma_estimate=cov * g.num_vertices,
        theta=len(batches) * num_colors, coverage=cov,
        num_batches=len(batches),
        # Skip the -1 "not instrumented" sentinels (tiled/kernel/LT/
        # data_parallel batches) so sums never go negative.
        fused_edge_visits=sum(b.fused_edge_visits for b in batches
                              if b.fused_edge_visits >= 0),
        unfused_edge_visits=sum(b.unfused_edge_visits for b in batches
                                if b.unfused_edge_visits >= 0))


def simulate_influence(g: csr.Graph, seeds, num_trials: int = 512,
                       master_seed: int = 77) -> float:
    """σ(S) by forward IC: one color per trial, frontier starts at all of S.

    Under IC, activations from multiple seeds in one realization are exactly
    a BFS from the seed *set* on the realized subgraph — so a single-color
    traversal seeded at every s ∈ S is the correct per-trial sample. Trials
    ride in parallel as colors (distinct counters ⇒ independent subgraphs).
    """
    n = g.num_vertices
    colors = min(num_trials, 256)
    total, trials_done = 0, 0
    while trials_done < num_trials:
        c = min(colors, num_trials - trials_done)
        fr = bitmask.make_mask(n, c)
        for s in np.asarray(seeds):
            fr = bitmask.set_color(fr, jnp.full((c,), int(s), jnp.int32),
                                   jnp.arange(c, dtype=jnp.int32))
        res = _run_from_frontier(g, fr, c,
                                 jnp.uint32(master_seed + trials_done))
        total += int(jnp.sum(bitmask.popcount(res)))
        trials_done += c
    return total / num_trials


def _run_from_frontier(g: csr.Graph, frontier, num_colors: int, seed,
                       max_levels: int = 64):
    """Fused traversal from an arbitrary initial frontier; returns visited."""
    from repro.core import traversal as trav

    visited = jnp.zeros_like(frontier)

    def cond(carry):
        fr, _, lvl = carry
        return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

    def body(carry):
        fr, vis, lvl = carry
        nf, nv, _ = trav.fused_step(g, fr, vis, lvl, seed)
        return nf, nv, lvl + 1

    fr, vis, _ = jax.lax.while_loop(cond, body,
                                    (frontier, visited, jnp.int32(0)))
    return vis | fr
