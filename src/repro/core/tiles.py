"""Block-sparse adjacency tiles — the TPU-native graph layout (DESIGN.md §2).

The GPU codes stream a CSR through warp-level gather/scatter queues.  The MXU
and VPU instead want dense, aligned tiles, so we store the adjacency matrix
``A[src, dst]`` as a list of non-empty ``T×T`` tiles (T = 128, the VPU lane
width and MXU edge).  Each tile carries:

  * ``prob``    (T, T) float32 — IC activation probability (0 ⇒ no edge),
  * ``edge_id`` (T, T) uint32  — the edge's index in the *CSR* edge array, so
    the counter RNG draws the identical Bernoulli realization on the tiled
    path, the CSR path, and inside the Pallas kernel (bit-for-bit coupling).

Tiles are sorted by destination block so the expansion kernel can accumulate
each output block across consecutive grid steps (Pallas revisiting pattern).
Vertex reordering (paper §5) now has a measurable TPU cost model: it shrinks
``num_tiles`` and raises ``occupancy`` (edges per stored tile).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import Graph

TILE = 128


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TiledGraph:
    """Block-sparse adjacency (see module docstring)."""
    prob: jnp.ndarray        # (nt, T, T) float32
    edge_id: jnp.ndarray     # (nt, T, T) uint32   (0 ok: prob gates validity)
    tile_src: jnp.ndarray    # (nt,) int32   source block index
    tile_dst: jnp.ndarray    # (nt,) int32   destination block index (sorted)
    first_of_dst: jnp.ndarray  # (nt,) int32  1 ⇒ first tile of its dst run
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))

    @property
    def num_tiles(self) -> int:
        return int(self.prob.shape[0])

    @property
    def padded_vertices(self) -> int:
        return -(-self.num_vertices // self.tile_size) * self.tile_size

    @property
    def occupancy(self) -> float:
        """Edges per stored tile slot — the reordering cost model."""
        nt = max(self.num_tiles, 1)
        return self.num_edges / (nt * self.tile_size ** 2)


def dedupe_edges(src: np.ndarray, dst: np.ndarray, prob: np.ndarray):
    """Combine parallel (src, dst) duplicates: p = 1 - Π(1 - p_i).

    A dense tile has one slot per (src, dst) pair; multi-edges must merge.
    The union-probability merge preserves the IC activation distribution.
    """
    key = src.astype(np.int64) * (dst.max() + 1 if len(dst) else 1) + dst
    order = np.argsort(key, kind="stable")
    key, src, dst, prob = key[order], src[order], dst[order], prob[order]
    uniq, first, inv = np.unique(key, return_index=True, return_inverse=True)
    log_keep = np.log1p(-np.clip(prob, 0.0, 1.0 - 1e-7))
    acc = np.zeros(len(uniq))
    np.add.at(acc, inv, log_keep)
    return src[first], dst[first], (1.0 - np.exp(acc)).astype(np.float32)


def from_graph(g: Graph, tile_size: int = TILE,
               pad_tiles_to: int | None = None) -> TiledGraph:
    """Extract the non-empty tile list from a CSR graph (host-side)."""
    e = g.num_edges
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    prob = np.asarray(g.prob)[:e]
    eid = np.arange(e, dtype=np.uint32)

    ts, td = src // tile_size, dst // tile_size
    tile_key = td.astype(np.int64) * (ts.max() + 1) + ts   # sort by dst, then src
    order = np.argsort(tile_key, kind="stable")
    src, dst, prob, eid, ts, td = (a[order] for a in (src, dst, prob, eid, ts, td))
    tile_key = tile_key[order]

    uniq, inv = np.unique(tile_key, return_inverse=True)
    nt = len(uniq)
    P = np.zeros((nt, tile_size, tile_size), np.float32)
    E = np.zeros((nt, tile_size, tile_size), np.uint32)
    li, lj = src % tile_size, dst % tile_size
    # Duplicate (src, dst) pairs must have been merged (dedupe_edges) — check.
    flat = inv.astype(np.int64) * tile_size * tile_size + li * tile_size + lj
    if len(np.unique(flat)) != len(flat):
        raise ValueError("parallel edges present — run tiles.dedupe_edges / "
                         "csr.from_edges(..., dedupe=True) first")
    P.reshape(-1)[flat] = prob
    E.reshape(-1)[flat] = eid

    t_src = np.zeros(nt, np.int32)
    t_dst = np.zeros(nt, np.int32)
    t_src = (uniq % (ts.max() + 1)).astype(np.int32)
    t_dst = (uniq // (ts.max() + 1)).astype(np.int32)
    first = np.ones(nt, np.int32)
    first[1:] = (t_dst[1:] != t_dst[:-1]).astype(np.int32)

    if pad_tiles_to is not None:
        if pad_tiles_to < nt:
            raise ValueError(f"pad_tiles_to={pad_tiles_to} < num_tiles={nt}")
        pad = pad_tiles_to - nt
        if pad:
            P = np.concatenate([P, np.zeros((pad, tile_size, tile_size), np.float32)])
            E = np.concatenate([E, np.zeros((pad, tile_size, tile_size), np.uint32)])
            # Padding tiles re-target the last dst block with prob 0 and are
            # never "first" — pure no-ops that keep shapes static.
            t_src = np.concatenate([t_src, np.full(pad, t_src[-1], np.int32)])
            t_dst = np.concatenate([t_dst, np.full(pad, t_dst[-1], np.int32)])
            first = np.concatenate([first, np.zeros(pad, np.int32)])

    return TiledGraph(
        prob=jnp.asarray(P), edge_id=jnp.asarray(E),
        tile_src=jnp.asarray(t_src), tile_dst=jnp.asarray(t_dst),
        first_of_dst=jnp.asarray(first),
        num_vertices=g.num_vertices, num_edges=e, tile_size=tile_size)


def edge_values_to_tiles(tg: TiledGraph, values: np.ndarray,
                         fill: float = 0.0) -> np.ndarray:
    """Map per-CSR-edge ``values`` into the ``(nt, T, T)`` tile layout
    (host-side).  Slot validity comes from ``prob > 0`` — empty slots share
    ``edge_id`` 0 with the real edge 0, so they take ``fill`` instead of the
    gathered value.  Used to carry per-edge side data (e.g. the LT
    selection-CDF prefixes) alongside the tile stack."""
    vals = np.asarray(values)
    gathered = vals[np.asarray(tg.edge_id)]
    return np.where(np.asarray(tg.prob) > 0, gathered,
                    np.asarray(fill, vals.dtype)).astype(vals.dtype)


def edge_slot_map(g: Graph, tile_size: int = TILE):
    """``(slot (E,) int64, num_tiles)``: CSR edge id → flat index into the
    ``(nt·T·T,)`` raveled tile stacks of ``from_graph(g, tile_size)``.

    The tile layout is a pure function of ``(src, dst, tile_size)`` — this
    mirrors `from_graph`'s sort/unique computation without materializing
    the stacks — so a values-only graph mutation (streaming deltas that
    tombstone/resurrect/renormalize without changing the edge arrays) can
    scatter new per-edge values straight into an existing layout:
    ``stack.reshape(-1)[slot] = new_values``.  Unlike
    `edge_values_to_tiles` this never consults slot validity, so slots
    whose probability crosses zero (tombstone ↔ live) take their new
    value instead of being masked by the stale one.
    """
    e = g.num_edges
    if e == 0:
        return np.zeros(0, np.int64), 0
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    ts, td = src // tile_size, dst // tile_size
    tile_key = td.astype(np.int64) * (ts.max() + 1) + ts
    order = np.argsort(tile_key, kind="stable")
    uniq, inv = np.unique(tile_key[order], return_inverse=True)
    li, lj = src[order] % tile_size, dst[order] % tile_size
    flat = inv.astype(np.int64) * tile_size * tile_size + li * tile_size + lj
    slot = np.empty(e, np.int64)
    slot[order] = flat                 # flat[j] is the slot of edge order[j]
    return slot, len(uniq)


def with_null_tile(tg: TiledGraph) -> TiledGraph:
    """``tg`` with ONE inert tile appended at index ``num_tiles`` — the
    fill target of sparse-frontier compaction (`jnp.nonzero` pads unused
    capacity slots with ``num_tiles``, which must gather something).

    The null tile is all-prob-0 (never propagates), sources block 0, and
    targets the LAST destination block, so a compacted-and-padded tile list
    stays sorted by destination block — the invariant the Pallas kernel's
    revisiting accumulation needs.  Null tiles either extend a real last-
    block run (zero extra contribution) or form their own zero run there.
    """
    t = tg.tile_size
    last_dst = tg.padded_vertices // t - 1
    return TiledGraph(
        prob=jnp.concatenate(
            [tg.prob, jnp.zeros((1, t, t), tg.prob.dtype)]),
        edge_id=jnp.concatenate(
            [tg.edge_id, jnp.zeros((1, t, t), tg.edge_id.dtype)]),
        tile_src=jnp.concatenate(
            [tg.tile_src, jnp.zeros((1,), tg.tile_src.dtype)]),
        tile_dst=jnp.concatenate(
            [tg.tile_dst, jnp.full((1,), last_dst, tg.tile_dst.dtype)]),
        first_of_dst=jnp.concatenate(
            [tg.first_of_dst, jnp.zeros((1,), tg.first_of_dst.dtype)]),
        num_vertices=tg.num_vertices, num_edges=tg.num_edges,
        tile_size=tg.tile_size)


def active_tile_ids(tile_src: jnp.ndarray, active_blocks: jnp.ndarray,
                    capacity: int, num_tiles: int) -> jnp.ndarray:
    """Compact the ids of tiles whose SOURCE block is active into a
    ``(capacity,)`` buffer, padded with ``num_tiles`` (the null tile).
    Ascending ids, so a dst-sorted tile list stays dst-sorted."""
    return jnp.nonzero(active_blocks[tile_src], size=capacity,
                       fill_value=num_tiles)[0]


def tile_stats(tg: TiledGraph) -> dict:
    """Reordering benchmark metrics (Fig. 5 analogue, TPU cost model)."""
    nblocks = tg.padded_vertices // tg.tile_size
    return dict(
        num_tiles=tg.num_tiles,
        possible_tiles=nblocks * nblocks,
        tile_fill_fraction=tg.num_tiles / max(nblocks * nblocks, 1),
        occupancy=tg.occupancy,
    )


def pad_mask_rows(mask: jnp.ndarray, padded_vertices: int) -> jnp.ndarray:
    pad = padded_vertices - mask.shape[0]
    return jnp.pad(mask, ((0, pad), (0, 0))) if pad else mask
