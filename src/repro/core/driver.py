"""Fault-tolerant fused-BPT sampling driver (paper §5 heterogeneous work
queue, made deterministic).

The paper's Ripples uses a host-side atomic counter that CPU/GPU workers
decrement to claim BPT batches.  Our batches are *idempotent* — batch ``b``
is a pure function of ``(graph, master_seed, b)`` (core/rrr.py) — so the
same queue becomes fault-tolerant for free:

* **node failure**  → the claimed batch times out and is reissued; the
  replacement reproduces bit-identical RRR sets.
* **stragglers**    → when the queue drains, outstanding batches are
  *speculatively* reissued to idle workers (MapReduce backup tasks);
  first completion wins, and idempotence makes the race benign.
* **elastic scale** → workers are stateless; the pool can grow/shrink
  between rounds without touching sampling state.

``failure_rate`` / ``slow_rate`` inject deterministic faults for tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait

import numpy as np

from repro.core import rrr
from repro.graph import csr


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class DriverStats:
    completed: int = 0
    failures: int = 0
    reissues: int = 0
    speculative: int = 0


class SamplingDriver:
    def __init__(self, g_rev: csr.Graph, num_colors: int, master_seed: int,
                 *, num_workers: int = 4, timeout_s: float = 120.0,
                 max_attempts: int = 5, failure_rate: float = 0.0,
                 slow_rate: float = 0.0, slow_s: float = 0.3,
                 spec=None, **sample_kw):
        from repro import sampling

        self.g_rev = g_rev
        self.num_colors = num_colors
        self.master_seed = master_seed
        self.num_workers = num_workers
        self.timeout_s = timeout_s
        self.max_attempts = max_attempts
        self.failure_rate = failure_rate
        self.slow_rate = slow_rate
        self.slow_s = slow_s
        # Shared reconciliation policy: the driver's num_colors/master_seed
        # are required args, hence always explicit — a disagreeing spec
        # raises rather than silently overriding.
        self.spec = sampling.resolve_spec(spec, sample_kw,
                                          num_colors=num_colors,
                                          master_seed=master_seed)
        if self.spec.backend in ("data_parallel", "graph_parallel"):
            raise ValueError(
                "SamplingDriver parallelizes across worker threads, not a "
                "mesh — use a dense/tiled/kernel spec here, or build the "
                "pool through ShardedSketchStore for mesh-parallel sampling")
        # Workers are threads sharing one stateless sampler: sampling is a
        # pure function of (graph, master_seed, batch_index), so concurrent
        # (and speculative duplicate) calls are race-free by construction.
        self.sampler = sampling.make_sampler(None, self.spec, g_rev=g_rev)
        self.stats = DriverStats()
        self._lock = threading.Lock()

    def _inject(self, batch_index: int, attempt: int):
        """Deterministic fault injection keyed by (batch, attempt)."""
        h = ((batch_index * 2654435761 + attempt * 40503)
             * 2246822519) & 0xFFFFFFFF
        u = (h % (1 << 24)) / (1 << 24)
        if u < self.failure_rate:
            with self._lock:
                self.stats.failures += 1
            raise InjectedFailure(f"batch {batch_index} attempt {attempt}")
        if u < self.failure_rate + self.slow_rate:
            time.sleep(self.slow_s)                    # straggler

    def _work(self, batch_index: int, attempt: int) -> rrr.RRRBatch:
        self._inject(batch_index, attempt)
        return self.sampler.sample(batch_index)

    def run(self, n_batches: int) -> list[rrr.RRRBatch]:
        """Sample ``n_batches`` with reissue-on-failure and speculative
        re-execution of stragglers.  Returns batches ordered by index."""
        results: dict[int, rrr.RRRBatch] = {}
        attempts = {b: 0 for b in range(n_batches)}
        pending = list(range(n_batches))

        with ThreadPoolExecutor(max_workers=self.num_workers) as pool:
            futures = {}

            def submit(b):
                attempts[b] += 1
                if attempts[b] > self.max_attempts:
                    raise RuntimeError(f"batch {b} exceeded max attempts")
                fut = pool.submit(self._work, b, attempts[b])
                futures[fut] = b

            for b in pending[: self.num_workers * 2]:
                submit(b)
            queued = set(pending[: self.num_workers * 2])
            backlog = [b for b in pending if b not in queued]

            deadline = time.monotonic() + self.timeout_s
            while len(results) < n_batches:
                if not futures:
                    for b in range(n_batches):      # everything failed: retry
                        if b not in results:
                            submit(b)
                done, _ = wait(list(futures), timeout=self.timeout_s,
                               return_when=FIRST_COMPLETED)
                if not done and time.monotonic() > deadline:
                    # global straggler sweep: reissue everything outstanding
                    for fut, b in list(futures.items()):
                        if b not in results:
                            self.stats.reissues += 1
                            submit(b)
                    deadline = time.monotonic() + self.timeout_s
                    continue
                for fut in done:
                    b = futures.pop(fut)
                    try:
                        res = fut.result()
                    except InjectedFailure:
                        if b not in results:
                            self.stats.reissues += 1
                            submit(b)
                        continue
                    if b not in results:
                        results[b] = res
                        with self._lock:
                            self.stats.completed += 1
                    if backlog:
                        nxt = backlog.pop(0)
                        submit(nxt)
                # speculative re-execution: idle capacity + outstanding work
                outstanding = [b for b in set(futures.values())
                               if b not in results]
                idle = self.num_workers - len(futures)
                for b in outstanding[: max(idle, 0)]:
                    self.stats.speculative += 1
                    submit(b)
        return [results[b] for b in range(n_batches)]
