"""Fused breadth-first probabilistic traversals (paper §3, Listing 1).

TPU-native formulation (DESIGN.md §2): the frontier is a dense packed color
bitmask ``(V, W)`` and one level of the fused traversal is an edge-centric
sweep

    contrib[e] = frontier[src[e]] & bernoulli(prob[e]) & ~visited[dst[e]]
    frontier'  = scatter_or(dst, contrib) & ~visited'
    visited'   = visited | frontier

which is the OR-AND-semiring SpMM of DESIGN.md.  Because every mask update is
bitwise-independent per color, the fused traversal restricted to color ``c``
is *exactly* the single-color BPT driven by the same counter RNG — fused and
unfused runs are coupled bit-for-bit (used by tests to check equivalence and
Theorem 1 without sampling error).

Level-synchronous semantics (matching the paper's Ripples port §4.2): the
whole frontier is folded into ``visited`` first, then expansion excludes all
previously-visited colors per destination.  A vertex may re-enter the frontier
in a later level, but only with colors it has never carried (Listing 1 line 11).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, rng
from repro.graph.csr import Graph


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TraversalStats:
    """Per-level instrumentation (sized ``max_levels``; host sums avoid i32
    overflow across levels)."""
    levels_run: jnp.ndarray            # () int32
    # "Edge visit" accounting mirrors the paper's Fig. 4: the fused algorithm
    # visits edge e at level t iff any color is active at src[e]; the unfused
    # equivalent visits it once *per* active color.
    fused_edge_visits: jnp.ndarray     # (max_levels,) int32
    unfused_edge_visits: jnp.ndarray   # (max_levels,) int32
    frontier_vertices: jnp.ndarray     # (max_levels,) int32  active vertices
    frontier_colors: jnp.ndarray       # (max_levels,) int32  Σ popcount(frontier)
    occupancy_num: jnp.ndarray         # (max_levels,) f32  Σ popcount / active
    # Fig. 9 analogue: fraction of 128-row tiles containing an active vertex.
    active_tile_frac: jnp.ndarray      # (max_levels,) f32
    # Kernel-grid work: grid steps launched this level.  Sparse-frontier
    # paths record the capacity rung that ran (compacted tile count); the
    # dense tiled grid records num_tiles; non-gridded (CSR edge-centric)
    # paths record 0 — the counter prices the *grid*, not edge work.
    grid_steps: jnp.ndarray            # (max_levels,) int32


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TraversalResult:
    visited: jnp.ndarray               # (V, W) uint32 — column c is RRR set c
    stats: TraversalStats


def init_frontier(num_vertices: int, num_colors: int,
                  starts: jnp.ndarray) -> jnp.ndarray:
    """(V, W) frontier with bit ``c`` set at row ``starts[c]``.

    Multiple colors may share a start vertex (paper Fig. 3 vertex 1)."""
    colors = jnp.arange(num_colors, dtype=jnp.int32)
    frontier = bitmask.make_mask(num_vertices, num_colors)
    return bitmask.set_color(frontier, jnp.asarray(starts, jnp.int32), colors)


def random_starts(key: jax.Array, num_vertices: int, num_colors: int,
                  sort: bool = False) -> jnp.ndarray:
    """Uniform-random start vertices (Listing 1 lines 1-3).  ``sort=True``
    pre-sorts starts for locality (paper §5 'sorted variant')."""
    starts = jax.random.randint(key, (num_colors,), 0, num_vertices, jnp.int32)
    return jnp.sort(starts) if sort else starts


def _scatter_or(base_words: jnp.ndarray, dst: jnp.ndarray,
                contrib: jnp.ndarray) -> jnp.ndarray:
    """base[dst] |= contrib with duplicate destinations ORed together."""
    lanes = bitmask.unpack_bits(contrib)                    # (E, W, 32)
    out = bitmask.unpack_bits(base_words)                   # (V, W, 32)
    out = out.at[dst].max(lanes)
    return bitmask.pack_bits(out)


def fused_step(g: Graph, frontier: jnp.ndarray, visited: jnp.ndarray,
               level: jnp.ndarray, seed: jnp.ndarray):
    """One level of the fused traversal.  Returns (frontier', visited', info)."""
    num_words = frontier.shape[-1]
    edge_ids = jnp.arange(g.padded_edges, dtype=jnp.uint32)

    visited = visited | frontier                            # Listing 1 line 8
    fr_src = frontier[g.src]                                # (E, W) gather
    # Independent Bernoulli(p_e) per (edge, color): one packed word per
    # (edge, word) pair.  Padding edges have prob 0 → never propagate.
    word_ids = jnp.arange(num_words, dtype=jnp.uint32)
    rand = jax.vmap(
        lambda w: rng.bernoulli_word(seed, level.astype(jnp.uint32),
                                     edge_ids, w, g.prob),
        out_axes=1)(word_ids)                               # (E, W)
    contrib = fr_src & rand & ~visited[g.dst]               # lines 11-13
    next_frontier = _scatter_or(jnp.zeros_like(visited), g.dst, contrib)
    next_frontier = next_frontier & ~visited                # line 11 (re-check
    # after OR: several sources may race to color the same dst — all valid)

    active_src = bitmask.count_colors(fr_src)               # (E,) per-edge
    info = dict(
        fused_visits=jnp.sum((active_src > 0).astype(jnp.int32)),
        unfused_visits=jnp.sum(active_src),
        frontier_vertices=jnp.sum(
            (bitmask.count_colors(frontier) > 0).astype(jnp.int32)),
        frontier_colors=jnp.sum(bitmask.count_colors(frontier)),
    )
    return next_frontier, visited, info


def _tile_activity(frontier: jnp.ndarray, tile_rows: int = 128) -> jnp.ndarray:
    """Fraction of row tiles with ≥1 active vertex (Fig. 9 analogue)."""
    v = frontier.shape[0]
    pad = (-v) % tile_rows
    act = (bitmask.count_colors(frontier) > 0)
    act = jnp.pad(act, (0, pad))
    tiles = act.reshape(-1, tile_rows).any(axis=1)
    return jnp.mean(tiles.astype(jnp.float32))


@partial(jax.jit, static_argnames=("num_colors", "max_levels"))
def run_fused(g: Graph, starts: jnp.ndarray, num_colors: int,
              seed: jnp.ndarray, max_levels: int = 64) -> TraversalResult:
    """Run the fused BPT to frontier exhaustion (≤ max_levels)."""
    v = g.num_vertices
    frontier = init_frontier(v, num_colors, starts)
    visited = bitmask.make_mask(v, num_colors)
    zeros_i = jnp.zeros((max_levels,), jnp.int32)
    zeros_f = jnp.zeros((max_levels,), jnp.float32)
    stats = TraversalStats(jnp.int32(0), zeros_i, zeros_i, zeros_i, zeros_i,
                           zeros_f, zeros_f, zeros_i)

    def cond(carry):
        frontier, _, level, _ = carry
        return jnp.logical_and(bitmask.any_set(frontier), level < max_levels)

    def body(carry):
        frontier, visited, level, stats = carry
        tile_frac = _tile_activity(frontier)
        nf, nv, info = fused_step(g, frontier, visited, level, seed)
        occ = jnp.where(info["frontier_vertices"] > 0,
                        info["frontier_colors"].astype(jnp.float32)
                        / jnp.maximum(info["frontier_vertices"], 1)
                        / jnp.float32(num_colors), 0.0)
        stats = TraversalStats(
            levels_run=stats.levels_run + 1,
            fused_edge_visits=stats.fused_edge_visits.at[level].set(
                info["fused_visits"]),
            unfused_edge_visits=stats.unfused_edge_visits.at[level].set(
                info["unfused_visits"]),
            frontier_vertices=stats.frontier_vertices.at[level].set(
                info["frontier_vertices"]),
            frontier_colors=stats.frontier_colors.at[level].set(
                info["frontier_colors"]),
            occupancy_num=stats.occupancy_num.at[level].set(occ),
            active_tile_frac=stats.active_tile_frac.at[level].set(tile_frac),
            grid_steps=stats.grid_steps,          # CSR path: not gridded
        )
        return nf, nv, level + 1, stats

    frontier, visited, _, stats = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0), stats))
    # Vertices still on the frontier at the level cap count as visited (their
    # colors have reached them even if not expanded further).
    visited = visited | frontier
    return TraversalResult(visited=visited, stats=stats)


@partial(jax.jit, static_argnames=("num_colors", "max_levels"))
def run_fused_block(g: Graph, starts: jnp.ndarray, seeds: jnp.ndarray,
                    num_colors: int, max_levels: int = 64):
    """Fused multi-batch sweep: ONE dispatch traverses a whole block of
    batches via ``lax.map`` (sequential per batch — one (V, W) transient
    at a time — so a pool build stops paying per-batch dispatch).

    starts (B, C) int32 / seeds (B,) uint32 → (visited (B, V, W),
    fused (B,), unfused (B,)) with the edge-visit totals equal to
    ``run_fused``'s per-level stats summed (same int32 arithmetic).
    """
    def one(args):
        st, sd = args
        frontier = init_frontier(g.num_vertices, num_colors, st)
        visited = jnp.zeros_like(frontier)

        def cond(c):
            fr, _, lvl, _, _ = c
            return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

        def body(c):
            fr, vis, lvl, fused, unfused = c
            nf, nv, info = fused_step(g, fr, vis, lvl, sd)
            return (nf, nv, lvl + 1, fused + info["fused_visits"],
                    unfused + info["unfused_visits"])

        fr, vis, _, fused, unfused = jax.lax.while_loop(
            cond, body,
            (frontier, visited, jnp.int32(0), jnp.int32(0), jnp.int32(0)))
        return vis | fr, fused, unfused

    return jax.lax.map(one, (starts, seeds))


@partial(jax.jit, static_argnames=("color_id", "max_levels"))
def run_single_color(g: Graph, start: jnp.ndarray, color_id: int,
                     seed: jnp.ndarray, max_levels: int = 64) -> TraversalResult:
    """Unfused baseline: one BPT using the *global* color id's RNG stream.

    Coupled with ``run_fused``: bit ``color_id`` of the fused visited mask is
    identical to this run's visited mask (tests rely on this)."""
    v = g.num_vertices
    word, lane = divmod(color_id, bitmask.WORD_BITS)
    frontier = jnp.zeros((v, 1), jnp.uint32).at[start, 0].set(
        jnp.uint32(1) << jnp.uint32(lane))
    visited = jnp.zeros((v, 1), jnp.uint32)
    edge_ids = jnp.arange(g.padded_edges, dtype=jnp.uint32)
    lane_bit = jnp.uint32(1) << jnp.uint32(lane)
    zeros_i = jnp.zeros((max_levels,), jnp.int32)
    zeros_f = jnp.zeros((max_levels,), jnp.float32)
    stats = TraversalStats(jnp.int32(0), zeros_i, zeros_i, zeros_i, zeros_i,
                           zeros_f, zeros_f, zeros_i)

    def cond(carry):
        frontier, _, level, _ = carry
        return jnp.logical_and(bitmask.any_set(frontier), level < max_levels)

    def body(carry):
        frontier, visited, level, stats = carry
        visited = visited | frontier
        fr_src = frontier[g.src]                            # (E, 1)
        # Same counter stream as the fused run's word `word`, restricted to
        # this lane: identical hash inputs ⇒ identical draw.
        bits = rng.hash_u32(seed, level.astype(jnp.uint32), edge_ids,
                            jnp.uint32(word * 32 + lane))
        draw = (rng.uniform_from_u32(bits) < g.prob)
        rand = jnp.where(draw, lane_bit, jnp.uint32(0))[:, None]
        contrib = fr_src & rand & ~visited[g.dst]
        nf = _scatter_or(jnp.zeros_like(visited), g.dst, contrib) & ~visited
        visits = jnp.sum((fr_src[:, 0] & lane_bit) > 0, dtype=jnp.int32)
        stats = TraversalStats(
            levels_run=stats.levels_run + 1,
            fused_edge_visits=stats.fused_edge_visits.at[level].set(visits),
            unfused_edge_visits=stats.unfused_edge_visits.at[level].set(visits),
            frontier_vertices=stats.frontier_vertices.at[level].set(
                jnp.sum((frontier[:, 0] > 0).astype(jnp.int32))),
            frontier_colors=stats.frontier_colors,
            occupancy_num=stats.occupancy_num,
            active_tile_frac=stats.active_tile_frac,
            grid_steps=stats.grid_steps,
        )
        return nf, visited, level + 1, stats

    frontier, visited, _, stats = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0), stats))
    visited = visited | frontier
    return TraversalResult(visited=visited, stats=stats)


def run_unfused(g: Graph, starts: np.ndarray, num_colors: int,
                seed: jnp.ndarray, max_levels: int = 64):
    """Run ``num_colors`` separate single-color BPTs (the unfused baseline of
    Figs. 7/8).  Returns (visited (V, W) assembled, total edge visits)."""
    w = bitmask.num_words(num_colors)
    visited = np.zeros((g.num_vertices, w), np.uint32)
    total_visits = 0
    for c in range(num_colors):
        res = run_single_color(g, int(starts[c]), c, seed,
                               max_levels=max_levels)
        visited[:, c // 32] |= np.asarray(res.visited[:, 0])
        total_visits += int(np.asarray(res.stats.fused_edge_visits,
                                       np.int64).sum())
    return jnp.asarray(visited), total_visits
