"""Random Reverse Reachable (RRR) set sampling (paper §2, Def. 2).

An RRR set for a uniformly-random root v is the visited set of a *reverse*
probabilistic BFS from v (Def. 2: traverse G with every edge flipped).  The
fused algorithm samples ``num_colors`` RRR sets per batch: color c's RRR set
is bit c of the visited mask — the (V, W) bitmask IS the RRR collection in
columnar form, which is exactly what greedy max-cover wants (DESIGN.md §2).

Batches are the unit of distribution and fault tolerance: batch ``b`` is a
pure function of ``(graph, master_seed, b)``, so a re-executed batch (lost
node, straggler reissue) reproduces the identical samples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, tiled_traversal, tiles, traversal
from repro.graph import csr


@dataclasses.dataclass(frozen=True)
class RRRBatch:
    """One fused batch of ``num_colors`` RRR sets."""
    visited: jnp.ndarray        # (V, W) uint32; column c = RRR set c
    roots: np.ndarray           # (num_colors,) root vertex per color
    batch_index: int
    fused_edge_visits: int
    unfused_edge_visits: int


def batch_seed(master_seed: int, batch_index: int) -> jnp.ndarray:
    """Distinct, reproducible RNG stream per batch (idempotent re-issue)."""
    return jnp.uint32((master_seed * 0x9E3779B9 + batch_index * 0x85EBCA6B)
                      & 0xFFFFFFFF)


def sample_batch(g_rev: csr.Graph, num_colors: int, master_seed: int,
                 batch_index: int, *, sort_starts: bool = False,
                 max_levels: int = 64,
                 tg_rev: tiles.TiledGraph | None = None,
                 use_kernel: bool = False,
                 model: str = "ic") -> RRRBatch:
    """Sample one fused batch of RRR sets on the REVERSED graph ``g_rev``.

    ``model``: "ic" (Independent Cascade, the paper's evaluation model) or
    "lt" (Linear Threshold via live-edge selection — g_rev must carry
    LT-normalized in-weights, see core/lt.normalize_lt_weights).
    ``tg_rev``/``use_kernel`` switch expansion to the tiled Pallas path;
    results are bit-for-bit identical to the CSR path (coupled RNG).
    """
    seed = batch_seed(master_seed, batch_index)
    key = jax.random.key(master_seed * 1_000_003 + batch_index)
    roots = traversal.random_starts(key, g_rev.num_vertices, num_colors,
                                    sort=sort_starts)
    if model == "lt":
        from repro.core import lt
        visited = lt.run_fused_lt(g_rev, roots, num_colors, seed,
                                  max_levels=max_levels)
        return RRRBatch(visited, np.asarray(roots), batch_index, -1, -1)
    if tg_rev is not None:
        visited, _ = tiled_traversal.run_fused_tiled(
            tg_rev, roots, num_colors, seed, max_levels=max_levels,
            use_kernel=use_kernel)
        return RRRBatch(visited, np.asarray(roots), batch_index, -1, -1)
    res = traversal.run_fused(g_rev, roots, num_colors, seed,
                              max_levels=max_levels)
    return RRRBatch(res.visited, np.asarray(roots), batch_index,
                    int(res.stats.fused_edge_visits.sum()),
                    int(res.stats.unfused_edge_visits.sum()))


def sample_collection(g: csr.Graph, theta: int, num_colors: int,
                      master_seed: int = 0, **kw) -> list[RRRBatch]:
    """θ RRR sets as ⌈θ/num_colors⌉ fused batches on transpose(g)."""
    g_rev = csr.transpose(g)
    n_batches = -(-theta // num_colors)
    return [sample_batch(g_rev, num_colors, master_seed, b, **kw)
            for b in range(n_batches)]


def stack_visited(batches: list[RRRBatch]) -> jnp.ndarray:
    """(B, V, W) stacked visited masks for seed selection."""
    return jnp.stack([b.visited for b in batches])
