"""Random Reverse Reachable (RRR) set sampling (paper §2, Def. 2).

An RRR set for a uniformly-random root v is the visited set of a *reverse*
probabilistic BFS from v (Def. 2: traverse G with every edge flipped).  The
fused algorithm samples ``num_colors`` RRR sets per batch: color c's RRR set
is bit c of the visited mask — the (V, W) bitmask IS the RRR collection in
columnar form, which is exactly what greedy max-cover wants (DESIGN.md §2).

Batches are the unit of distribution and fault tolerance: batch ``b`` is a
pure function of ``(graph, master_seed, b)``, so a re-executed batch (lost
node, straggler reissue) reproduces the identical samples.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, tiled_traversal, tiles, traversal
from repro.graph import csr


@dataclasses.dataclass(frozen=True)
class RRRBatch:
    """One fused batch of ``num_colors`` RRR sets.

    ``*_edge_visits`` are -1 on paths that do not instrument them (tiled,
    kernel, LT, data_parallel); only the dense IC sweep tracks stats."""
    visited: jnp.ndarray        # (V, W) uint32; column c = RRR set c
    roots: np.ndarray           # (num_colors,) root vertex per color
    batch_index: int
    fused_edge_visits: int
    unfused_edge_visits: int


def batch_seeds(master_seed: int, batch_indices) -> np.ndarray:
    """(B,) uint32 counter seeds — host-side, one value per batch index.
    THE stream derivation (single source of truth for every backend)."""
    return np.asarray(
        [(master_seed * 0x9E3779B9 + int(b) * 0x85EBCA6B) & 0xFFFFFFFF
         for b in batch_indices], np.uint32)


def batch_seed(master_seed: int, batch_index: int) -> jnp.ndarray:
    """Distinct, reproducible RNG stream per batch (idempotent re-issue)."""
    return jnp.uint32(batch_seeds(master_seed, [batch_index])[0])


def batch_starts(num_vertices: int, num_colors: int, master_seed: int,
                 batch_index: int, sort: bool = False) -> jnp.ndarray:
    """The (num_colors,) root vertices of batch ``batch_index`` — THE
    start-derivation every sampling backend shares, so a given
    ``(master_seed, batch_index)`` reproduces identical roots everywhere."""
    key = jax.random.key(master_seed * 1_000_003 + batch_index)
    return traversal.random_starts(key, num_vertices, num_colors, sort=sort)


def sample_batch(g_rev: csr.Graph, num_colors: int, master_seed: int,
                 batch_index: int, *, sort_starts: bool = False,
                 max_levels: int = 64,
                 tg_rev: tiles.TiledGraph | None = None,
                 use_kernel: bool = False,
                 model: str = "ic") -> RRRBatch:
    """Sample one fused batch of RRR sets on the REVERSED graph ``g_rev``.

    NOTE: this is the low-level primitive of the `repro.sampling` facade —
    new code should go through ``repro.sampling.make_sampler`` (a CI grep
    guard enforces that nothing outside ``repro/sampling/`` calls this).

    ``model``: "ic" (Independent Cascade, the paper's evaluation model) or
    "lt" (Linear Threshold via live-edge selection — g_rev must carry
    LT-normalized in-weights, see core/lt.normalize_lt_weights).
    ``tg_rev``/``use_kernel`` switch expansion to the tiled Pallas path;
    results are bit-for-bit identical to the CSR path (coupled RNG).
    """
    seed = batch_seed(master_seed, batch_index)
    roots = batch_starts(g_rev.num_vertices, num_colors, master_seed,
                         batch_index, sort=sort_starts)
    if model == "lt":
        from repro.core import lt
        visited = lt.run_fused_lt(g_rev, roots, num_colors, seed,
                                  max_levels=max_levels)
        return RRRBatch(visited, np.asarray(roots), batch_index, -1, -1)
    if tg_rev is not None:
        visited, _, _ = tiled_traversal.run_fused_tiled(
            tg_rev, roots, num_colors, seed, max_levels=max_levels,
            use_kernel=use_kernel)
        return RRRBatch(visited, np.asarray(roots), batch_index, -1, -1)
    res = traversal.run_fused(g_rev, roots, num_colors, seed,
                              max_levels=max_levels)
    return RRRBatch(res.visited, np.asarray(roots), batch_index,
                    int(res.stats.fused_edge_visits.sum()),
                    int(res.stats.unfused_edge_visits.sum()))


def sample_collection(g: csr.Graph, theta: int,
                      num_colors: int | None = None,
                      master_seed: int | None = None, *, spec=None,
                      mesh=None, **kw) -> list[RRRBatch]:
    """θ RRR sets as ⌈θ/num_colors⌉ fused batches on transpose(g).

    Routed through the `repro.sampling` facade (``sampling.resolve_spec``
    policy: explicit num_colors/master_seed that disagree with ``spec``
    raise); ``mesh`` backs the ``data_parallel`` backend; legacy
    ``sample_batch`` kwargs convert with a DeprecationWarning.
    """
    from repro import sampling

    spec = sampling.resolve_spec(spec, kw, num_colors=num_colors,
                                 master_seed=master_seed)
    sampler = sampling.make_sampler(g, spec, mesh=mesh)
    n_batches = -(-theta // spec.num_colors)
    return sampler.sample_many(range(n_batches))


def stack_visited(batches: list[RRRBatch]) -> jnp.ndarray:
    """(B, V, W) stacked visited masks for seed selection."""
    return jnp.stack([b.visited for b in batches])
