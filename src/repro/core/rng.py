"""Counter-based stateless RNG shared by kernels, references, and baselines.

The paper's GPU codes draw one uniform per (edge, color) attempt via curand.
On TPU we need an RNG that (a) is a pure function of its counters so fused and
unfused traversals can be *coupled* on identical edge realizations (used to
test Theorem 1 exactly), and (b) lowers inside a Pallas kernel body with plain
integer ops.  We use a small Philox/threefry-style mixer over a 4-tuple
``(seed, level, edge_id, word_id)`` producing one uint32 word == 32 color
lanes per call.

All functions are pure jnp and dtype-stable (uint32 in / uint32 out).
"""
from __future__ import annotations

import jax.numpy as jnp

# Constants from splitmix64 / murmur3 finalizers, truncated to 32-bit ops.
# Plain Python ints, cast at use sites: module-level jnp scalars would be
# captured device constants, which Pallas kernel bodies reject.
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9


def _mix32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 finalizer — full-avalanche 32-bit mixer."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def hash_u32(seed, level, edge_id, word_id) -> jnp.ndarray:
    """Hash 4 counters to one uint32 word (vectorized over any of them)."""
    seed = jnp.asarray(seed, jnp.uint32)
    level = jnp.asarray(level, jnp.uint32)
    edge_id = jnp.asarray(edge_id, jnp.uint32)
    word_id = jnp.asarray(word_id, jnp.uint32)
    g = jnp.uint32(_GOLDEN)
    h = seed * g
    h = _mix32(h ^ (level + g + (h << jnp.uint32(6)) + (h >> jnp.uint32(2))))
    h = _mix32(h ^ (edge_id + g + (h << jnp.uint32(6)) + (h >> jnp.uint32(2))))
    h = _mix32(h ^ (word_id + g + (h << jnp.uint32(6)) + (h >> jnp.uint32(2))))
    return h


def uniform_from_u32(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 → float32 uniform in [0, 1) using the top 24 bits."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def bernoulli_word(seed, level, edge_id, word_id, prob, lanes: int = 32) -> jnp.ndarray:
    """Packed uint32 word of ``lanes`` independent Bernoulli(prob) bits.

    Bit ``c`` of the result is the draw for color ``word_id*32 + c`` of edge
    ``edge_id`` at traversal ``level``.  One hash call per lane (vectorized) —
    each (edge, color) attempt is an independent draw, as the IC model and
    Listing 1 line 13 require.
    """
    lane = jnp.arange(lanes, dtype=jnp.uint32)
    # Fold the lane into the word counter so every color gets its own stream.
    bits = hash_u32(seed, level, edge_id[..., None], word_id * jnp.uint32(32) + lane)
    draws = uniform_from_u32(bits) < jnp.asarray(prob, jnp.float32)[..., None]
    return pack_bool_word(draws)


def pack_bool_word(bits_bool: jnp.ndarray) -> jnp.ndarray:
    """Pack trailing axis of ≤32 bools into a uint32 (bit c = lane c)."""
    lanes = bits_bool.shape[-1]
    weights = (jnp.uint32(1) << jnp.arange(lanes, dtype=jnp.uint32))
    return jnp.sum(bits_bool.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)
