"""Packed color-bitmask utilities.

A *color* is one traversal in a fused group (paper §3).  Masks are stored as
``(..., W)`` uint32 arrays with ``W = ceil(colors / 32)`` words — the same
blocked-bitmask layout the paper's Ripples port uses (§4.2), chosen there for
warp alignment and here because 32 colors/word matches the VPU lane width.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def num_words(num_colors: int) -> int:
    return -(-num_colors // WORD_BITS)


def color_tail_mask(num_colors: int) -> np.ndarray:
    """(W,) uint32 mask that zeroes bits past ``num_colors`` in the last word."""
    w = num_words(num_colors)
    out = np.full((w,), 0xFFFFFFFF, dtype=np.uint32)
    rem = num_colors % WORD_BITS
    if rem:
        out[-1] = np.uint32((1 << rem) - 1)
    return out


def make_mask(num_items: int, num_colors: int) -> jnp.ndarray:
    """All-zeros packed mask of shape (num_items, W)."""
    return jnp.zeros((num_items, num_words(num_colors)), jnp.uint32)


def set_color(mask: jnp.ndarray, item: jnp.ndarray, color: jnp.ndarray) -> jnp.ndarray:
    """Set bit ``color`` of row ``item`` (vectorized over both)."""
    item = jnp.asarray(item)
    color = jnp.asarray(color)
    word = color // WORD_BITS
    bit = jnp.uint32(1) << (color % WORD_BITS).astype(jnp.uint32)
    # Scatter-OR via max on one-hot-per-bit contributions: build per-row word
    # updates and OR them in.  Duplicate (item, word) pairs are combined with
    # a bitwise-or segment reduction implemented as unpack→max→pack.
    flat = jnp.zeros(mask.shape, jnp.uint32)
    flat = scatter_or_words(flat, item, word, bit)
    return mask | flat


def scatter_or_words(dst: jnp.ndarray, rows: jnp.ndarray, words: jnp.ndarray,
                     values: jnp.ndarray, *,
                     unique: bool = False) -> jnp.ndarray:
    """dst[rows, words] |= values with duplicate-index OR semantics.

    Bitwise-or is not a native scatter combiner; since OR over packed words is
    per-bit max, we unpack each contribution to 32 bool lanes, scatter with
    ``max``, and repack.  Cost: 32× the index traffic — fine for the pure-JAX
    path; the Pallas kernel keeps everything packed.

    ``unique=True`` is the packed fast path for callers whose contributions
    are already OR-combined per (row, word) target — every (rows[i],
    words[i]) pair distinct, e.g. segment-locally pre-OR'd compaction
    output or the distributed sparse-frontier reconstruction.  With no
    duplicate to combine, a gather-OR-scatter of whole uint32 words is
    exact (no lost updates) and pays 1× the index traffic instead of 32×.
    """
    if unique:
        cur = dst[rows, words]
        return dst.at[rows, words].set(cur | values, unique_indices=True)
    lanes = unpack_bits(values[..., None])[..., 0, :]          # (..., 32) bool
    dst_lanes = unpack_bits(dst)                               # (R, W, 32)
    dst_lanes = dst_lanes.at[rows, words].max(lanes)
    return pack_bits(dst_lanes)


def unpack_bits(mask: jnp.ndarray) -> jnp.ndarray:
    """(..., W) uint32 → (..., W, 32) bool."""
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return ((mask[..., None] >> shifts) & jnp.uint32(1)).astype(jnp.bool_)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., W, 32) bool → (..., W) uint32."""
    weights = jnp.uint32(1) << jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


def popcount(mask: jnp.ndarray) -> jnp.ndarray:
    """Per-word population count (SWAR — no lookup tables, kernel-safe)."""
    x = mask
    x = x - ((x >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    x = (x + (x >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> jnp.uint32(24)


def any_set(mask: jnp.ndarray) -> jnp.ndarray:
    """True if any bit set anywhere in the mask tensor."""
    return jnp.any(mask != 0)


def count_colors(mask: jnp.ndarray) -> jnp.ndarray:
    """Total set bits per row: (R, W) → (R,) int32."""
    return jnp.sum(popcount(mask), axis=-1).astype(jnp.int32)
