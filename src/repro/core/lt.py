"""Fused traversals under the Linear Threshold (LT) diffusion model.

The paper evaluates IC but defines both models (§2).  For RIS under LT the
classic live-edge equivalence (Kempe et al. 2003) applies: each vertex
selects AT MOST ONE incoming edge, edge (v→u) with probability w(v,u)
(Σ_v w(v,u) ≤ 1, none with 1−Σw); an RRR set is the reverse-reachable set
over the selected edges.  Fusion carries over directly: the selection is
*per (vertex, color)* — vertex u's chosen in-edge for color c is a pure
counter-hash of (seed, u, c), so the whole traversal stays level-sync
bitmask propagation and edge (v→u) propagates color c iff it IS u's
selection for c.

Unlike IC there is no per-level redraw: selections are fixed per traversal
(the live-edge subgraph is sampled once), which the hash structure encodes
by excluding ``level`` from the counters.

Split for distribution (repro.sampling's ``data_parallel`` backend): the
per-graph CDF prefix sums precompute on host ONCE (``selection_cum_before``)
while the per-seed selection (``selection_mask_from_cb``) and the level loop
(``lt_traversal_program``) are pure traceable jnp — so a shard_map body can
draw each shard's batches with its own RNG streams, bit-identical to the
single-device path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, rng
from repro.core.traversal import init_frontier
from repro.graph import csr


def normalize_lt_weights(g: csr.Graph) -> csr.Graph:
    """Scale each vertex's IN-edge weights to sum ≤ 1 (LT requirement).

    Incoming weight mass w(v,u) = prob(v,u) / max(1, Σ_in prob(·,u)).
    Idempotent: an already-normalized graph has Σ_in ≤ 1 ⇒ scale 1.

    Order-preserving: only ``prob`` is rewritten — edge array positions
    (the CSR edge ids that key the counter RNG) and ``indptr`` are kept.
    Streamed graphs (`repro.stream.apply_delta`) are not src-sorted, so a
    rebuild through ``csr.from_edges`` would re-sort and renumber every
    edge id; for sorted graphs the two constructions are bit-identical.
    """
    import dataclasses

    e = g.num_edges
    dst = np.asarray(g.dst)[:e]
    prob = np.asarray(g.prob)[:e].astype(np.float64)
    in_sum = np.zeros(g.num_vertices)
    np.add.at(in_sum, dst, prob)
    scale = 1.0 / np.maximum(in_sum[dst], 1.0)
    new_prob = np.asarray(g.prob).copy()
    new_prob[:e] = (prob * scale).astype(np.float32)
    return dataclasses.replace(g, prob=jnp.asarray(new_prob))


def selection_cum_before(g: csr.Graph) -> np.ndarray:
    """(E_pad,) float32: Σ of in-edge probabilities *before* each edge in
    its destination's CDF (host-side precompute — needs concrete arrays).

    Per-graph, seed-independent: compute once, reuse across every batch."""
    e_pad = g.padded_edges
    e = g.num_edges
    dst_np = np.asarray(g.dst)[:e]
    prob_np = np.asarray(g.prob)[:e].astype(np.float64)
    order = np.argsort(dst_np, kind="stable")
    sorted_prob = prob_np[order]
    sorted_dst = dst_np[order]
    csum = np.cumsum(sorted_prob)
    group_start = np.searchsorted(sorted_dst, sorted_dst, side="left")
    prefix = csum - sorted_prob                       # Σ p before i (global)
    cum_before_sorted = prefix - prefix[group_start]  # per-dst prefix
    cum_before = np.zeros(e_pad, np.float32)
    cum_before[order] = cum_before_sorted.astype(np.float32)
    return cum_before


def selection_mask_from_cb(g: csr.Graph, cb: jnp.ndarray, num_colors: int,
                           seed) -> jnp.ndarray:
    """(E_pad, W) uint32: bit c of edge e set iff e is dst[e]'s live edge
    for color c.  Inverse-CDF over each vertex's in-edge list: edge e is
    selected for color c iff  cum_before[e] ≤ u(dst,c) < cum_before[e]+p[e]
    where u ~ U[0,1) per (dst, color) — at most one edge wins, and the
    no-edge case (u ≥ Σp) selects nothing, all per the LT live-edge rule.

    Pure jnp given the host-precomputed ``cb`` — traceable (jit/shard_map).
    """
    dst = g.dst
    prob = g.prob.astype(jnp.float32)
    seed = jnp.asarray(seed, jnp.uint32)
    words = []
    for w in range(bitmask.num_words(num_colors)):
        lanes = []
        for lane in range(32):
            c = w * 32 + lane
            # one uniform per (destination vertex, color): edges into the
            # same vertex share it — at most one falls in its CDF slot.
            u = rng.uniform_from_u32(
                rng.hash_u32(seed, jnp.uint32(0x17), dst.astype(jnp.uint32),
                             jnp.uint32(c)))
            sel = jnp.logical_and(u >= cb, u < cb + prob)
            lanes.append(sel)
        words.append(rng.pack_bool_word(jnp.stack(lanes, -1)))
    return jnp.stack(words, -1)


def _selection_mask(g: csr.Graph, num_colors: int, seed) -> jnp.ndarray:
    """Host-precompute + selection in one call (single-device convenience)."""
    return selection_mask_from_cb(g, jnp.asarray(selection_cum_before(g)),
                                  num_colors, seed)


def lt_traversal_program(g: csr.Graph, sel, starts, num_colors: int,
                         max_levels: int):
    """Level loop over a fixed live-edge selection — trace-time program
    (callers jit or stage inside shard_map).  Returns visited (V, W)."""
    frontier = init_frontier(g.num_vertices, num_colors, starts)
    visited = jnp.zeros_like(frontier)

    def cond(c):
        fr, _, lvl = c
        return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

    def body(c):
        fr, vis, lvl = c
        vis = vis | fr
        contrib = fr[g.src] & sel & ~vis[g.dst]
        from repro.core.traversal import _scatter_or
        nf = _scatter_or(jnp.zeros_like(vis), g.dst, contrib) & ~vis
        return nf, vis, lvl + 1

    fr, vis, _ = jax.lax.while_loop(cond, body,
                                    (frontier, visited, jnp.int32(0)))
    return vis | fr


def run_fused_lt(g: csr.Graph, starts, num_colors: int, seed,
                 max_levels: int = 64):
    """Fused LT traversal: visited (V, W) — column c = LT RRR set c.

    The live-edge selection mask precomputes on host (CDF prefix sums need
    concrete arrays); selection + level loop are jitted."""
    seed = jnp.uint32(seed)
    cb = jnp.asarray(selection_cum_before(g))
    return _run_fused_lt_jit(g, cb, starts, seed, num_colors, max_levels)


@partial(jax.jit, static_argnames=("num_colors", "max_levels"))
def _run_fused_lt_jit(g: csr.Graph, cb, starts, seed, num_colors: int,
                      max_levels: int):
    sel = selection_mask_from_cb(g, cb, num_colors, seed)
    return lt_traversal_program(g, sel, starts, num_colors, max_levels)


@partial(jax.jit, static_argnames=("num_colors", "max_levels"))
def run_fused_lt_block(g: csr.Graph, cb, starts, seeds, num_colors: int,
                       max_levels: int = 64) -> jnp.ndarray:
    """Fused multi-batch LT sweep: ONE dispatch traverses a block of
    batches via ``lax.map`` (each batch draws its own live-edge selection
    from its seed, one (E, W) selection transient at a time).

    starts (B, C) int32 / seeds (B,) uint32 → visited (B, V, W)."""
    def one(args):
        st, sd = args
        sel = selection_mask_from_cb(g, cb, num_colors, sd)
        return lt_traversal_program(g, sel, st, num_colors, max_levels)

    return jax.lax.map(one, (starts, seeds))
