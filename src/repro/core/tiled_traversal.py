"""Full fused-BPT traversal on the block-sparse tile layout.

Same level-synchronous semantics as ``core.traversal.run_fused`` (the CSR
edge-centric path) but expansion goes through the tile formulation — either
the Pallas kernel (``use_kernel=True``) or its pure-jnp oracle.  Because all
three paths share the counter RNG keyed by *CSR edge id*, their visited masks
are bit-for-bit identical; tests rely on it.

``run_fused_lt_tiled`` is the LT analogue: the same tile sweep with the
per-(edge, color) Bernoulli replaced by the fixed LT live-edge selection
(`kernels.ref.lt_select_expand_ref`), bit-identical to ``lt.run_fused_lt``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitmask, tiles
from repro.core.traversal import init_frontier
from repro.kernels import fused_expand as fe
from repro.kernels import ref as kref


@partial(jax.jit, static_argnames=("num_colors", "max_levels"))
def run_fused_lt_tiled(tg: tiles.TiledGraph, cb_tiles, starts,
                       num_colors: int, seed, max_levels: int = 64):
    """LT fused traversal on the block-sparse tile layout.

    Expansion goes through `kernels.ref.lt_select_expand_ref` — the fixed
    live-edge selection recomputed per level from the counter hash — so the
    visited mask is bit-for-bit identical to `lt.run_fused_lt` on the same
    (LT-normalized) graph.  ``cb_tiles`` is the selection-CDF prefix in tile
    layout (``tiles.edge_values_to_tiles(tg, lt.selection_cum_before(g))``).
    Returns (visited (V, W) uint32, levels_run int32).
    """
    vp = tg.padded_vertices
    frontier = tiles.pad_mask_rows(
        init_frontier(tg.num_vertices, num_colors, starts), vp)
    visited = jnp.zeros_like(frontier)
    # Selection uniforms are level-independent: ONE table per traversal.
    u = kref.lt_selection_uniforms(jnp.uint32(seed), vp, num_colors)

    def cond(carry):
        fr, _, level = carry
        return jnp.logical_and(bitmask.any_set(fr), level < max_levels)

    def body(carry):
        fr, vis, level = carry
        vis = vis | fr
        nf = kref.lt_select_expand_ref(tg.prob, cb_tiles, tg.tile_src,
                                       tg.tile_dst, fr, vis, u)
        return nf, vis, level + 1

    frontier, visited, levels = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0)))
    visited = visited | frontier                         # cap-level colors
    return visited[: tg.num_vertices], levels


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "use_kernel",
                                   "interpret"))
def run_fused_tiled(tg: tiles.TiledGraph, starts, num_colors: int, seed,
                    max_levels: int = 64, use_kernel: bool = True,
                    interpret: bool = True):
    """Returns (visited (V, W) uint32, levels_run int32)."""
    vp = tg.padded_vertices
    frontier = tiles.pad_mask_rows(
        init_frontier(tg.num_vertices, num_colors, starts), vp)
    visited = jnp.zeros_like(frontier)
    seed = jnp.uint32(seed)

    def expand(fr, vis, level):
        if use_kernel:
            return fe.fused_expand(
                tg.prob, tg.edge_id, tg.tile_src, tg.tile_dst,
                tg.first_of_dst, fr, vis, seed, level, interpret=interpret)
        return kref.fused_expand_ref(
            tg.prob, tg.edge_id, tg.tile_src, tg.tile_dst, fr, vis, seed,
            level)

    def cond(carry):
        fr, _, level = carry
        return jnp.logical_and(bitmask.any_set(fr), level < max_levels)

    def body(carry):
        fr, vis, level = carry
        vis = vis | fr                                   # Listing 1 line 8
        nf = expand(fr, vis, level.astype(jnp.uint32))
        return nf, vis, level + 1

    frontier, visited, levels = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0)))
    visited = visited | frontier                         # cap-level colors
    return visited[: tg.num_vertices], levels
