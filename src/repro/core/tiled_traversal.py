"""Full fused-BPT traversal on the block-sparse tile layout.

Same level-synchronous semantics as ``core.traversal.run_fused`` (the CSR
edge-centric path) but expansion goes through the tile formulation — either
the Pallas kernels (``use_kernel=True``: `kernels.fused_expand` for IC,
`kernels.lt_select_expand` for LT) or their pure-jnp oracles.  Because all
paths share the counter RNG keyed by *CSR edge id*, their visited masks
are bit-for-bit identical; tests rely on it.

``run_fused_lt_tiled`` is the LT analogue: the same tile sweep with the
per-(edge, color) Bernoulli replaced by the fixed LT live-edge selection
(`kernels.lt_select_expand` / `kernels.ref.lt_select_expand_ref`),
bit-identical to ``lt.run_fused_lt``.

Both support the **sparse-frontier** execution mode (``frontier="sparse"``):
per level, the active source row-blocks are computed from the packed
frontier, the ids of tiles sourcing from them compact into a capacity
bucket (`core.sparse.bucket_ladder` — nested ``lax.cond`` picks the
smallest rung that fits, top rung = all tiles so nothing can overflow),
and ONLY the gathered tiles expand.  Compaction preserves the
dst-sorted tile order (ascending ids; padding gathers the appended null
tile targeting the last block — `tiles.with_null_tile`), and
``first_of_dst`` is recomputed on the gathered list, so the Pallas
kernel's revisiting accumulation runs unchanged on the compacted grid —
the kernel grid itself shrinks to the capacity rung.  Skipped tiles have
no active source row, hence zero contribution: sparse is bit-identical
to dense by construction.

Both runners return ``(visited, levels_run, grid_steps)`` where
``grid_steps`` is the TOTAL number of kernel grid steps launched across
levels — ``levels · num_tiles`` for the dense grid, the sum of the per-level
capacity rungs for the sparse grid.  The ratio is the ``active_grid_frac``
benchmark column and the `scripts/check_work_counters.py` guard.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bitmask, sparse, tiles
from repro.core.traversal import init_frontier
from repro.kernels import fused_expand as fe
from repro.kernels import lt_select_expand as lse
from repro.kernels import ref as kref


def _gathered_first_of_dst(tile_dst: jnp.ndarray) -> jnp.ndarray:
    """Recompute ``first_of_dst`` on a gathered (still dst-sorted) tile
    list — a run's global first tile may not have been gathered."""
    return jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (tile_dst[1:] != tile_dst[:-1]).astype(jnp.int32)])


def _sparse_tile_expand(tgn: tiles.TiledGraph, num_tiles: int,
                        ladder: tuple[int, ...], frontier, expand_gathered):
    """Ladder-compacted tile expansion: gather the tiles whose source
    block is active (``tgn`` = null-extended stacks) and hand the
    compacted stacks to ``expand_gathered(prob, eid, ts, td, ids)``.
    Returns ``(next_frontier, grid_steps)`` — the rung that ran."""
    act = sparse.row_block_activity(frontier, tgn.tile_size)
    real_src = tgn.tile_src[:num_tiles]
    count = jnp.sum(act[real_src].astype(jnp.int32))

    def step_at(cap: int):
        def run(_):
            ids = tiles.active_tile_ids(real_src, act, cap, num_tiles)
            nf = expand_gathered(tgn.prob[ids], tgn.edge_id[ids],
                                 tgn.tile_src[ids], tgn.tile_dst[ids], ids)
            return nf, jnp.int32(cap)
        return run

    return sparse.cond_ladder(count, ladder, step_at)


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "use_kernel",
                                   "interpret", "frontier", "ladder"))
def run_fused_lt_tiled(tg: tiles.TiledGraph, cb_tiles, starts,
                       num_colors: int, seed, max_levels: int = 64,
                       use_kernel: bool = True, interpret: bool = True,
                       frontier: str = "dense",
                       ladder: tuple[int, ...] | None = None):
    """LT fused traversal on the block-sparse tile layout.

    Expansion goes through `kernels.lt_select_expand` (``use_kernel=True``)
    or its oracle `kernels.ref.lt_select_expand_ref` — the fixed live-edge
    selection recomputed per level from the counter hash — so the visited
    mask is bit-for-bit identical to `lt.run_fused_lt` on the same
    (LT-normalized) graph.  ``cb_tiles`` is the selection-CDF prefix in tile
    layout (``tiles.edge_values_to_tiles(tg, lt.selection_cum_before(g))``).
    ``frontier="sparse"`` compacts to the active tiles per level (see
    module docstring); ``ladder`` overrides the capacity buckets.
    Returns (visited (V, W) uint32, levels_run int32, grid_steps int32).
    """
    vp = tg.padded_vertices
    fr0 = tiles.pad_mask_rows(
        init_frontier(tg.num_vertices, num_colors, starts), vp)
    visited = jnp.zeros_like(fr0)
    # Selection uniforms are level-independent: ONE table per traversal.
    u = kref.lt_selection_uniforms(jnp.uint32(seed), vp, num_colors)

    def expand_tiles(p, cbt, ts, td, fi, fr, vis):
        if use_kernel:
            return lse.lt_select_expand(p, cbt, ts, td, fi, fr, vis, u,
                                        interpret=interpret)
        return kref.lt_select_expand_ref(p, cbt, ts, td, fr, vis, u)

    if frontier == "sparse":
        if ladder is None:
            ladder = sparse.bucket_ladder(tg.num_tiles)
        tgn = tiles.with_null_tile(tg)
        cbn = jnp.concatenate(
            [cb_tiles, jnp.zeros((1,) + cb_tiles.shape[1:],
                                 cb_tiles.dtype)])

        def expand(fr, vis, level):
            def gathered(p, eid, ts, td, ids):
                return expand_tiles(p, cbn[ids], ts, td,
                                    _gathered_first_of_dst(td), fr, vis)
            return _sparse_tile_expand(tgn, tg.num_tiles, ladder, fr,
                                       gathered)
    else:
        def expand(fr, vis, level):
            nf = expand_tiles(tg.prob, cb_tiles, tg.tile_src, tg.tile_dst,
                              tg.first_of_dst, fr, vis)
            return nf, jnp.int32(tg.num_tiles)

    def cond(carry):
        fr, _, level, _ = carry
        return jnp.logical_and(bitmask.any_set(fr), level < max_levels)

    def body(carry):
        fr, vis, level, gs = carry
        vis = vis | fr
        nf, step_gs = expand(fr, vis, level)
        return nf, vis, level + 1, gs + step_gs

    fr, visited, levels, grid_steps = jax.lax.while_loop(
        cond, body, (fr0, visited, jnp.int32(0), jnp.int32(0)))
    visited = visited | fr                               # cap-level colors
    return visited[: tg.num_vertices], levels, grid_steps


@partial(jax.jit, static_argnames=("num_colors", "max_levels", "use_kernel",
                                   "interpret", "frontier", "ladder"))
def run_fused_tiled(tg: tiles.TiledGraph, starts, num_colors: int, seed,
                    max_levels: int = 64, use_kernel: bool = True,
                    interpret: bool = True, frontier: str = "dense",
                    ladder: tuple[int, ...] | None = None):
    """Returns (visited (V, W) uint32, levels_run int32, grid_steps int32).

    ``frontier="sparse"`` compacts each level's expansion to the tiles
    with an active source block (module docstring); works through both
    the Pallas kernel and the jnp oracle, bit-identical to dense."""
    vp = tg.padded_vertices
    fr0 = tiles.pad_mask_rows(
        init_frontier(tg.num_vertices, num_colors, starts), vp)
    visited = jnp.zeros_like(fr0)
    seed = jnp.uint32(seed)

    def expand_tiles(p, eid, ts, td, fi, fr, vis, level):
        if use_kernel:
            return fe.fused_expand(p, eid, ts, td, fi, fr, vis, seed,
                                   level, interpret=interpret)
        return kref.fused_expand_ref(p, eid, ts, td, fr, vis, seed, level)

    if frontier == "sparse":
        if ladder is None:
            ladder = sparse.bucket_ladder(tg.num_tiles)
        tgn = tiles.with_null_tile(tg)

        def expand(fr, vis, level):
            def gathered(p, eid, ts, td, ids):
                return expand_tiles(p, eid, ts, td,
                                    _gathered_first_of_dst(td), fr, vis,
                                    level)
            return _sparse_tile_expand(tgn, tg.num_tiles, ladder, fr,
                                       gathered)
    else:
        def expand(fr, vis, level):
            nf = expand_tiles(tg.prob, tg.edge_id, tg.tile_src,
                              tg.tile_dst, tg.first_of_dst, fr, vis,
                              level)
            return nf, jnp.int32(tg.num_tiles)

    def cond(carry):
        fr, _, level, _ = carry
        return jnp.logical_and(bitmask.any_set(fr), level < max_levels)

    def body(carry):
        fr, vis, level, gs = carry
        vis = vis | fr                                   # Listing 1 line 8
        nf, step_gs = expand(fr, vis, level.astype(jnp.uint32))
        return nf, vis, level + 1, gs + step_gs

    fr, visited, levels, grid_steps = jax.lax.while_loop(
        cond, body, (fr0, visited, jnp.int32(0), jnp.int32(0)))
    visited = visited | fr                               # cap-level colors
    return visited[: tg.num_vertices], levels, grid_steps
