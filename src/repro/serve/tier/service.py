"""The tier front door: admission → routing → metrics, one object.

`ServingTier` is what a process serves traffic through:

    tier = ServingTier.build(store, replicas=2,
                             quota_qps=50.0, default_deadline=0.02)
    tier.set_quota("free-tier", rate=5.0, burst=10)
    fut = tier.submit_sigma("alice", [3, 17, 42])    # ShedError if over quota
    sigma = fut.result()
    print(tier.to_json(indent=1))                    # SLO snapshot
    tier.close()

Every submit: (1) the tenant's token bucket admits or sheds
(`quota.ShedError` carries retry-after — raised on the caller, nothing
reaches an engine); (2) the router picks a replica (least-pending by
default); (3) a done-callback records the submit→resolve latency into the
tier histogram (per-query-kind + overall) and counts per-tenant serves.
`gather()` re-exports the router's epoch-consistency guard.

`snapshot()` is the JSON observability surface: tenant admit/shed/served
counts, shed rate, latency percentiles (p50/p99/p999), per-replica
dispatch counts + queue depth + pool version, cache hit rates (through
`ResultCache.stats()` — the atomic snapshot), and the autoscaler's last
decision when one is attached.  Tenant ids appear under
`metrics.escape_label` form (``"org.acme"`` → ``"org%2Eacme"``) so a
dotted id can't nest deeper than the ``tenant.<id>.<counter>`` level the
totals sum over.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time

from repro.serve.tier import metrics as metrics_lib
from repro.serve.tier import quota as quota_lib
from repro.serve.tier import router as router_lib
from repro.serve.tier.autoscale import AutoScaler


class ServingTier:
    """Per-tenant admission + replica routing + metrics over one pool."""

    def __init__(self, group: router_lib.ReplicaGroup,
                 admission: quota_lib.AdmissionController, *,
                 metrics: metrics_lib.MetricSet | None = None,
                 autoscaler: AutoScaler | None = None):
        self.group = group
        self.admission = admission
        self.metrics = metrics if metrics is not None else \
            metrics_lib.MetricSet()
        self.autoscaler = autoscaler
        self._tracker = None        # DirtySlotTracker, lazy (first delta)
        self._last_stream = None    # last stream.StreamReport
        self._compactor: threading.Thread | None = None
        self._compact_stop = threading.Event()

    @classmethod
    def build(cls, store, replicas: int = 2, *,
              engine_factory=router_lib.QueryEngine,
              policy: str = "least_pending",
              quota_qps: float | None = 100.0, quota_burst: float | None = None,
              autoscale: dict | None = None,
              **frontend_kw) -> "ServingTier":
        """Assemble the whole tier from one warm store.

        ``autoscale``: kwargs for `AutoScaler` (e.g. ``{"k": 4,
        "target_eps": 0.3, "target_p99_ms": 50}``) — the scaler is wired to
        the tier's latency histogram and started by ``start_background``.
        """
        metrics = metrics_lib.MetricSet()
        group = router_lib.ReplicaGroup.build(
            store, replicas, engine_factory=engine_factory, policy=policy,
            metrics=metrics, **frontend_kw)
        admission = quota_lib.AdmissionController(
            quota_qps, quota_burst, metrics=metrics)
        scaler = None
        if autoscale is not None:
            scaler = AutoScaler(group, metrics=metrics,
                                latency_hist=metrics.hist("latency.all"),
                                **autoscale)
        return cls(group, admission, metrics=metrics, autoscaler=scaler)

    # ------------------------------------------------------------- submit
    def set_quota(self, tenant: str, rate: float | None,
                  burst: float | None = None) -> None:
        self.admission.set_quota(tenant, rate, burst)

    def _submit(self, tenant: str, kind: str, payload, deadline, cost):
        self.admission.admit(tenant, cost)      # ShedError propagates
        t0 = time.monotonic()
        fut = getattr(self.group, f"submit_{kind}")(payload,
                                                    deadline=deadline)
        hist_all = self.metrics.hist("latency.all")
        hist_kind = self.metrics.hist(f"latency.{kind}")
        served = self.metrics.counter(
            f"tenant.{metrics_lib.escape_label(tenant)}.served")

        def record(f):
            if f.cancelled() or f.exception() is not None:
                return
            dt = time.monotonic() - t0
            hist_all.record(dt)
            hist_kind.record(dt)
            served.add()

        fut.add_done_callback(record)
        return fut

    def submit_top_k(self, tenant: str, k: int, *,
                     deadline: float | None = None, cost: float = 1.0):
        return self._submit(tenant, "top_k", k, deadline, cost)

    def submit_sigma(self, tenant: str, seed_set, *,
                     deadline: float | None = None, cost: float = 1.0):
        return self._submit(tenant, "sigma", seed_set, deadline, cost)

    def submit_marginal(self, tenant: str, exclude, *,
                        deadline: float | None = None, cost: float = 1.0):
        return self._submit(tenant, "marginal", exclude, deadline, cost)

    def gather(self, futures, timeout: float | None = None) -> list:
        """Epoch-consistent results (`router.EpochMixError` on a mix)."""
        return self.group.gather(futures, timeout)

    # ----------------------------------------------------- streaming deltas
    def apply_delta(self, tenant: str, delta, *, cost: float = 1.0):
        """Admission-gated streaming graph update — the write front door.

        Charges the tenant's token bucket like any query (`quota.ShedError`
        propagates — a tenant can't starve the pool with delta spam), then
        sweeps the delta across every replica (`ReplicaGroup.apply_delta`:
        one shared dirty-set plan, per-replica atomic swap, graph-epoch
        version bump).  Returns the `repro.stream.StreamReport`; counters
        and histograms land under ``stream.*`` in `snapshot()`.
        """
        from repro.stream import DirtySlotTracker

        self.admission.admit(tenant, cost)      # ShedError propagates
        if self._tracker is None:
            self._tracker = DirtySlotTracker.for_store(
                self.group.replicas[0].store)
        report = self.group.apply_delta(delta, self._tracker)
        self._last_stream = report
        m = self.metrics
        m.counter("stream.deltas_applied").add()
        m.counter("stream.edges_inserted").add(report.inserted)
        m.counter("stream.edges_deleted").add(report.deleted)
        m.counter("stream.slots_resampled").add(report.dirty_slots)
        m.hist("stream.dirty_fraction").record(report.dirty_fraction)
        m.hist("stream.refresh_s").record(report.refresh_s)
        m.counter(f"tenant.{metrics_lib.escape_label(tenant)}.served").add()
        return report

    def maybe_compact(self, threshold: float = 0.10) -> bool:
        """Tombstone-compaction policy: when the forward graph's tombstone
        fraction exceeds ``threshold``, sweep a `ReplicaGroup.compact`
        rebuild (every slot resampled, replicas re-converge
        bit-identically on the renumbered edge ids) and count it under
        ``stream.compactions``.  Returns whether a compaction ran.

        This is the knob the id-stable delta policy needs: interior
        tombstones are individually cheap but accumulate without bound;
        the background loop (``start_background(compact_every=...)``)
        polls this instead of compacting on a timer, so a read-heavy tier
        with little churn never pays the rebuild.
        """
        from repro.stream import compact as compact_lib

        frac = compact_lib.tombstone_fraction(
            self.group.replicas[0].store.graph)
        if frac <= threshold:
            return False
        self.group.compact()
        self.metrics.counter("stream.compactions").add()
        self.metrics.hist("stream.compacted_fraction").record(frac)
        return True

    # ------------------------------------------------------- observability
    def snapshot(self) -> dict:
        snap = self.metrics.snapshot()
        tenants = snap.get("tenant", {})
        admitted = sum(t.get("admitted", 0) for t in tenants.values())
        shed = sum(t.get("shed", 0) for t in tenants.values())
        snap["totals"] = {
            "admitted": admitted, "shed": shed,
            "shed_rate": shed / (admitted + shed) if admitted + shed else 0.0,
        }
        snap["replicas"] = [{
            "index": r.index,
            "pending": r.pending,
            "version": list(r.version),
            "batches": len(r.store.batches),
            "dispatches": r.frontend.batcher.dispatches,
            "flushes": r.frontend.stats.flushes,
            "cache": r.frontend.batcher.cache.stats()
            if r.frontend.batcher.cache is not None else None,
        } for r in self.group.replicas]
        snap["consistent"] = self.group.consistent()
        if self._tracker is not None:
            # Counter/hist snapshots already nest under "stream" (dotted
            # names); graft the tracker's memory/coverage stats alongside.
            snap.setdefault("stream", {})["tracker"] = self._tracker.stats()
        if self.autoscaler is not None and self.autoscaler.decisions:
            snap["autoscale_last"] = dataclasses.asdict(
                self.autoscaler.decisions[-1])
        return snap

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.snapshot(), **dump_kw)

    # ---------------------------------------------------------- lifecycle
    def start_background(self, *, refresh_every: float | None = None,
                         refresh_fraction: float = 0.25,
                         autoscale_every: float | None = None,
                         compact_every: float | None = None,
                         compact_threshold: float = 0.10) -> None:
        """Arm the background loops: replica-sweep refresh, autoscaling,
        and the tombstone-compaction poll (`maybe_compact` every
        ``compact_every`` seconds against ``compact_threshold``)."""
        if refresh_every is not None:
            self.group.start_refresh(refresh_every, refresh_fraction)
        if autoscale_every is not None:
            if self.autoscaler is None:
                raise RuntimeError("tier built without autoscale config")
            self.autoscaler.start(autoscale_every)
        if compact_every is not None:
            if self._compactor is not None:
                raise RuntimeError("compaction thread already running")

            def loop():
                while not self._compact_stop.wait(compact_every):
                    self.maybe_compact(compact_threshold)

            self._compactor = threading.Thread(target=loop, daemon=True,
                                               name="tier-compact")
            self._compactor.start()

    def close(self, timeout: float | None = None) -> None:
        self._compact_stop.set()
        if self._compactor is not None:
            self._compactor.join(timeout)
            self._compactor = None
        if self.autoscaler is not None:
            self.autoscaler.close(timeout)
        self.group.close(timeout)

    def __enter__(self) -> "ServingTier":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
