"""Per-tenant token-bucket admission control.

Sits *in front of* submit: a query that would oversubscribe its tenant's
bucket is shed with a retriable `ShedError` **before** it touches a
batcher, so an over-quota tenant can never occupy engine slots, poison a
shared flush, or crowd a deadline — the blast radius of a hot tenant is
exactly its own traffic.

Each tenant owns one token bucket (``rate`` tokens/s refill, ``burst``
capacity) refilled lazily from a monotonic clock on every admission
attempt, so there is no refill thread and an idle tenant costs nothing.
`ShedError.retry_after` tells the client exactly when the bucket will
next hold the tokens its request needs — the contract an open-loop load
generator (and a well-behaved client) uses to back off instead of
hammering.

The clock is injectable for deterministic tests.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time

from repro.serve.tier.metrics import escape_label


class ShedError(RuntimeError):
    """Request shed by admission control; retriable after ``retry_after``.

    ``retry_after`` (seconds) is when the tenant's bucket will have refilled
    enough for this request's cost; ``tenant`` names the throttled tenant.
    ``retry_after`` is ``math.inf`` when the request can NEVER be admitted
    (``cost`` exceeds the bucket's burst capacity) — don't retry those.
    """

    def __init__(self, tenant: str, retry_after: float, cost: float = 1.0):
        if math.isinf(retry_after):
            msg = (f"tenant {tenant!r}: cost {cost:g} exceeds burst "
                   "capacity — never admissible; do not retry")
        else:
            msg = f"tenant {tenant!r} over quota: retry in {retry_after:.3f}s"
        super().__init__(msg)
        self.tenant = tenant
        self.retry_after = retry_after
        self.cost = cost


@dataclasses.dataclass
class _Bucket:
    rate: float         # tokens per second
    burst: float        # bucket capacity
    tokens: float       # current fill
    stamp: float        # last refill time (clock units)

    def refill(self, now: float) -> None:
        self.tokens = min(self.burst, self.tokens + (now - self.stamp)
                          * self.rate)
        self.stamp = now


class AdmissionController:
    """Token-bucket admission over named tenants.

    Unknown tenants get the default (``rate``/``burst``) on first sight;
    ``set_quota`` pins a per-tenant override (e.g. a paid tier).  A
    ``rate`` of ``None`` (or ``float("inf")``) means unmetered.
    """

    def __init__(self, rate: float | None = 100.0, burst: float | None = None,
                 *, clock=time.monotonic, metrics=None):
        self.default_rate = rate
        self.default_burst = burst
        self._clock = clock
        self._metrics = metrics
        self._lock = threading.Lock()
        self._buckets: dict[str, _Bucket] = {}

    def _make_bucket(self, rate: float | None,
                     burst: float | None) -> _Bucket | None:
        if rate is None or rate == float("inf"):
            return None                     # unmetered tenant
        burst = burst if burst is not None else max(1.0, rate)
        return _Bucket(rate=float(rate), burst=float(burst),
                       tokens=float(burst), stamp=self._clock())

    def set_quota(self, tenant: str, rate: float | None,
                  burst: float | None = None) -> None:
        with self._lock:
            self._buckets[tenant] = self._make_bucket(rate, burst)

    def quota(self, tenant: str) -> tuple[float, float] | None:
        """(rate, burst) for a tenant, or None when unmetered."""
        with self._lock:
            if tenant not in self._buckets:
                self._buckets[tenant] = self._make_bucket(
                    self.default_rate, self.default_burst)
            b = self._buckets[tenant]
        return None if b is None else (b.rate, b.burst)

    # ------------------------------------------------------------- admit
    def admit(self, tenant: str, cost: float = 1.0) -> None:
        """Take ``cost`` tokens from the tenant's bucket or raise `ShedError`.

        The shed path never blocks and never takes partial tokens — a shed
        request leaves the bucket exactly as it found it, so retrying at
        ``retry_after`` genuinely succeeds absent competing traffic.  A
        ``cost`` above the bucket's burst capacity can never be satisfied
        by waiting (tokens cap at burst); it sheds with
        ``retry_after=math.inf`` so clients don't retry forever on a
        finite hint that can never come true.
        """
        with self._lock:
            if tenant not in self._buckets:
                self._buckets[tenant] = self._make_bucket(
                    self.default_rate, self.default_burst)
            bucket = self._buckets[tenant]
            if bucket is None:
                self._count(tenant, "admitted")
                return
            if cost > bucket.burst:
                retry_after = math.inf
            else:
                bucket.refill(self._clock())
                if bucket.tokens >= cost:
                    bucket.tokens -= cost
                    self._count(tenant, "admitted")
                    return
                retry_after = (cost - bucket.tokens) / bucket.rate
        self._count(tenant, "shed")
        raise ShedError(tenant, retry_after, cost)

    def _count(self, tenant: str, what: str) -> None:
        if self._metrics is not None:
            # Tenant ids are user-supplied: escape so a dotted id (e.g.
            # "org.acme") can't nest under extra snapshot levels and fall
            # out of the tier's admitted/shed totals.
            self._metrics.counter(
                f"tenant.{escape_label(tenant)}.{what}").add()
