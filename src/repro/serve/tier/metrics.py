"""Lock-cheap serving metrics: counters + log-bucketed latency histograms.

Every tier component (admission controller, replica router, autoscaler,
front door) exports its observability through one `MetricSet`:

* `Counter` — a monotonically-increasing integer behind a per-counter lock
  (the critical section is one add, never a dispatch);
* `Histogram` — latencies recorded into geometrically-spaced buckets, so
  ``record()`` is a bisect + one locked increment and quantiles
  (p50/p99/p999) come from the bucket CDF with no sample retention;
* `MetricSet.snapshot()` — a JSON-serializable dict of every metric, each
  read atomically (counters under their own lock, histogram counts copied
  in one acquisition), suitable for a scrape endpoint or the SLO
  load-generator's per-cell records.

Nothing here touches jax: metrics are pure host bookkeeping, cheap enough
to sit on the submit path of every query.
"""
from __future__ import annotations

import bisect
import json
import math
import threading


def escape_label(label: str) -> str:
    """Metric-name-safe form of a user-supplied label (e.g. a tenant id).

    Metric names are dotted paths and `MetricSet.snapshot` nests them by
    splitting on ``"."``, so a dot inside a label would nest that tenant's
    counters one level deeper (and drop them from the tier's totals).
    Percent-escaping ``%`` then ``.`` is injective — distinct labels can
    never collide after escaping — and keeps names ASCII and readable
    (``"org.acme"`` → ``"org%2Eacme"``).
    """
    return label.replace("%", "%25").replace(".", "%2E")


class Counter:
    """Thread-safe monotonic counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0

    def add(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


def _default_bounds() -> list[float]:
    """Geometric bucket upper bounds: 50 µs … ~520 s, ×1.4 per bucket
    (~42 buckets — ≤ ±20% quantile resolution, plenty for SLO tails)."""
    bounds, b = [], 50e-6
    while b < 600.0:
        bounds.append(b)
        b *= 1.4
    return bounds


class Histogram:
    """Latency histogram with bucket-CDF quantiles (seconds in, seconds out)."""

    def __init__(self, bounds: list[float] | None = None):
        self._bounds = list(bounds) if bounds is not None else _default_bounds()
        self._lock = threading.Lock()
        self._counts = [0] * (len(self._bounds) + 1)    # +1 overflow bucket
        self._count = 0
        self._sum = 0.0
        self._max = 0.0

    def record(self, seconds: float) -> None:
        i = bisect.bisect_left(self._bounds, seconds)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += seconds
            if seconds > self._max:
                self._max = seconds

    def _copy(self):
        with self._lock:
            return list(self._counts), self._count, self._sum, self._max

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket where the CDF crosses ``q`` (0 when
        empty; the observed max for the overflow bucket)."""
        counts, total, _, mx = self._copy()
        if total == 0:
            return 0.0
        rank, seen = math.ceil(q * total), 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= rank:
                return self._bounds[i] if i < len(self._bounds) else mx
        return mx

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def snapshot(self) -> dict:
        counts, total, s, mx = self._copy()
        out = {"count": total, "mean": (s / total) if total else 0.0,
               "max": mx}
        for name, q in (("p50", 0.50), ("p99", 0.99), ("p999", 0.999)):
            out[name] = self.quantile(q)
        return out


class MetricSet:
    """Named counters + histograms with one atomic-per-metric snapshot.

    Metrics are created on first use (``counter(name)`` / ``hist(name)``),
    so components never pre-declare; names are dotted paths
    (``"tenant.alice.admitted"``, ``"router.replica0.dispatch_s"``) and the
    snapshot nests them back into a tree for readable JSON.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def hist(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    @staticmethod
    def _nest(tree: dict, name: str, value) -> None:
        parts = name.split(".")
        for p in parts[:-1]:
            tree = tree.setdefault(p, {})
        tree[parts[-1]] = value

    def snapshot(self) -> dict:
        """JSON-serializable tree of every metric (each metric atomic)."""
        with self._lock:
            counters = dict(self._counters)
            hists = dict(self._hists)
        tree: dict = {}
        for name, c in sorted(counters.items()):
            self._nest(tree, name, c.value)
        for name, h in sorted(hists.items()):
            self._nest(tree, name, h.snapshot())
        return tree

    def to_json(self, **dump_kw) -> str:
        return json.dumps(self.snapshot(), **dump_kw)
