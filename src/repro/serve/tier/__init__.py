"""Production serving tier: quotas → replicas → autoscaling → SLOs.

PRs 1–5 built one fast engine behind one async stream; this package is
the layer that makes the sketch pool survive *traffic*:

* `quota.AdmissionController` — per-tenant token buckets in front of
  submit; over-quota requests shed with a retriable `ShedError` carrying
  ``retry_after`` before they can touch an engine;
* `router.ReplicaGroup` — N engine replicas serving clones of the SAME
  epoch-tagged pool (least-pending/round-robin pick), with an epoch
  consistency guard (`EpochMixError`) that refuses to hand back replies
  spanning a mid-stream refresh, and an atomic-per-replica refresh sweep
  that re-converges all replicas bit-identically at the new epoch;
* `autoscale.AutoScaler` — grows/shrinks the pool slot count from
  measured signals (query p99 + the inverse IMM coverage-error bound
  `core.imm.eps_bound_for_theta`) through the donated-buffer
  ensure/shrink paths, never a cold rebuild;
* `metrics.MetricSet` — lock-cheap counters + log-bucket latency
  histograms (p50/p99/p999), snapshot-able as JSON;
* `service.ServingTier` — the front door wiring all of the above.

    store = SketchStore(g, PoolConfig(num_colors=64)); store.ensure(8)
    tier = ServingTier.build(store, replicas=2, quota_qps=50.0,
                             default_deadline=0.02)
    tier.set_quota("free", rate=2.0, burst=2)
    sigma = tier.submit_sigma("alice", [3, 17, 42]).result()

Load behavior is measured by ``benchmarks/bench_serve_load.py`` (open-loop
Poisson arrivals, tenant mix → p50/p99/p999, shed rate, achieved qps).
"""
from repro.serve.tier.autoscale import AutoScaleDecision, AutoScaler
from repro.serve.tier.metrics import Counter, Histogram, MetricSet
from repro.serve.tier.quota import AdmissionController, ShedError
from repro.serve.tier.router import EpochMixError, Replica, ReplicaGroup
from repro.serve.tier.service import ServingTier

__all__ = ["AdmissionController", "AutoScaleDecision", "AutoScaler",
           "Counter", "EpochMixError", "Histogram", "MetricSet", "Replica",
           "ReplicaGroup", "ServingTier", "ShedError"]
