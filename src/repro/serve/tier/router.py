"""Replica routing: fan read traffic over N engines serving ONE pool.

A `Replica` bundles a sketch store, a query engine, a `MicroBatcher` (+
its epoch-keyed cache) and a deadline-batched `AsyncFrontEnd`.  A
`ReplicaGroup` holds N of them built from **clones of the same pool**
(`SketchStore.clone` — shared immutable batches, zero resampling) and
routes each submit to one replica:

* **least_pending** (default) — the replica with the fewest unresolved
  queries, so a slow flush on one replica never queues the others;
* **round_robin** — strict rotation, useful for benchmarking.

**Epoch consistency.**  Every answer is stamped with the pool ``version``
of the flush that computed it (`AsyncFrontEnd` sets ``fut.pool_version``
inside the dispatch lock).  `gather()` is the guard: it refuses to hand
back a set of replies spanning more than one pool version
(`EpochMixError`), so a caller composing multi-query results (a σ
comparison, a marginal-gain sweep) can never silently mix estimates from
different sample populations.

**Replica refresh.**  `refresh()` sweeps the replicas one at a time, each
swap atomic per replica (`AsyncFrontEnd.mutate_store` — the same lock
every flush holds).  Because each clone continues the same
``next_batch_index`` trajectory from the same master seed, the same
refresh applied to every replica resamples the same slots with the same
RNG streams: after the sweep all replicas are **bit-identical again at
the new epoch**.  Mid-sweep, replicas disagree only on version — which
`gather()` turns into a retriable error instead of a wrong answer.
Sweeps are mutually exclusive: `refresh()` and `scale_to()` hold a
group-wide mutation lock for the whole sweep, so every replica sees the
same mutation sequence in the same order even with the background
refresh and autoscale threads both running.  `start_refresh(every)` runs
the sweep on a background thread.
"""
from __future__ import annotations

import itertools
import threading
import time

from repro.serve.distributed.frontend import AsyncFrontEnd
from repro.serve.influence import MicroBatcher, ResultCache
from repro.serve.influence.engine import QueryEngine


class EpochMixError(RuntimeError):
    """A reply set spans more than one pool version; retry the request.

    Raised by `ReplicaGroup.gather` instead of returning estimates drawn
    from different sample populations.  ``versions`` lists the distinct
    pool versions observed.
    """

    def __init__(self, versions):
        super().__init__(f"replies span pool versions {sorted(versions)} — "
                         "a refresh landed mid-request; retry")
        self.versions = tuple(sorted(versions))


class Replica:
    """One engine replica: store + engine + batcher + async front-end."""

    def __init__(self, index: int, store, engine, frontend: AsyncFrontEnd):
        self.index = index
        self.store = store
        self.engine = engine
        self.frontend = frontend

    @classmethod
    def build(cls, index: int, store, *, engine_factory=QueryEngine,
              cache_capacity: int = 4096, **frontend_kw) -> "Replica":
        engine = engine_factory(store)
        batcher = MicroBatcher(engine, cache=ResultCache(cache_capacity))
        return cls(index, store, engine,
                   AsyncFrontEnd(batcher, **frontend_kw))

    @property
    def pending(self) -> int:
        return self.frontend.inflight

    @property
    def version(self):
        return self.store.version

    def close(self, timeout: float | None = None) -> None:
        self.frontend.close(timeout)


class ReplicaGroup:
    """N replicas of one epoch-tagged pool behind a pick policy."""

    POLICIES = ("least_pending", "round_robin")

    def __init__(self, replicas: list[Replica], *,
                 policy: str = "least_pending", metrics=None):
        if not replicas:
            raise ValueError("ReplicaGroup needs at least one replica")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"pick one of {self.POLICIES}")
        self.replicas = list(replicas)
        self.policy = policy
        self._metrics = metrics
        self._rr = itertools.count()
        # Serializes group-wide mutation sweeps (refresh / scale_to).  Per-
        # replica atomicity (mutate_store) is NOT enough: if the background
        # refresh sweep and the autoscaler's scale sweep interleaved,
        # replica 0 could apply refresh-then-ensure while replica 1 applied
        # ensure-then-refresh — each order consumes batch indices (RNG
        # streams) into different slots, so the replicas would permanently
        # diverge while still agreeing on (epoch, count) and consistent()
        # could not tell.  Holding this lock for the FULL sweep guarantees
        # every replica applies the same mutation sequence in the same
        # order.
        self._mutate_lock = threading.Lock()
        self._refresher: threading.Thread | None = None
        self._stop = threading.Event()

    @classmethod
    def build(cls, store, num_replicas: int, *, engine_factory=QueryEngine,
              policy: str = "least_pending", metrics=None,
              **frontend_kw) -> "ReplicaGroup":
        """Replicate ``store`` (clone — no resampling) behind a group."""
        replicas = [
            Replica.build(i, store if i == 0 else store.clone(),
                          engine_factory=engine_factory, **frontend_kw)
            for i in range(num_replicas)]
        return cls(replicas, policy=policy, metrics=metrics)

    # --------------------------------------------------------------- pick
    def pick(self) -> Replica:
        if self.policy == "round_robin" or len(self.replicas) == 1:
            return self.replicas[next(self._rr) % len(self.replicas)]
        return min(self.replicas, key=lambda r: (r.pending, r.index))

    def _submit(self, kind: str, payload, deadline):
        r = self.pick()
        fut = getattr(r.frontend, f"submit_{kind}")(payload,
                                                    deadline=deadline)
        fut.replica_index = r.index
        if self._metrics is not None:
            self._metrics.counter(f"router.replica{r.index}.dispatched").add()
        return fut

    def submit_top_k(self, k: int, *, deadline: float | None = None):
        return self._submit("top_k", k, deadline)

    def submit_sigma(self, seed_set, *, deadline: float | None = None):
        return self._submit("sigma", seed_set, deadline)

    def submit_marginal(self, exclude, *, deadline: float | None = None):
        return self._submit("marginal", exclude, deadline)

    # ------------------------------------------------------------- gather
    @staticmethod
    def gather(futures, timeout: float | None = None) -> list:
        """Results of ``futures``, refusing mixed-epoch reply sets.

        Waits for every future, re-raises the first failure, and checks all
        replies carry the SAME pool version — else `EpochMixError` (the
        caller retries; by then the refresh sweep has converged).  Single
        replies can't mix and pass trivially.  ``timeout`` bounds the WHOLE
        gather (one deadline shared across the futures), not each future.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        values = [f.result(None if deadline is None
                           else deadline - time.monotonic())
                  for f in futures]
        versions = {f.pool_version for f in futures}
        if len(versions) > 1:
            raise EpochMixError(versions)
        return values

    # ------------------------------------------------- epoch-swap refresh
    def refresh(self, fraction: float = 0.25) -> list[int]:
        """Refresh every replica (atomic per replica, identical streams);
        returns the resampled slots (same on every replica).  The whole
        sweep holds the group mutation lock so it can never interleave
        with `scale_to` (see ``_mutate_lock``)."""
        slots: list[int] = []
        with self._mutate_lock:
            for r in self.replicas:
                slots = r.frontend.refresh_now(fraction)
        return slots

    def scale_to(self, num_batches: int) -> None:
        """Grow/shrink every replica's pool to ``num_batches`` slots, each
        swap atomic per replica and the whole sweep exclusive with
        `refresh` (group mutation lock).  Same mutation + same stream
        trajectory ⇒ replicas stay bit-identical at the new size."""
        with self._mutate_lock:
            for r in self.replicas:
                r.frontend.mutate_store(
                    lambda store: (store.ensure(num_batches),
                                   store.shrink(num_batches)))

    def apply_delta(self, delta, tracker):
        """Apply a streaming graph delta to EVERY replica: one shared
        plan (replicas are bit-identical, so one dirty set serves all),
        then a per-replica atomic swap + dirty-slot resample through
        `AsyncFrontEnd.mutate_store` — the same lock every flush holds,
        so an in-flight query is answered entirely pre- or post-delta
        and stamped with the matching graph-epoch version.

        The whole plan+sweep holds the group mutation lock: a refresh or
        scale sweep can neither interleave (which would let replicas see
        delta/refresh in different orders and permanently diverge) nor
        run against a stale plan.  Returns the `stream.StreamReport`.
        """
        from repro.stream import refresh as stream_refresh

        with self._mutate_lock:
            store0 = self.replicas[0].store
            plan = stream_refresh.plan_refresh(store0, tracker, delta)
            t0 = time.perf_counter()
            for r in self.replicas:
                r.frontend.mutate_store(
                    lambda store: stream_refresh.apply_plan(store, plan))
            refresh_s = time.perf_counter() - t0
            tracker.sync(store0)
            tracker.note_delta(len(plan.dirty_slots))
        return stream_refresh.StreamReport(
            inserted=plan.applied.inserted, deleted=plan.applied.deleted,
            touched_row_blocks=len(plan.touched_row_blocks),
            dirty_slots=len(plan.dirty_slots),
            total_slots=plan.total_slots,
            dirty_fraction=plan.dirty_fraction, refresh_s=refresh_s,
            graph_epoch=store0.graph_epoch)

    def compact(self) -> float:
        """Tombstone-compaction rebuild swept over every replica; returns
        the tombstone fraction that was reclaimed.

        ONE shared rebuilt pair (`stream.compact_graph`) serves the whole
        group — each replica swaps it in and resamples EVERY slot at its
        recorded batch indices, so the group re-converges bit-identical
        on the renumbered edge ids.  Holds the group mutation lock for
        the whole sweep, exclusive with refresh / scale / delta sweeps.
        """
        from repro.stream import compact as compact_lib

        with self._mutate_lock:
            store0 = self.replicas[0].store
            frac = compact_lib.tombstone_fraction(store0.graph)
            g2, g_rev2 = compact_lib.compact_graph(store0.graph)

            def swap(store):
                store.apply_graph_update(g2, g_rev2)
                store.resample_slots(list(range(len(store.batches))))

            for r in self.replicas:
                r.frontend.mutate_store(swap)
        return frac

    def start_refresh(self, every: float, fraction: float = 0.25) -> None:
        """Background replica-refresh sweep every ``every`` seconds."""
        if self._refresher is not None:
            raise RuntimeError("refresh thread already running")

        def loop():
            while not self._stop.wait(every):
                self.refresh(fraction)

        self._refresher = threading.Thread(target=loop, daemon=True,
                                           name="tier-refresh")
        self._refresher.start()

    # ---------------------------------------------------------- lifecycle
    @property
    def num_batches(self) -> int:
        return len(self.replicas[0].store.batches)

    def versions(self) -> list:
        return [r.version for r in self.replicas]

    def consistent(self) -> bool:
        """True when every replica serves the same pool version."""
        return len(set(self.versions())) == 1

    def pending(self) -> list[int]:
        return [r.pending for r in self.replicas]

    def close(self, timeout: float | None = None) -> None:
        self._stop.set()
        if self._refresher is not None:
            self._refresher.join(timeout)
            self._refresher = None
        for r in self.replicas:
            r.close(timeout)

    def __enter__(self) -> "ReplicaGroup":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
