"""Signal-driven sketch-pool autoscaling.

The pool slot count is the serving tier's one capacity knob: more slots →
tighter coverage-error bound (θ = slots × colors samples) but a heavier
per-query popcount sweep (every σ/marginal/top-k scans all B slots).  The
`AutoScaler` closes the loop from two *measured* signals:

* **coverage error** — `core.imm.eps_bound_for_theta`, the exact inverse
  of ``estimate_theta``'s λ*/LB sample bound: the smallest IMM ε the
  current θ certifies, with OPT lower-bounded by the greedy σ̂ the pool
  itself serves (refreshed each step, it tracks pool drift for free);
* **query latency** — the tier's p99 from its `metrics.Histogram`
  (an SLO target in milliseconds).

Policy (evaluated by ``step()``, applied via `ReplicaGroup.scale_to` →
`AsyncFrontEnd.mutate_store` → ``SketchStore.ensure``/``shrink``, so every
scale event is an atomic per-replica epoch swap that extends or slices the
existing pool allocation — **never** a cold rebuild):

1. ε bound above ``target_eps`` → **grow** to the slot count whose θ meets
   the target (accuracy beats latency: an out-of-bound estimator is wrong,
   a slow one is late).
2. Otherwise, p99 above ``target_p99_ms`` AND the pool has ε headroom
   (shedding one ``shrink_step`` keeps ε ≤ ``headroom`` × target) →
   **shrink** one step.
3. Otherwise **hold**.

Decisions are clamped to [``min_batches``, ``max_batches``] and returned
as an `AutoScaleDecision` record so launchers/benchmarks can log the whole
control trajectory.  ``start(every)`` runs ``step()`` on a background
thread.
"""
from __future__ import annotations

import dataclasses
import math
import threading

from repro.core import imm


@dataclasses.dataclass(frozen=True)
class AutoScaleDecision:
    action: str                 # "grow" | "shrink" | "hold"
    batches_before: int
    batches_after: int
    reason: str
    eps_bound: float
    p99_ms: float | None
    theta: int


class AutoScaler:
    """Grow/shrink a `ReplicaGroup`'s pool from measured signals."""

    def __init__(self, group, *, k: int = 8, target_eps: float = 0.3,
                 target_p99_ms: float | None = None,
                 latency_hist=None, ell: float = 1.0,
                 headroom: float = 1.3, shrink_step: int = 1,
                 min_batches: int = 1, max_batches: int | None = None,
                 metrics=None):
        self.group = group
        self.k = k
        self.target_eps = target_eps
        self.target_p99_ms = target_p99_ms
        self.latency_hist = latency_hist
        self.ell = ell
        self.headroom = headroom
        self.shrink_step = shrink_step
        self.min_batches = min_batches
        store = group.replicas[0].store
        self.max_batches = (max_batches if max_batches is not None
                            else store.capacity)
        self._metrics = metrics
        self._opt_lb = 1.0
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self.decisions: list[AutoScaleDecision] = []

    # ------------------------------------------------------------ signals
    @property
    def _store(self):
        return self.group.replicas[0].store

    def _refresh_opt_lb(self) -> float:
        """OPT ≥ σ̂(greedy seeds): one top-k through a replica's own
        front-end (so it serializes with dispatch and rides the cache)."""
        fut = self.group.submit_top_k(self.k, deadline=0.0)
        _, sigma_hat = fut.result(timeout=600)
        self._opt_lb = max(self._opt_lb, float(sigma_hat))
        return self._opt_lb

    def eps_bound(self, theta: int | None = None) -> float:
        store = self._store
        return imm.eps_bound_for_theta(
            store.graph.num_vertices, self.k,
            theta if theta is not None else store.num_samples,
            ell=self.ell, opt_lb=self._opt_lb)

    def _batches_for_eps(self, eps: float) -> int:
        """Smallest slot count whose θ certifies ``eps`` (λ* ∝ 1/ε²)."""
        store = self._store
        coeff = imm.eps_bound_for_theta(store.graph.num_vertices, self.k, 1,
                                        ell=self.ell, opt_lb=self._opt_lb)
        theta_needed = (coeff / eps) ** 2
        return max(1, math.ceil(theta_needed / store.num_colors))

    def p99_ms(self) -> float | None:
        if self.latency_hist is None or self.latency_hist.count == 0:
            return None
        return self.latency_hist.quantile(0.99) * 1e3

    # --------------------------------------------------------------- step
    def step(self) -> AutoScaleDecision:
        """Evaluate the signals once; apply and record the decision."""
        self._refresh_opt_lb()
        before = self.group.num_batches
        eps_now = self.eps_bound()
        p99 = self.p99_ms()
        target, action, reason = before, "hold", "within targets"

        if eps_now > self.target_eps:
            want = min(self._batches_for_eps(self.target_eps),
                       self.max_batches)
            if want > before:
                action, target = "grow", want
                reason = (f"eps bound {eps_now:.3f} > target "
                          f"{self.target_eps:.3f}")
            else:
                reason = (f"eps bound {eps_now:.3f} over target but pool "
                          f"at max_batches={self.max_batches}")
        elif (self.target_p99_ms is not None and p99 is not None
              and p99 > self.target_p99_ms):
            shrunk = max(self.min_batches, before - self.shrink_step)
            eps_shrunk = self.eps_bound(shrunk * self._store.num_colors)
            if shrunk < before and \
                    eps_shrunk <= self.headroom * self.target_eps:
                action, target = "shrink", shrunk
                reason = (f"p99 {p99:.1f}ms > target {self.target_p99_ms}ms "
                          f"with eps headroom ({eps_shrunk:.3f} ≤ "
                          f"{self.headroom:.2f}×{self.target_eps:.3f})")
            else:
                reason = (f"p99 {p99:.1f}ms over target but no eps headroom "
                          "to shrink")

        if action != "hold":
            self.group.scale_to(target)
        after = self.group.num_batches
        decision = AutoScaleDecision(action, before, after, reason,
                                     round(eps_now, 4), p99,
                                     self._store.num_samples)
        self.decisions.append(decision)
        if self._metrics is not None:
            self._metrics.counter(f"autoscale.{action}").add()
        return decision

    # ---------------------------------------------------------- lifecycle
    def start(self, every: float) -> None:
        if self._thread is not None:
            raise RuntimeError("autoscaler already running")

        def loop():
            while not self._stop.wait(every):
                self.step()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="tier-autoscale")
        self._thread.start()

    def close(self, timeout: float | None = None) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
