"""Batched serving: prefill once, decode many, static-shape caches.

``caches_from_prefill`` converts the per-stack cache pytrees that
``model.forward(collect_cache=True)`` emits (tuples, prompt-length) into the
decode layout (dicts, padded to ``max_len``) — one prefill pass replaces
prompt_len decode steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as dec
from repro.models import model
from repro.models.config import ModelConfig
from repro.models.model import stacks_of


def _pad_seq(x, max_len, axis):
    pad = max_len - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def caches_from_prefill(cfg: ModelConfig, prefill_caches, max_len: int):
    """Prefill cache (tuples, length L) → decode cache (dicts, max_len)."""
    out = []
    for (pattern, groups), stack_cache in zip(stacks_of(cfg), prefill_caches):
        stack = {}
        for i, kind in enumerate(pattern):
            c = stack_cache[f"block{i}"]
            if kind == "mamba":
                state, tail = c
                stack[f"block{i}"] = {"state": state, "conv": tail}
            elif kind == "mamba_attn":
                (state, tail), (k, v) = c
                stack[f"block{i}"] = (
                    {"state": state, "conv": tail},
                    {"k": _pad_seq(k, max_len, 2),
                     "v": _pad_seq(v, max_len, 2)})
            elif cfg.attention == "mla":
                c_lat, k_rope = c
                stack[f"block{i}"] = {"c": _pad_seq(c_lat, max_len, 2),
                                      "k_rope": _pad_seq(k_rope, max_len, 2)}
            else:
                k, v = c
                stack[f"block{i}"] = {"k": _pad_seq(k, max_len, 2),
                                      "v": _pad_seq(v, max_len, 2)}
        out.append(stack)
    return out


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int):
    """Returns (last-position logits, decode-ready caches, prompt_len)."""
    logits, _, caches = model.forward(params, cfg, batch, collect_cache=True)
    prompt_len = logits.shape[1]
    return logits[:, -1:], caches_from_prefill(cfg, caches, max_len), \
        prompt_len


def generate(params, cfg: ModelConfig, prompt: jnp.ndarray, num_new: int,
             *, key=None, temperature: float = 0.0, max_len: int = 0):
    """Greedy / temperature sampling for a batch of equal-length prompts.

    prompt: (B, Lp) (audio: (B, K, Lp)).  Returns (B, num_new) tokens
    (audio: (B, K, num_new))."""
    Lp = prompt.shape[-1]
    max_len = max_len or Lp + num_new
    batch = {"tokens": prompt, "labels": prompt}
    last_logits, caches, _ = prefill(params, cfg, batch, max_len)

    step_fn = jax.jit(lambda p, c, t, n: dec.decode_step(p, cfg, c, t, n))
    outs = []
    logits = last_logits

    def sample(lg, k):
        if temperature <= 0:
            return jnp.argmax(lg, -1)
        return jax.random.categorical(k, lg / temperature, axis=-1)

    key = key if key is not None else jax.random.key(0)
    for i in range(num_new):
        key, sk = jax.random.split(key)
        if cfg.num_codebooks:
            tok = sample(logits[:, -1], sk)          # (B, K)
            tok = jnp.swapaxes(tok[:, None], 1, 2)   # (B, K, 1)
        else:
            tok = sample(logits[:, -1], sk)[:, None]  # (B, 1)
        outs.append(tok)
        logits, caches = step_fn(params, caches, tok, jnp.int32(Lp + i))
    return jnp.concatenate(outs, -1 if cfg.num_codebooks else 1)
