"""Epoch-keyed LRU result cache for influence queries.

Entries are tagged with the sketch pool ``version`` (epoch + size) they
were computed against; a lookup under any other version is a miss and
evicts the stale entry, so a pool refresh invalidates the whole working set
without a scan.  Keys are canonicalized seed-set tuples, making the cache
insensitive to caller-side ordering/duplication of seeds.

**Thread safety.**  Mutations (``get``/``put``/``clear``) are guarded by an
internal lock, and ``stats()`` returns one *atomic* snapshot of the
counters — hits, misses, size, and hit rate all read under the same lock
acquisition, so observers (the serving tier's ``metrics`` exporter, which
polls caches from outside their owning batcher) never see a torn view such
as a hit count from one flush paired with a miss count from the next.  The
bare ``hits``/``misses`` attributes remain for single-threaded callers.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable


def seed_key(seeds) -> tuple:
    """Canonical cache key for a seed set (order/duplicate insensitive)."""
    return tuple(sorted({int(s) for s in seeds}))


class ResultCache:
    """LRU over (kind, key) entries, each pinned to a pool version."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._entries: OrderedDict[tuple, tuple[Hashable, Any]] = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, version: Hashable, kind: str, key: Hashable):
        """Value if present AND computed under ``version``; else None."""
        with self._lock:
            entry = self._entries.get((kind, key))
            if entry is None:
                self.misses += 1
                return None
            ver, value = entry
            if ver != version:
                del self._entries[(kind, key)]          # stale epoch
                self.misses += 1
                return None
            self._entries.move_to_end((kind, key))
            self.hits += 1
            return value

    def put(self, version: Hashable, kind: str, key: Hashable, value) -> None:
        with self._lock:
            self._entries[(kind, key)] = (version, value)
            self._entries.move_to_end((kind, key))
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def stats(self) -> dict:
        """Atomic counter snapshot: {hits, misses, size, hit_rate}."""
        with self._lock:
            hits, misses, size = self.hits, self.misses, len(self._entries)
        total = hits + misses
        return {"hits": hits, "misses": misses, "size": size,
                "hit_rate": (hits / total) if total else 0.0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
