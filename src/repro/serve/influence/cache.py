"""Epoch-keyed LRU result cache for influence queries.

Entries are tagged with the sketch pool ``version`` (epoch + size) they
were computed against; a lookup under any other version is a miss and
evicts the stale entry, so a pool refresh invalidates the whole working set
without a scan.  Keys are canonicalized seed-set tuples, making the cache
insensitive to caller-side ordering/duplication of seeds.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Hashable


def seed_key(seeds) -> tuple:
    """Canonical cache key for a seed set (order/duplicate insensitive)."""
    return tuple(sorted({int(s) for s in seeds}))


class ResultCache:
    """LRU over (kind, key) entries, each pinned to a pool version."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._entries: OrderedDict[tuple, tuple[Hashable, Any]] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, version: Hashable, kind: str, key: Hashable):
        """Value if present AND computed under ``version``; else None."""
        entry = self._entries.get((kind, key))
        if entry is None:
            self.misses += 1
            return None
        ver, value = entry
        if ver != version:
            del self._entries[(kind, key)]          # stale epoch
            self.misses += 1
            return None
        self._entries.move_to_end((kind, key))
        self.hits += 1
        return value

    def put(self, version: Hashable, kind: str, key: Hashable, value) -> None:
        self._entries[(kind, key)] = (version, value)
        self._entries.move_to_end((kind, key))
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
