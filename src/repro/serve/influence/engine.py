"""Batched influence-query engine over a sketch pool.

Three query types, all answered from the pool's columnar (B, V, W) bitmask
stack with jit-compiled, static-shape device programs:

* **top-k** — greedy max-k-cover seed selection, via the shared incremental
  kernel ``core.imm.greedy_extend`` (the same ``lax.fori_loop`` program
  offline ``run_imm`` uses);
* **σ(S)** — influence estimate for an arbitrary seed set: the covered
  colors are the OR of the seeds' mask rows, σ(S) ≈ n · covered/θ;
* **marginal gain with exclusions** — per-vertex gain Δσ(v | X) against an
  active mask with the exclusion set X's colors stripped, one
  ``kernels.ops.cover_counts`` sweep per pool batch.

σ(S)/marginal queries are *slotted*: the engine compiles one program for a
fixed ``(query_slots, max_seeds)`` shape and the batcher pads every flush
into it, so concurrent callers share a single device dispatch and no query
mix triggers recompilation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitmask, imm
from repro.kernels import ops
from repro.serve.influence import sketch_store


def _frozen(arr: np.ndarray) -> np.ndarray:
    """Engine results are shared across callers (cache hits, deduped
    tickets) — freeze them so one caller's in-place edit can't corrupt
    another's answer."""
    arr.flags.writeable = False
    return arr


def pad_queries(seed_sets, query_slots: int, max_seeds: int):
    """Pack ragged seed sets into (Q, S) index + validity-mask tensors."""
    if len(seed_sets) > query_slots:
        raise ValueError(f"{len(seed_sets)} queries > {query_slots} slots")
    seeds = np.zeros((query_slots, max_seeds), np.int32)
    mask = np.zeros((query_slots, max_seeds), bool)
    for q, s in enumerate(seed_sets):
        s = list(s)
        if len(s) > max_seeds:
            raise ValueError(f"seed set of {len(s)} > max_seeds={max_seeds}")
        seeds[q, :len(s)] = s
        mask[q, :len(s)] = True
    return jnp.asarray(seeds), jnp.asarray(mask)


def _union_rows(visited, seeds, mask, take_rows=None):
    """OR of the selected mask rows: (B,V,W) × (Q,S) → (B,Q,W) covered.

    ``take_rows`` overrides the row gather when the vertex dim is sharded
    (`ShardedSketchStore` row sharding): it maps flat GLOBAL seed ids to
    (B, Q·S, W) rows — the owning model shard contributes its local row,
    one psum over the model axis merges (rows are disjointly owned, so
    integer sum ≡ the exact row), and the result is replicated across
    model shards.
    """
    b, v, w = visited.shape
    q, s = seeds.shape
    flat = seeds.reshape(-1)
    rows = (jnp.take(visited, flat, axis=1) if take_rows is None
            else take_rows(flat)).reshape(b, q, s, w)
    rows = jnp.where(mask[None, :, :, None], rows, jnp.uint32(0))
    return jax.lax.reduce(rows, jnp.uint32(0), jax.lax.bitwise_or, (2,))


def sigma_counts_program(visited, seeds, mask, num_colors: int,
                         all_reduce=None, take_rows=None):
    """Covered-color counts per query slot: (Q,) int32.

    Trace-time program (callers jit).  ``all_reduce`` merges per-shard
    partial counts when the batch dim is sharded — one collective per flush,
    bit-identical to single-device because the reduction is integer.  With
    vertex rows ALSO sharded, pass ``take_rows`` (see `_union_rows`) and
    keep ``all_reduce`` over the batch axis only: the merged covered mask
    is replicated across model shards, so reducing over both axes would
    overcount M×.
    """
    tail = jnp.asarray(bitmask.color_tail_mask(num_colors))
    covered = _union_rows(visited, seeds, mask, take_rows) \
        & tail[None, None, :]
    counts = jnp.sum(bitmask.popcount(covered), axis=(0, 2)).astype(jnp.int32)
    return all_reduce(counts) if all_reduce is not None else counts


def marginal_counts_program(visited, excl_seeds, excl_mask, num_colors: int,
                            use_kernel: bool, all_reduce=None,
                            take_rows=None, embed_counts=None):
    """Per-vertex marginal-gain counts per exclusion slot: (Q, V) int32.

    Trace-time program (callers jit); ``all_reduce`` as in
    ``sigma_counts_program``.  With vertex rows sharded, ``take_rows``
    gathers the exclusion rows globally and ``embed_counts`` places each
    shard's (V_loc,) local gains at its row offset in the padded (Vp,)
    vector BEFORE ``all_reduce`` — which then psums over data AND model
    (offsets are disjoint, so the sum is exact and the (Q, Vp) result
    replicated; callers slice ``[:, :num_vertices]``).
    """
    tail = jnp.asarray(bitmask.color_tail_mask(num_colors))
    active = tail[None, None, :] & ~_union_rows(visited, excl_seeds,
                                                excl_mask,
                                                take_rows)     # (B, Q, W)
    count = (ops.cover_counts_batched if use_kernel
             else imm._count_fn(False))
    embed = embed_counts if embed_counts is not None else (lambda x: x)
    counts = jax.lax.map(lambda act: embed(count(visited, act).sum(0)),
                         jnp.swapaxes(active, 0, 1))           # (Q, V)
    return all_reduce(counts) if all_reduce is not None else counts


_sigma_counts = jax.jit(sigma_counts_program,
                        static_argnames=("num_colors",))
_marginal_counts = jax.jit(marginal_counts_program,
                           static_argnames=("num_colors", "use_kernel"))


class QueryEngine:
    """Static-shape query programs bound to one `SketchStore`."""

    def __init__(self, store: sketch_store.SketchStore, *,
                 query_slots: int = 8, max_seeds: int = 8,
                 use_kernel: bool = True):
        self.store = store
        self.query_slots = query_slots
        self.max_seeds = max_seeds
        self.use_kernel = use_kernel

    @property
    def _n(self) -> int:
        return self.store.graph.num_vertices

    @property
    def _theta(self) -> int:
        return self.store.num_samples

    # -------------------------------------------------------------- top-k
    def top_k(self, k: int) -> tuple[np.ndarray, float]:
        """Greedy seed selection over the pool: (seeds (k,), σ estimate)."""
        seeds, cov = imm.greedy_max_cover(
            self.store.visited_stack(), k, self.store.num_colors,
            use_kernel=self.use_kernel)
        return _frozen(seeds), cov * self._n

    # --------------------------------------------------------------- σ(S)
    def sigma_padded(self, seeds: jnp.ndarray, mask: jnp.ndarray) -> np.ndarray:
        """σ estimates for pre-padded (Q, S) queries (one device dispatch)."""
        counts = _sigma_counts(self.store.visited_stack(), seeds, mask,
                               self.store.num_colors)
        return _frozen(np.asarray(counts, np.float64) * self._n / self._theta)

    def sigma(self, seed_sets) -> np.ndarray:
        """Convenience: σ(S) for ≤ ``query_slots`` ragged seed sets."""
        seeds, mask = pad_queries(seed_sets, self.query_slots, self.max_seeds)
        return self.sigma_padded(seeds, mask)[:len(seed_sets)]

    # ----------------------------------------------------- marginal gains
    def marginal_padded(self, excl_seeds: jnp.ndarray,
                        excl_mask: jnp.ndarray) -> np.ndarray:
        """(Q, V) per-vertex Δσ(v | X) for pre-padded exclusion sets."""
        counts = _marginal_counts(self.store.visited_stack(), excl_seeds,
                                  excl_mask, self.store.num_colors,
                                  self.use_kernel)
        return _frozen(np.asarray(counts, np.float64) * self._n / self._theta)

    def marginal_gains(self, exclude) -> np.ndarray:
        """(V,) per-vertex marginal influence gain given exclusions.

        Vertices already in ``exclude`` naturally score ~0: their colors are
        stripped from the active mask.
        """
        seeds, mask = pad_queries([exclude], self.query_slots, self.max_seeds)
        return self.marginal_padded(seeds, mask)[0]

    def best_extension(self, exclude, num: int = 1) -> np.ndarray:
        """Resume greedy selection after ``exclude`` via the shared
        incremental kernel — exact marginal-gain argmax, not a rescore."""
        visited = self.store.visited_stack()
        active = imm.initial_active(visited.shape[0], self.store.num_colors)
        for s in exclude:
            active = active & ~visited[:, int(s), :]
        seeds, _, _ = imm.greedy_extend(visited, active, num,
                                        use_kernel=self.use_kernel)
        return np.asarray(seeds)
