"""Online influence-query serving: persistent RRR sketch store + engine.

Fused BPTs make RRR sampling cheap; this package makes the samples a
long-lived, queryable asset instead of a throwaway (DiFuseR-style sketch
reuse).  Lifecycle: **sample** a pool of columnar ``(V, W)`` bitmask batches
under a device-memory budget → **serve** top-k / σ(S) / marginal-gain
queries against it → **refresh** stale batches epoch by epoch → **persist**
and restore through the checkpoint manifest format.

    store   = SketchStore(graph, PoolConfig(num_colors=64, max_batches=32))
    store.ensure(16)                          # sample 16 fused batches
    engine  = QueryEngine(store)
    batcher = MicroBatcher(engine, cache=ResultCache())
    t0 = batcher.submit_top_k(8)
    t1 = batcher.submit_sigma([3, 17, 42])
    t2 = batcher.submit_marginal(exclude=[3, 17])
    results = batcher.flush()                 # one padded device dispatch/kind
"""
from repro.sampling import SamplerSpec
from repro.serve.influence.batcher import FlushError, MicroBatcher
from repro.serve.influence.cache import ResultCache
from repro.serve.influence.engine import QueryEngine
from repro.serve.influence.sketch_store import PoolConfig, SketchStore

__all__ = ["FlushError", "MicroBatcher", "PoolConfig", "QueryEngine",
           "ResultCache", "SamplerSpec", "SketchStore"]
