"""Persistent pool of fused-BPT RRR sketch batches.

The store owns a device-resident collection of columnar ``(V, W)`` RRR
bitmask batches (`core.rrr.RRRBatch`) sampled on the reversed graph, under a
device-memory budget.  It implements the sketch-pool protocol that
``core.imm.run_imm`` / ``estimate_theta`` consume (``num_colors``,
``master_seed``, ``ensure``), so offline IMM and the online
`engine.QueryEngine` share one sampled asset.

Sampling routes through the `repro.sampling` facade: ``PoolConfig.spec`` is
a typed, frozen `SamplerSpec` (diffusion × backend + knobs) and the store
builds one `Sampler` from it — the same spec serves IC and LT pools, dense
and tiled/kernel expansion, and (in the sharded subclass) shard_map
data-parallel and graph-parallel pool builds.  (The deprecated untyped
``sample_kw`` dict, which warned since the Sampler-API PR, is gone — pass
``spec=SamplerSpec(...)``.)

Freshness is tracked per batch with an **epoch** tag: ``refresh()`` bumps
the store epoch and resamples the oldest batches with brand-new batch
indices (hence new RNG streams — never a repeat of a retired sample);
``shrink()`` bumps it too, so ``version`` (``(epoch, count)``, the
result-cache key) is never re-issued by a shrink→grow cycle.  Any
mutation changes ``version``.

Persistence rides the checkpoint manifest format (`checkpoint.manager`):
``save()`` writes an atomic ``step_<N>/{manifest.json, leaf_*.npy}``
snapshot of the pool tensors + counters, with the `SamplerSpec` recorded in
the manifest ``extra``; ``SketchStore.restore`` rebuilds a bit-identical
pool (uint32 masks round-trip exactly through ``.npy``) and REFUSES a
diffusion mismatch — a pool sampled under IC is never silently served as
LT or vice versa.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager
from repro.core import bitmask, rrr
from repro.graph import csr
from repro.sampling import SamplerSpec, resolve_spec


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Sizing + sampling knobs for a sketch pool.  Frozen AND fully
    immutable (every field hashable), so a config can key jit caches.

    ``memory_budget_mb`` (when set) caps ``max_batches`` by the device bytes
    of one ``(V, W)`` uint32 batch — the pool never allocates past it.

    ``spec`` types the sampling configuration; after ``__post_init__`` it is
    always a resolved `SamplerSpec` (the default is dense IC built from
    ``num_colors``/``master_seed``, which default to 64/0 when unset).
    When an explicit spec is given, ``num_colors``/``master_seed`` are
    adopted from it, and an explicitly-set value that disagrees with the
    spec raises (``sampling.resolve_spec`` — the ``None`` field defaults
    make "explicitly set" detectable).
    """
    num_colors: int | None = None
    max_batches: int = 64
    memory_budget_mb: float | None = None
    master_seed: int | None = None
    spec: SamplerSpec | None = None

    def __post_init__(self):
        spec = resolve_spec(self.spec,
                            num_colors=self.num_colors,
                            master_seed=self.master_seed)
        object.__setattr__(self, "num_colors", spec.num_colors)
        object.__setattr__(self, "master_seed", spec.master_seed)
        object.__setattr__(self, "spec", spec)

    def with_master_seed(self, master_seed: int) -> "PoolConfig":
        """Config with ``master_seed`` replaced consistently in the spec
        too (restore adopts a snapshot's seed this way)."""
        return dataclasses.replace(
            self, master_seed=master_seed,
            spec=self.spec.replace(master_seed=master_seed))


@partial(jax.jit, donate_argnums=(0,))
def _set_slots(stack: jnp.ndarray, slots: jnp.ndarray,
               masks: jnp.ndarray) -> jnp.ndarray:
    """``stack[slots] = masks`` with the stack buffer DONATED — a refresh
    rewrites the touched slots in the existing pool allocation instead of
    re-staging the whole ``(B, V, W)`` stack (sharded stacks keep their
    sharding: the scatter only writes the owning shards' slot blocks)."""
    return stack.at[slots].set(masks)


class SketchStore:
    """Epoch-tagged, budgeted, persistable pool of RRR sketch batches."""

    # Where a restored mask lives.  The sharded subclass stages masks to
    # host (its device residency is the assembled per-shard stack, so a
    # restore must never transit the whole pool through one device).
    _mask_array = staticmethod(jnp.asarray)

    def __init__(self, g: csr.Graph, config: PoolConfig | None = None, *,
                 g_rev: csr.Graph | None = None):
        self.graph = g
        self.config = config if config is not None else PoolConfig()
        self.sampler = self._make_sampler(g, self.config.spec, g_rev)
        # The sampler owns graph reversal (and LT weight normalization).
        self.g_rev = self.sampler.g_rev
        self.epoch = 0
        self.graph_epoch = 0
        self.next_batch_index = 0
        self.batches: list[rrr.RRRBatch] = []
        self.batch_epochs: list[int] = []
        self._stack: jnp.ndarray | None = None

    def _make_sampler(self, g: csr.Graph, spec: SamplerSpec,
                      g_rev: csr.Graph | None):
        """Subclass hook — the sharded store passes its mesh here."""
        from repro import sampling
        return sampling.make_sampler(g, spec, g_rev=g_rev)

    # ------------------------------------------------------------- sizing
    @property
    def spec(self) -> SamplerSpec:
        return self.config.spec

    @property
    def num_colors(self) -> int:
        return self.config.num_colors

    @property
    def master_seed(self) -> int:
        return self.config.master_seed

    @property
    def bytes_per_batch(self) -> int:
        w = bitmask.num_words(self.config.num_colors)
        return self.graph.num_vertices * w * 4

    @property
    def capacity(self) -> int:
        """Max batches the budget admits (≥ 1 so the pool is never unusable)."""
        cap = self.config.max_batches
        if self.config.memory_budget_mb is not None:
            cap = min(cap, int(self.config.memory_budget_mb * 2 ** 20
                               // self.bytes_per_batch))
        return max(cap, 1)

    @property
    def num_samples(self) -> int:
        return len(self.batches) * self.config.num_colors

    @property
    def version(self) -> tuple[int, int, int]:
        """Cache key: changes on a graph delta, on refresh, AND on pool
        growth.  The leading graph-epoch component makes results computed
        on different topologies un-mixable (`router.EpochMixError`) and
        un-cacheable across a `repro.stream` delta even though slot count
        and refresh epoch look unchanged."""
        return (self.graph_epoch, self.epoch, len(self.batches))

    # ----------------------------------------------------------- sampling
    def _sample_block(self, batch_indices: list[int]) -> list[rrr.RRRBatch]:
        """Sample a block of batch indices through the store's sampler —
        ONE facade call, so block-capable backends (data_parallel) build
        every slot in parallel instead of one batch at a time."""
        return self.sampler.sample_many(batch_indices)

    def _take_indices(self, count: int) -> list[int]:
        """Allocate ``count`` never-before-used batch indices (RNG streams)."""
        idx = list(range(self.next_batch_index, self.next_batch_index + count))
        self.next_batch_index += count
        return idx

    def ensure(self, num_batches: int) -> list[rrr.RRRBatch]:
        """Grow the pool to ≥ ``num_batches`` (clamped to capacity).

        Sketch-pool protocol entry point for ``core.imm``; returns the live
        batch list (callers must not mutate it).
        """
        want = min(num_batches, self.capacity)
        missing = want - len(self.batches)
        if missing > 0:
            new = self._sample_block(self._take_indices(missing))
            for b in new:
                self.batches.append(b)
                self.batch_epochs.append(self.epoch)
            self._extend_stack(new)
        return self.batches

    def shrink(self, num_batches: int) -> list[int]:
        """Drop the highest slots down to ``num_batches`` (floor 1); returns
        the dropped slots.  The slot *prefix* is kept, so offline IMM's
        first-⌈θ/colors⌉-slots selection stays meaningful and replicas that
        apply the same shrink stay bit-identical.  The cached stack is
        sliced in place (no resample, no host re-staging).

        A shrink that drops anything bumps the store epoch: ``version`` is
        ``(epoch, count)`` and a later grow back to the same count samples
        NEW batch indices into the re-added slots, so without the bump a
        shrink→grow cycle would re-issue a previously-seen version and
        epoch-keyed result caches would serve stale answers against the
        new pool contents (the autoscaler's normal oscillation pattern).
        Within one epoch the count only grows, so ``(epoch, count)`` can
        never repeat.
        """
        keep = max(1, min(int(num_batches), len(self.batches)))
        dropped = list(range(keep, len(self.batches)))
        if not dropped:
            return dropped
        self.epoch += 1
        self.batches = self.batches[:keep]
        self.batch_epochs = self.batch_epochs[:keep]
        self._truncate_stack(keep)
        return dropped

    def clone(self) -> "SketchStore":
        """A replica pool sharing this store's (immutable) batches.

        The clone has its own sampler, stack cache, and counters, so later
        ``ensure``/``refresh``/``shrink`` on either store are independent —
        but because slot ``i`` is a pure function of ``(graph, master_seed,
        batch_index)`` and both stores continue from the same
        ``next_batch_index``, applying the *same* mutation sequence to every
        clone keeps them bit-identical (the serving tier's replica-group
        invariant).  No resampling: batch masks are shared references
        (RRR batches are never mutated in place).
        """
        c = self._clone_empty()
        c.epoch = self.epoch
        c.graph_epoch = self.graph_epoch
        c.next_batch_index = self.next_batch_index
        c.batches = list(self.batches)
        c.batch_epochs = list(self.batch_epochs)
        return c

    def _clone_empty(self) -> "SketchStore":
        """Subclass hook: a fresh store with this store's graph + config
        (the sharded subclass threads its mesh through)."""
        return type(self)(self.graph, self.config, g_rev=self.g_rev)

    def _extend_stack(self, new_batches: list[rrr.RRRBatch]) -> None:
        """Append newly-sampled slots to the cached stack without
        re-staging the existing allocation (a tier scale-up event must not
        cold-rebuild the pool).  No-op while the stack is unbuilt."""
        if self._stack is None:
            return
        masks = jnp.stack([jnp.asarray(b.visited) for b in new_batches])
        self._stack = jnp.concatenate([self._stack, masks])

    def _truncate_stack(self, keep: int) -> None:
        """Slice the cached stack to the kept slot prefix (device-side)."""
        if self._stack is not None:
            self._stack = self._stack[:keep]

    def visited_stack(self) -> jnp.ndarray:
        """(B, V, W) stacked masks for the query engine (cached per version)."""
        if not self.batches:
            raise ValueError("empty pool — call ensure() first")
        if self._stack is None:
            self._stack = rrr.stack_visited(self.batches)
        return self._stack

    # ------------------------------------------------------------ refresh
    def _update_stack(self, slots: list[int],
                      new_batches: list[rrr.RRRBatch]) -> None:
        """Write refreshed slots into the cached stack IN PLACE (donated
        buffer — `_set_slots`).  A refresh never changes the pool's shape,
        so the existing ``(B, V, W)`` allocation (and, in the sharded
        subclass, its per-device placement) is reused; only the touched
        slots transit a device.  No-op while the stack is unbuilt (lazy).

        Donation contract: the previously-returned ``visited_stack()``
        array object is consumed — consumers must re-fetch per query (the
        query engines already do).
        """
        if self._stack is None:
            return
        masks = jnp.stack([jnp.asarray(b.visited) for b in new_batches])
        self._stack = _set_slots(self._stack,
                                 jnp.asarray(slots, jnp.int32), masks)

    def refresh(self, fraction: float = 0.25) -> list[int]:
        """Resample the oldest-epoch batches with fresh RNG streams.

        Bumps the store epoch, then replaces ``ceil(fraction · B)`` batches
        (oldest epoch tag first, lowest slot on ties) with new samples drawn
        at never-before-used batch indices.  Returns the replaced slots.
        The cached visited stack is updated in place (`_update_stack`) —
        a refresh reuses the pool allocation instead of re-staging it.
        """
        if not self.batches:
            return []
        self.epoch += 1
        count = min(len(self.batches),
                    max(1, math.ceil(fraction * len(self.batches))))
        order = sorted(range(len(self.batches)),
                       key=lambda i: (self.batch_epochs[i], i))
        slots = order[:count]
        new = self._sample_block(self._take_indices(count))
        for i, b in zip(slots, new):
            self.batches[i] = b
            self.batch_epochs[i] = self.epoch
        self._update_stack(slots, new)
        return slots

    # ---------------------------------------------------- streaming deltas
    def apply_graph_update(self, g: csr.Graph, g_rev: csr.Graph,
                           touched_row_blocks=None) -> None:
        """Swap in a mutated graph pair (`repro.stream.apply_delta` output)
        and bump the graph epoch.

        For the streaming path the graphs are delta-applied descendants of
        the current pair — CSR edge ids stable, the reversed graph
        maintained by applying the reversed delta (NOT `csr.transpose`,
        which renumbers).  The sampler is REBOUND (`Sampler.rebind`): a
        values-only delta that names its ``touched_row_blocks`` patches
        the sampler's per-graph indexes in place (churn-priced), anything
        structural rebuilds them.  Existing batches keep their recorded
        RNG streams, so `resample_slots` can re-derive any slot on the new
        topology while clean slots stay bit-identical.

        The other caller is `stream.compact` — a rebuilt (renumbered!)
        graph pair is fine too because rebind detects the structural
        change and rebuilds, but then EVERY slot must be resampled (edge
        ids moved, so every slot's bits are suspect), which the compaction
        path does.

        ``g_rev`` must already carry the LT normalization invariant when
        the pool is LT (`stream.apply_delta(..., lt_normalized=True)`
        maintains it): the sampler re-runs `lt.normalize_lt_weights`,
        which is idempotent — order-preserving and a no-op on normalized
        weights — so the ids AND bits both survive.
        """
        self.graph = g
        self.sampler = self.sampler.rebind(g, g_rev, touched_row_blocks)
        self.g_rev = self.sampler.g_rev
        self.graph_epoch += 1

    def resample_slots(self, slots: list[int]) -> list[rrr.RRRBatch]:
        """Re-derive the given slots from their RECORDED RNG streams on
        the current graph (the incremental-refresh write path).

        Unlike `refresh` this allocates no new batch indices and bumps no
        epoch — slot ``i`` stays the pure function ``(graph, master_seed,
        batch_index_i)``, so after a graph delta the resampled slots match
        a cold rebuild of the same indices bit-for-bit, and replicas that
        apply the same delta + resample stay identical.  The cached stack
        is updated in place through the donated `_set_slots` scatter.
        """
        if not slots:
            return []
        new = self._sample_block([self.batches[i].batch_index
                                  for i in slots])
        for i, b in zip(slots, new):
            self.batches[i] = b
        self._update_stack(slots, new)
        return new

    # -------------------------------------------------------- persistence
    def _tree(self) -> dict[str, Any]:
        return {
            "visited": np.stack([np.asarray(b.visited) for b in self.batches]),
            "roots": np.stack([b.roots for b in self.batches]),
            "batch_indices": np.asarray(
                [b.batch_index for b in self.batches], np.int64),
            "batch_epochs": np.asarray(self.batch_epochs, np.int64),
            "edge_visits": np.asarray(
                [[b.fused_edge_visits, b.unfused_edge_visits]
                 for b in self.batches], np.int64),
            "counters": np.asarray(
                [self.epoch, self.next_batch_index,
                 self.config.master_seed, self.config.num_colors,
                 self.graph_epoch], np.int64),
        }

    def _manifest_extra(self) -> dict:
        """Manifest ``extra`` metadata — the `SamplerSpec` always rides
        along so restore can refuse a diffusion mismatch."""
        return {"kind": "sketch_pool",
                "sampler_spec": self.config.spec.to_manifest()}

    def save(self, directory: str, *, keep: int = 3) -> None:
        """Atomic manifest snapshot; step number = store epoch."""
        manager.save(directory, self.epoch, self._tree(), keep=keep,
                     extra=self._manifest_extra())

    @classmethod
    def _resolve_snapshot(cls, directory: str, step: int | None):
        """(step, manifest) of the latest (or given) snapshot — read ONCE;
        restore paths that need the manifest early pass it back down."""
        step = step if step is not None else manager.latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no sketch-pool snapshot in {directory}")
        return step, manager.read_manifest(directory, step)

    @classmethod
    def _restored_fields(cls, directory: str, config: PoolConfig,
                         step: int | None, manifest: dict | None = None):
        """(config, epoch, next_batch_index, batches, batch_epochs,
        graph_epoch) of a snapshot.  Leaves load as host numpy; each mask
        is placed via ``cls._mask_array``, so the whole pool never
        transits one device unless the subclass wants it to."""
        if manifest is None:
            step, manifest = cls._resolve_snapshot(directory, step)
        saved_spec = manifest.get("extra", {}).get("sampler_spec")
        if saved_spec is not None:
            saved = SamplerSpec.from_manifest(saved_spec)
            if saved.diffusion != config.spec.diffusion:
                raise ValueError(
                    f"snapshot was sampled under diffusion "
                    f"{saved.diffusion!r} but the restore config requests "
                    f"{config.spec.diffusion!r} — an IC pool must never be "
                    "silently served as LT (or vice versa); restore with a "
                    "matching SamplerSpec")
        target = {e["path"]: np.zeros(e["shape"], manager._np_dtype(e["dtype"]))
                  for e in manifest["leaves"]}
        tree, _ = manager.restore(directory, target, step, as_numpy=True)
        counters = np.asarray(tree["counters"])
        if int(counters[3]) != config.num_colors:
            raise ValueError(f"snapshot colors {int(counters[3])} != "
                             f"config {config.num_colors}")
        config = config.with_master_seed(int(counters[2]))
        visited = np.asarray(tree["visited"])
        roots = np.asarray(tree["roots"])
        indices = np.asarray(tree["batch_indices"])
        visits = np.asarray(tree["edge_visits"])
        batches = [
            rrr.RRRBatch(cls._mask_array(visited[i]), roots[i],
                         int(indices[i]), int(visits[i, 0]),
                         int(visits[i, 1]))
            for i in range(visited.shape[0])]
        epochs = [int(e) for e in np.asarray(tree["batch_epochs"])]
        # Pre-streaming snapshots carry 4 counters (no graph epoch): 0.
        graph_epoch = int(counters[4]) if counters.shape[0] > 4 else 0
        return (config, int(counters[0]), int(counters[1]), batches, epochs,
                graph_epoch)

    @classmethod
    def restore(cls, directory: str, g: csr.Graph,
                config: PoolConfig | None = None, *,
                step: int | None = None,
                g_rev: csr.Graph | None = None) -> "SketchStore":
        """Rebuild a bit-identical pool from the latest (or given) snapshot."""
        config, epoch, nbi, batches, epochs, gepoch = cls._restored_fields(
            directory, config if config is not None else PoolConfig(), step)
        store = cls(g, config, g_rev=g_rev)
        store.epoch = epoch
        store.graph_epoch = gepoch
        store.next_batch_index = nbi
        store.batches = batches
        store.batch_epochs = epochs
        return store
