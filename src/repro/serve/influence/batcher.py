"""Micro-batcher: many concurrent callers, one device dispatch per kind.

Callers ``submit_*`` queries and later ``flush()``; the batcher resolves
cache hits host-side, packs the remaining σ(S)/marginal queries into the
engine's fixed ``(query_slots, max_seeds)`` tensors (chunking when a flush
overflows the slots — every chunk reuses the same compiled program), runs
one dispatch per query kind, and fans results back out by ticket.

**Thread safety.**  Submits and flushes may come from any thread: ticket
allocation, the pending list, the dispatch counter, and every result-cache
access are guarded by one internal lock.  ``flush()`` swaps the pending
list out under the lock and runs the device dispatches *outside* it, so
callers keep submitting (into the next batch) while a flush is on device.
The ``ResultCache`` carries its own lock and an atomic ``stats()``
snapshot, so observers (e.g. the serving tier's metrics exporter) may read
it concurrently; *writes* still route through the owning batcher.

**Deadlines.**  ``submit_*(..., deadline=s)`` tags the request "dispatch
within ``s`` seconds"; the batcher never flushes by itself, but exposes
``oldest_deadline()`` / ``pending_count`` so a driver (e.g.
`repro.serve.distributed.frontend.AsyncFrontEnd`) can flush on *full slot
or oldest deadline, whichever first* — a lone request is never stuck
waiting for a slot to fill.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

from repro.serve.influence import cache as cache_lib
from repro.serve.influence import engine as engine_lib

TOP_K, SIGMA, MARGINAL = "top_k", "sigma", "marginal"


@dataclasses.dataclass(frozen=True)
class _Pending:
    ticket: int
    kind: str
    key: tuple          # canonical cache key
    seeds: tuple        # seed / exclusion set as submitted (deduped, sorted)
    deadline: float | None = None   # absolute time.monotonic() dispatch-by


class FlushError(RuntimeError):
    """A device dispatch failed mid-flush.

    ``tickets`` lists only the tickets left *unanswered* — queries resolved
    before the failure (cache hits, earlier successful dispatch kinds in
    the same flush) sit in ``partial`` and should be delivered normally.
    Tickets submitted after the flush swapped its pending set are in
    neither: they are still queued for the next flush.
    """

    def __init__(self, tickets, partial: dict, cause: BaseException):
        super().__init__(f"influence-query flush failed: {cause!r}")
        self.tickets = tuple(tickets)
        self.partial = partial
        self.__cause__ = cause


class MicroBatcher:
    """Pads concurrent influence queries into slotted batch dispatches."""

    def __init__(self, engine, cache: cache_lib.ResultCache | None = None):
        self.engine = engine
        self.cache = cache
        self._lock = threading.RLock()
        self._pending: list[_Pending] = []
        self._next_ticket = 0
        self.dispatches = 0         # device dispatches issued (observability)

    # ------------------------------------------------------------- submit
    def _submit(self, kind: str, key: tuple, seeds: tuple,
                deadline: float | None) -> int:
        dl = None if deadline is None else time.monotonic() + deadline
        with self._lock:
            t = self._next_ticket
            self._next_ticket += 1
            self._pending.append(_Pending(t, kind, key, seeds, dl))
        return t

    def submit_top_k(self, k: int, *, deadline: float | None = None) -> int:
        return self._submit(TOP_K, (int(k),), (int(k),), deadline)

    def _checked_key(self, seeds) -> tuple:
        """Canonicalize + validate at submit time: an oversized seed set
        must fail on the offending caller, never abort a shared flush."""
        key = cache_lib.seed_key(seeds)
        if len(key) > self.engine.max_seeds:
            raise ValueError(f"seed set of {len(key)} > "
                             f"max_seeds={self.engine.max_seeds}")
        return key

    def submit_sigma(self, seed_set, *, deadline: float | None = None) -> int:
        key = self._checked_key(seed_set)
        return self._submit(SIGMA, key, key, deadline)

    def submit_marginal(self, exclude, *,
                        deadline: float | None = None) -> int:
        key = self._checked_key(exclude)
        return self._submit(MARGINAL, key, key, deadline)

    # -------------------------------------------------------- observation
    @property
    def pending_count(self) -> int:
        with self._lock:
            return len(self._pending)

    def oldest_deadline(self) -> float | None:
        """Earliest absolute dispatch-by time among pending queries (None
        when nothing pending carries a deadline)."""
        with self._lock:
            dls = [p.deadline for p in self._pending if p.deadline is not None]
        return min(dls) if dls else None

    # -------------------------------------------------------------- flush
    def _lookup(self, p: _Pending, version):
        if self.cache is None:
            return None
        return self.cache.get(version, p.kind, p.key)

    def _store(self, p: _Pending, value, version) -> None:
        if self.cache is not None:
            self.cache.put(version, p.kind, p.key, value)

    def flush(self) -> dict[int, Any]:
        """Answer every pending query; returns {ticket: result}.

        Results: top-k → (seeds, σ estimate); sigma → float; marginal →
        (V,) gain vector.  Identical queries in one flush share a slot.
        Device dispatches run outside the lock; submits landing during a
        flush join the *next* one.

        A dispatch failure raises `FlushError` carrying the results already
        computed (``partial``) and naming exactly the still-unanswered
        tickets; later submits are untouched and stay pending.  A driver
        delivers the partials and fails precisely the named callers.
        """
        with self._lock:
            pending, self._pending = self._pending, []
            # Snapshot the pool version with the batch: results are tagged
            # with the version they were *requested* under, so a refresh
            # landing mid-dispatch can only make these entries stale
            # (miss + recompute later), never poison the cache with an
            # old answer filed under the new version.
            version = self.engine.store.version
        results: dict[int, Any] = {}
        try:
            self._flush(pending, results, version)
        except Exception as e:              # noqa: BLE001 — annotate + rethrow
            unanswered = [p.ticket for p in pending
                          if p.ticket not in results]
            raise FlushError(unanswered, results, e) from e
        return results

    def _flush(self, pending: list[_Pending], results: dict[int, Any],
               version) -> None:
        todo: dict[str, dict[tuple, list[_Pending]]] = {}
        with self._lock:
            for p in pending:
                hit = self._lookup(p, version)
                if hit is not None:
                    results[p.ticket] = hit
                else:
                    todo.setdefault(p.kind, {}).setdefault(p.key, []).append(p)

        for key, ps in todo.get(TOP_K, {}).items():
            value = self.engine.top_k(key[0])
            with self._lock:
                self.dispatches += 1
                self._store(ps[0], value, version)
                for p in ps:
                    results[p.ticket] = value

        for kind, run in ((SIGMA, self._run_sigma),
                          (MARGINAL, self._run_marginal)):
            groups = list(todo.get(kind, {}).items())
            slots = self.engine.query_slots
            for i in range(0, len(groups), slots):
                chunk = groups[i:i + slots]
                values = run([ps[0].seeds for _, ps in chunk])
                with self._lock:
                    self.dispatches += 1
                    for (key, ps), value in zip(chunk, values):
                        self._store(ps[0], value, version)
                        for p in ps:
                            results[p.ticket] = value

    def _run_sigma(self, seed_sets):
        return list(self.engine.sigma(seed_sets))

    def _run_marginal(self, excl_sets):
        seeds, mask = engine_lib.pad_queries(
            excl_sets, self.engine.query_slots, self.engine.max_seeds)
        gains = self.engine.marginal_padded(seeds, mask)
        return [gains[q] for q in range(len(excl_sets))]
