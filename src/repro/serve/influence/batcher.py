"""Micro-batcher: many concurrent callers, one device dispatch per kind.

Callers ``submit_*`` queries and later ``flush()``; the batcher resolves
cache hits host-side, packs the remaining σ(S)/marginal queries into the
engine's fixed ``(query_slots, max_seeds)`` tensors (chunking when a flush
overflows the slots — every chunk reuses the same compiled program), runs
one dispatch per query kind, and fans results back out by ticket.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.serve.influence import cache as cache_lib
from repro.serve.influence import engine as engine_lib

TOP_K, SIGMA, MARGINAL = "top_k", "sigma", "marginal"


@dataclasses.dataclass(frozen=True)
class _Pending:
    ticket: int
    kind: str
    key: tuple          # canonical cache key
    seeds: tuple        # seed / exclusion set as submitted (deduped, sorted)


class MicroBatcher:
    """Pads concurrent influence queries into slotted batch dispatches."""

    def __init__(self, engine: engine_lib.QueryEngine,
                 cache: cache_lib.ResultCache | None = None):
        self.engine = engine
        self.cache = cache
        self._pending: list[_Pending] = []
        self._next_ticket = 0
        self.dispatches = 0         # device dispatches issued (observability)

    # ------------------------------------------------------------- submit
    def _submit(self, kind: str, key: tuple, seeds: tuple) -> int:
        t = self._next_ticket
        self._next_ticket += 1
        self._pending.append(_Pending(t, kind, key, seeds))
        return t

    def submit_top_k(self, k: int) -> int:
        return self._submit(TOP_K, (int(k),), (int(k),))

    def _checked_key(self, seeds) -> tuple:
        """Canonicalize + validate at submit time: an oversized seed set
        must fail on the offending caller, never abort a shared flush."""
        key = cache_lib.seed_key(seeds)
        if len(key) > self.engine.max_seeds:
            raise ValueError(f"seed set of {len(key)} > "
                             f"max_seeds={self.engine.max_seeds}")
        return key

    def submit_sigma(self, seed_set) -> int:
        key = self._checked_key(seed_set)
        return self._submit(SIGMA, key, key)

    def submit_marginal(self, exclude) -> int:
        key = self._checked_key(exclude)
        return self._submit(MARGINAL, key, key)

    # -------------------------------------------------------------- flush
    def _lookup(self, p: _Pending):
        if self.cache is None:
            return None
        return self.cache.get(self.engine.store.version, p.kind, p.key)

    def _store(self, p: _Pending, value) -> None:
        if self.cache is not None:
            self.cache.put(self.engine.store.version, p.kind, p.key, value)

    def flush(self) -> dict[int, Any]:
        """Answer every pending query; returns {ticket: result}.

        Results: top-k → (seeds, σ estimate); sigma → float; marginal →
        (V,) gain vector.  Identical queries in one flush share a slot.
        """
        pending, self._pending = self._pending, []
        results: dict[int, Any] = {}
        todo: dict[str, dict[tuple, list[_Pending]]] = {}
        for p in pending:
            hit = self._lookup(p)
            if hit is not None:
                results[p.ticket] = hit
            else:
                todo.setdefault(p.kind, {}).setdefault(p.key, []).append(p)

        for key, ps in todo.get(TOP_K, {}).items():
            value = self.engine.top_k(key[0])
            self.dispatches += 1
            for p in ps:
                results[p.ticket] = value
            self._store(ps[0], value)

        for kind, run in ((SIGMA, self._run_sigma),
                          (MARGINAL, self._run_marginal)):
            groups = list(todo.get(kind, {}).items())
            slots = self.engine.query_slots
            for i in range(0, len(groups), slots):
                chunk = groups[i:i + slots]
                values = run([ps[0].seeds for _, ps in chunk])
                self.dispatches += 1
                for (key, ps), value in zip(chunk, values):
                    for p in ps:
                        results[p.ticket] = value
                    self._store(ps[0], value)
        return results

    def _run_sigma(self, seed_sets):
        return list(self.engine.sigma(seed_sets))

    def _run_marginal(self, excl_sets):
        seeds, mask = engine_lib.pad_queries(
            excl_sets, self.engine.query_slots, self.engine.max_seeds)
        gains = self.engine.marginal_padded(seeds, mask)
        return [gains[q] for q in range(len(excl_sets))]
