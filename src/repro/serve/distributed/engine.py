"""Distributed influence-query engine: local coverage + one collective.

Same query API as `repro.serve.influence.engine.QueryEngine` (so
`MicroBatcher` / `AsyncFrontEnd` drive either engine unchanged), but the
pool's slot dim is sharded over a mesh axis and every program runs under
``shard_map``:

* each device reduces coverage over **its local batches** with the shared
  count programs (`kernels.ops.cover_counts` / the popcount fallback);
* **one ``lax.psum``** merges the per-shard partial counts — the ButterFly
  BFS lesson: engineer exactly one deliberate collective per reduction;
* greedy selection (`core.imm.greedy_extend_program`) argmaxes on the
  *merged, replicated* counts, so every shard picks the same seed with no
  second collective, and each updates only its local active-mask slice.

When the store also shards VERTEX rows over the mesh's model axis
(`ShardedSketchStore.row_shards` > 1 — each device holds only its V/M row
slice of every local slot), the same programs run 2-D: per-vertex gain
counts are computed over the local row slice, embedded at the shard's row
offset, and merged with a psum over **data and model together** (disjoint
offsets make the sum exact); selected/seed visited rows come back through
one model-axis psum (rows are disjointly owned, so the integer sum IS the
row); and reductions over model-replicated state (the active mask, the
merged covered mask) name the data axis only.  The greedy argmax still
runs on merged, replicated counts — the vertex padding rows carry all-zero
masks and can never outscore a real vertex.

All reductions are integer, so the N-shard answer is **bit-identical** to
the 1-device `QueryEngine` on the same pool — asserted by
``tests/serve_distributed_check.py`` (including D×M row-sharded meshes).

``use_kernel`` defaults to the popcount fallback here: the Pallas coverage
kernel targets TPU lowering and both paths produce identical integer
counts (asserted by the kernel tests), so on CPU meshes the fallback is
the conservative choice; pass ``use_kernel=True`` on TPU pods.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import imm
from repro.distributed import compat
from repro.serve.distributed import sharded_store as store_lib
from repro.serve.influence import engine as engine_lib


class DistributedQueryEngine:
    """Static-shape shard_map query programs bound to one sharded store."""

    def __init__(self, store: store_lib.ShardedSketchStore, *,
                 query_slots: int = 8, max_seeds: int = 8,
                 use_kernel: bool = False):
        self.store = store
        self.query_slots = query_slots
        self.max_seeds = max_seeds
        self.use_kernel = use_kernel
        self._greedy_fns: dict[int, object] = {}
        self._sigma_fn = None
        self._marginal_fn = None

    @property
    def _n(self) -> int:
        return self.store.graph.num_vertices

    @property
    def _theta(self) -> int:
        return self.store.num_samples

    def _psum(self):
        return functools.partial(jax.lax.psum, axis_name=self.store.axis)

    def _row_layout(self):
        """``(row_axis, M, Vp, V_loc)`` — the pool's vertex-row sharding
        (``row_axis`` is None / M == 1 when rows are replicated)."""
        m = self.store.row_shards
        vp = self.store.padded_vertices
        return self.store.row_axis, m, vp, vp // m

    @staticmethod
    def _row_hooks(vis, row_axis: str, vp: int, vloc: int):
        """Trace-time helpers for a row-sharded ``vis`` (B_loc, V_loc, W).

        ``take(flat_global_ids) -> (B, n, W)`` — each shard contributes the
        rows it owns (others zero), one psum over ``row_axis`` merges; row
        ownership is disjoint so the integer sum IS the exact row, and the
        result is replicated across model shards.  ``embed(local_counts)``
        places a shard's (V_loc,) partial at its row offset in the global
        (Vp,) vector, so a psum over (data, model) yields exact merged
        counts — pad rows have all-zero masks, hence zero counts, and can
        never win the greedy argmax over a real vertex (ties break low).
        """
        off = jax.lax.axis_index(row_axis) * vloc
        psum_row = functools.partial(jax.lax.psum, axis_name=row_axis)

        def take(flat):
            loc = jnp.clip(flat - off, 0, vloc - 1)
            rows = jnp.take(vis, loc, axis=1)           # (B, n, W)
            ok = (flat >= off) & (flat < off + vloc)
            return psum_row(jnp.where(ok[None, :, None], rows,
                                      jnp.uint32(0)))

        def embed(counts):
            return jax.lax.dynamic_update_slice(
                jnp.zeros((vp,), counts.dtype), counts, (off,))

        return take, embed

    # ------------------------------------------------------ sharded state
    def _initial_active(self) -> jnp.ndarray:
        """(Bp, W) all-uncovered mask, pad slots zeroed, sharded P(axis).

        Zeroing pad rows keeps them out of every popcount: a pad slot has a
        zero visited mask AND a zero active mask, so it adds nothing to
        gain counts or to the uncovered total.
        """
        bp = self.store.padded_batches
        act = imm.initial_active(bp, self.store.num_colors)
        valid = (jnp.arange(bp) < len(self.store.batches))[:, None]
        act = jnp.where(valid, act, jnp.uint32(0))
        return jax.device_put(
            act, NamedSharding(self.store.mesh, P(self.store.axis)))

    # ----------------------------------------------------------- programs
    def _greedy(self, k: int):
        """jit(shard_map) greedy program for a fixed k (cached)."""
        fn = self._greedy_fns.get(k)
        if fn is None:
            axis, use_kernel = self.store.axis, self.use_kernel
            psum = self._psum()
            row_axis, m, vp, vloc = self._row_layout()

            if m > 1:
                # Row-sharded pool: local gains embedded at the shard's
                # row offset, ONE psum over (data × model) merges them
                # (disjoint offsets ⇒ exact), the argmax runs on the
                # replicated merged (Vp,) counts — same seed on every
                # shard, no second collective — and the winner's visited
                # row comes back via one model-axis psum.  The active
                # mask is replicated across model shards, so the
                # uncovered popcount reduces over data only.
                merge = functools.partial(jax.lax.psum,
                                          axis_name=(axis, row_axis))

                def body(vis, act):
                    take, embed = self._row_hooks(vis, row_axis, vp, vloc)
                    return imm.greedy_extend_program(
                        vis, act, k, use_kernel, all_reduce=merge,
                        embed_counts=embed,
                        fetch_row=lambda sel: take(sel[None])[:, 0, :],
                        final_reduce=psum)

                in_vis = P(axis, row_axis)
            else:
                def body(vis, act):
                    return imm.greedy_extend_program(vis, act, k, use_kernel,
                                                     all_reduce=psum)

                in_vis = P(axis)

            fn = jax.jit(compat.shard_map(
                body, self.store.mesh,
                in_specs=(in_vis, P(axis)),
                out_specs=(P(), P(axis), P())))
            self._greedy_fns[k] = fn
        return fn

    def _sigma(self):
        if self._sigma_fn is None:
            axis, nc = self.store.axis, self.store.num_colors
            psum = self._psum()
            row_axis, m, vp, vloc = self._row_layout()

            if m > 1:
                # Seed rows merge over model (disjoint ownership), the
                # covered mask is then model-replicated, so the count
                # reduction names the data axis only.
                def body(vis, seeds, mask):
                    take, _ = self._row_hooks(vis, row_axis, vp, vloc)
                    return engine_lib.sigma_counts_program(
                        vis, seeds, mask, nc, all_reduce=psum,
                        take_rows=take)

                in_vis = P(axis, row_axis)
            else:
                def body(vis, seeds, mask):
                    return engine_lib.sigma_counts_program(
                        vis, seeds, mask, nc, all_reduce=psum)

                in_vis = P(axis)

            self._sigma_fn = jax.jit(compat.shard_map(
                body, self.store.mesh,
                in_specs=(in_vis, P(), P()), out_specs=P()))
        return self._sigma_fn

    def _marginal(self):
        if self._marginal_fn is None:
            axis, nc = self.store.axis, self.store.num_colors
            use_kernel, psum = self.use_kernel, self._psum()
            row_axis, m, vp, vloc = self._row_layout()

            if m > 1:
                merge = functools.partial(jax.lax.psum,
                                          axis_name=(axis, row_axis))

                def body(vis, seeds, mask):
                    take, embed = self._row_hooks(vis, row_axis, vp, vloc)
                    return engine_lib.marginal_counts_program(
                        vis, seeds, mask, nc, use_kernel, all_reduce=merge,
                        take_rows=take, embed_counts=embed)

                in_vis = P(axis, row_axis)
            else:
                def body(vis, seeds, mask):
                    return engine_lib.marginal_counts_program(
                        vis, seeds, mask, nc, use_kernel, all_reduce=psum)

                in_vis = P(axis)

            self._marginal_fn = jax.jit(compat.shard_map(
                body, self.store.mesh,
                in_specs=(in_vis, P(), P()), out_specs=P()))
        return self._marginal_fn

    # -------------------------------------------------------------- top-k
    def top_k(self, k: int) -> tuple[np.ndarray, float]:
        """Greedy seed selection over the sharded pool: one program, one
        psum per greedy round."""
        seeds, _, uncovered = self._greedy(k)(self.store.visited_stack(),
                                              self._initial_active())
        theta = self._theta
        cov = (theta - int(uncovered)) / theta
        return engine_lib._frozen(np.asarray(seeds)), cov * self._n

    # --------------------------------------------------------------- σ(S)
    def sigma_padded(self, seeds: jnp.ndarray,
                     mask: jnp.ndarray) -> np.ndarray:
        counts = self._sigma()(self.store.visited_stack(), seeds, mask)
        return engine_lib._frozen(
            np.asarray(counts, np.float64) * self._n / self._theta)

    def sigma(self, seed_sets) -> np.ndarray:
        seeds, mask = engine_lib.pad_queries(seed_sets, self.query_slots,
                                             self.max_seeds)
        return self.sigma_padded(seeds, mask)[:len(seed_sets)]

    # ----------------------------------------------------- marginal gains
    def marginal_padded(self, excl_seeds: jnp.ndarray,
                        excl_mask: jnp.ndarray) -> np.ndarray:
        counts = self._marginal()(self.store.visited_stack(), excl_seeds,
                                  excl_mask)
        # Row-sharded pools count over (Q, Vp) — drop the vertex padding
        # (no-op when the stack carries exactly V rows).
        return engine_lib._frozen(
            np.asarray(counts, np.float64)[:, :self._n]
            * self._n / self._theta)

    def marginal_gains(self, exclude) -> np.ndarray:
        seeds, mask = engine_lib.pad_queries([exclude], self.query_slots,
                                             self.max_seeds)
        return self.marginal_padded(seeds, mask)[0]

    def best_extension(self, exclude, num: int = 1) -> np.ndarray:
        """Resume greedy selection after ``exclude`` — exact marginal-gain
        argmax through the same one-collective greedy program."""
        visited = self.store.visited_stack()
        active = self._initial_active()
        for s in exclude:
            active = active & ~visited[:, int(s), :]
        seeds, _, _ = self._greedy(num)(visited, active)
        return np.asarray(seeds)
