"""Async deadline-batched serving front-end.

Callers submit influence queries from any thread and get a
``concurrent.futures.Future``; a single dispatcher thread owns every
device dispatch.  A flush fires on whichever comes first:

* **full slot** — pending queries reach ``flush_slots`` (the engine's
  padded batch is full, dispatching now wastes nothing), or
* **deadline** — the *oldest* pending request's deadline arrives (a lone
  request is dispatched on time instead of waiting for company).

A background refresh worker (enabled with ``refresh_every``) resamples the
stalest ``refresh_fraction`` of the pool between dispatches.  Refresh and
flush serialize on one dispatch lock, and ``SketchStore.refresh`` bumps
the store version inside that critical section — so every flush sees a
consistent (stack, version) pair and the epoch-keyed ``ResultCache`` can
never serve a result computed under another epoch.

Works identically over a single-device ``QueryEngine`` or a
``DistributedQueryEngine`` — the front-end only talks to the batcher.

    engine  = DistributedQueryEngine(store)
    fe = AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=0.02, refresh_every=30.0)
    fut = fe.submit_sigma([3, 17, 42])          # any thread
    sigma = fut.result()
    fe.close()
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time

from repro.serve.influence import batcher as batcher_lib


@dataclasses.dataclass
class FrontEndStats:
    """Serving observability counters (read at any time; snapshot under the
    front-end's condition lock)."""
    flushes: int = 0
    slot_flushes: int = 0       # triggered by a full slot
    deadline_flushes: int = 0   # triggered by the oldest request's deadline
    drain_flushes: int = 0      # close() draining the tail
    served: int = 0
    refreshes: int = 0
    max_queue_wait: float = 0.0  # worst submit → dispatch-start wait (s)


class AsyncFrontEnd:
    """Thread-safe request queue + deadline-batched dispatcher thread."""

    def __init__(self, batcher, *, default_deadline: float = 0.05,
                 flush_slots: int | None = None,
                 refresh_every: float | None = None,
                 refresh_fraction: float = 0.25):
        self.batcher = batcher
        self.default_deadline = default_deadline
        self.flush_slots = (flush_slots if flush_slots is not None
                            else batcher.engine.query_slots)
        self.refresh_every = refresh_every
        self.refresh_fraction = refresh_fraction
        self.stats = FrontEndStats()

        self._cv = threading.Condition()
        self._futures: dict[int, concurrent.futures.Future] = {}
        self._submit_times: dict[int, float] = {}
        self._closed = False
        self._stop_event = threading.Event()
        # Serializes device dispatches with pool refreshes: a refresh can
        # never swap sketches out from under an in-flight flush.
        self._dispatch_lock = threading.Lock()

        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, daemon=True, name="frontend-dispatch")
        self._dispatcher.start()
        self._refresher = None
        if refresh_every is not None:
            self._refresher = threading.Thread(
                target=self._refresh_loop, daemon=True,
                name="frontend-refresh")
            self._refresher.start()

    # ------------------------------------------------------------- submit
    def _submit(self, submit_fn, payload,
                deadline: float | None) -> concurrent.futures.Future:
        deadline = self.default_deadline if deadline is None else deadline
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncFrontEnd is closed")
            # Validation (e.g. oversized seed set) raises HERE, on the
            # offending caller's thread — never inside a shared flush.
            ticket = submit_fn(payload, deadline=deadline)
            self._futures[ticket] = fut
            self._submit_times[ticket] = time.monotonic()
            self._cv.notify_all()
        return fut

    def submit_top_k(self, k: int, *,
                     deadline: float | None = None) -> concurrent.futures.Future:
        return self._submit(self.batcher.submit_top_k, k, deadline)

    def submit_sigma(self, seed_set, *,
                     deadline: float | None = None) -> concurrent.futures.Future:
        return self._submit(self.batcher.submit_sigma, seed_set, deadline)

    def submit_marginal(self, exclude, *,
                        deadline: float | None = None) -> concurrent.futures.Future:
        return self._submit(self.batcher.submit_marginal, exclude, deadline)

    @property
    def inflight(self) -> int:
        """Submitted-but-unresolved queries (queued + on device) — the
        load signal a replica router balances on."""
        with self._cv:
            return len(self._futures)

    # --------------------------------------------------------- dispatcher
    def _wait_for_trigger(self) -> str | None:
        """Block until a flush should fire; returns the trigger kind, or
        None when closed and fully drained."""
        with self._cv:
            while True:
                pending = self.batcher.pending_count
                if self._closed:
                    return "drain" if pending else None
                if pending >= self.flush_slots:
                    return "slots"
                deadline = self.batcher.oldest_deadline()
                now = time.monotonic()
                if deadline is not None and now >= deadline:
                    return "deadline"
                self._cv.wait(
                    timeout=None if deadline is None else deadline - now)

    def _dispatch_loop(self) -> None:
        while True:
            trigger = self._wait_for_trigger()
            if trigger is None:
                return
            start = time.monotonic()
            try:
                with self._dispatch_lock:
                    # Refreshes serialize on the dispatch lock, so this read
                    # equals the version ``flush`` snapshots internally —
                    # the epoch tag every resolved future is stamped with.
                    version = self.batcher.engine.store.version
                    results = self.batcher.flush()
                failed, error = (), None
            except batcher_lib.FlushError as e:  # fail futures, not the thread
                results, failed, error = e.partial, e.tickets, e
            resolved = []
            attr = {"slots": "slot_flushes", "deadline": "deadline_flushes",
                    "drain": "drain_flushes"}[trigger]
            with self._cv:
                self.stats.flushes += 1
                setattr(self.stats, attr, getattr(self.stats, attr) + 1)
                # Fail exactly the tickets the broken dispatch left
                # unanswered; partial results below are delivered normally,
                # and requests submitted during the flush stay queued.
                for ticket in failed:
                    fut = self._futures.pop(ticket, None)
                    self._submit_times.pop(ticket, None)
                    if fut is not None:
                        resolved.append((fut, None, error))
                for ticket, value in results.items():
                    fut = self._futures.pop(ticket, None)
                    t0 = self._submit_times.pop(ticket, None)
                    if t0 is not None:
                        self.stats.max_queue_wait = max(
                            self.stats.max_queue_wait, start - t0)
                    if fut is not None:
                        resolved.append((fut, value, None))
                        self.stats.served += 1
            # Resolve outside the lock: a future callback may re-submit.
            for fut, value, err in resolved:
                if not fut.set_running_or_notify_cancel():
                    continue        # caller cancelled while queued
                if err is not None:
                    fut.set_exception(err)
                else:
                    # Epoch tag: the pool version this answer was computed
                    # under (the serving tier's replica router refuses to
                    # mix replies across versions).  Set before set_result
                    # so done-callbacks and result() waiters always see it.
                    fut.pool_version = version
                    fut.set_result(value)

    # --------------------------------------------- store mutations/refresh
    def mutate_store(self, fn):
        """Run ``fn(store)`` atomically wrt dispatch and return its result.

        The mutation (refresh, tier autoscale grow/shrink, ...) holds the
        same lock every flush holds, so a version bump + stack swap can
        never land under an in-flight dispatch — each flush sees one
        consistent (stack, version) pair, and every replica-wide mutation
        the serving tier applies is an atomic epoch swap on this replica.
        """
        with self._dispatch_lock:
            result = fn(self.batcher.engine.store)
        with self._cv:
            self._cv.notify_all()
        return result

    def refresh_now(self, fraction: float | None = None) -> list[int]:
        """One epoch refresh, serialized with dispatch; returns the
        resampled slots."""
        frac = self.refresh_fraction if fraction is None else fraction
        slots = self.mutate_store(lambda store: store.refresh(frac))
        with self._cv:
            self.stats.refreshes += 1
        return slots

    def _refresh_loop(self) -> None:
        while not self._stop_event.wait(self.refresh_every):
            with self._dispatch_lock:
                if self._closed:
                    return
                # Atomic wrt dispatch: version bump + stack invalidation
                # happen inside the same critical section the flush uses.
                self.batcher.engine.store.refresh(self.refresh_fraction)
            with self._cv:
                self.stats.refreshes += 1
                self._cv.notify_all()

    # -------------------------------------------------------------- close
    def close(self, timeout: float | None = None) -> None:
        """Stop accepting submits, drain, join workers, resolve stragglers.

        Drain contract: **no submitted future is ever left unresolved.**
        The dispatcher's final iterations flush everything still pending
        (the ``drain`` trigger), delivering answers or — if a drain
        dispatch breaks — failing exactly the consumed tickets with the
        `FlushError`.  If any future somehow remains after the workers are
        joined (dispatcher died on an unexpected error, or ``timeout``
        expired mid-drain), it is failed here with a `FlushError` rather
        than hanging its caller forever.  Idempotent.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._stop_event.set()
        self._dispatcher.join(timeout)
        if self._refresher is not None:
            self._refresher.join(timeout)
        with self._cv:
            leftovers = list(self._futures.items())
            self._futures.clear()
            self._submit_times.clear()
        if leftovers:
            error = batcher_lib.FlushError(
                [t for t, _ in leftovers], {},
                RuntimeError("AsyncFrontEnd closed before the dispatcher "
                             "drained these tickets"))
            for _, fut in leftovers:
                if fut.set_running_or_notify_cancel():
                    fut.set_exception(error)

    def __enter__(self) -> "AsyncFrontEnd":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
