"""Mesh-sharded RRR sketch pool: each device owns a disjoint slice of slots.

`ShardedSketchStore` extends the single-device `SketchStore` with a device
*placement* policy — nothing about sampling changes.  Slot ``i`` always
holds the batch drawn at the store's i-th stream allocation (the same
``next_batch_index`` bookkeeping as the base class), so a 1-device pool and
an N-device pool are **bit-identical per slot**; the mesh only decides
which device materializes slot ``i``.  That invariant is what makes the
distributed query engine's answers bit-for-bit equal to single-device ones.

Layout: the stacked ``(B, V, W)`` mask is zero-padded to a multiple of the
mesh axis size and placed with ``NamedSharding(mesh, P(axis))`` — shard
``s`` owns the contiguous slot block ``[s·Bp/S, (s+1)·Bp/S)``.  Pad slots
are all-zero masks; the query engine zeroes their active-mask rows so they
contribute nothing to any reduction.

Budget: ``PoolConfig.memory_budget_mb`` is **per shard** here — an N-shard
pool admits N× the batches of a 1-device pool under the same per-device
budget, which is the point of sharding.  To make that true on a real pod,
the pool never materializes on one device: each sampled mask is staged to
host memory, and ``visited_stack`` assembles the sharded stack from
per-device blocks (`jax.make_array_from_single_device_arrays`), so device
residency is exactly one slot block per shard.  Sampling distributes too:
with ``PoolConfig.spec.backend == "data_parallel"`` every ``ensure`` /
``refresh`` traverses its whole block of new batch indices in ONE
shard_map program — each shard computes its own contiguous slice with
per-batch RNG streams on its own devices, so pool builds parallelize
across the mesh instead of staging one batch at a time through the
default device (other backends keep the sequential default-device path).
With ``backend == "graph_parallel"`` the GRAPH is partitioned too: on a
2-D (data × model) mesh each device persistently holds only its
destination-row slice of the adjacency tiles, batches shard over ``data``
and every per-level collective (frontier all-gather) names only ``model``
— graphs bigger than one device's memory build pools at all, and the
resulting slots are still bit-identical to a 1-device dense pool.

When the mesh carries the spec's ``model_axis`` (size > 1), the pool's
VERTEX rows shard over it too: ``visited_stack`` pads V to a multiple of
M and places ``(Bp, Vp, W)`` with ``P(axis, model_axis)``, so each device
persistently holds only the V/M row slice of its slot block — the
serving-side completion of the 2-D story (the sampler already row-shards
the GRAPH; now the pool it builds is row-sharded at rest too).  The
distributed query engine reduces coverage locally and merges with one
psum over data and one over model (`DistributedQueryEngine`), still
bit-identical to the 1-device engine.  Host-staged batches stay full-V,
so snapshots remain mesh-shape-free and restore onto any D×M layout.

Refresh reuses the pool allocation: the base class's donated-buffer slot
scatter (`sketch_store._set_slots`) rewrites only the refreshed slots of
the sharded stack in place — untouched shards' blocks never move, and the
whole pool is never re-staged from host (the `BENCH_pool_build.json`
``refresh_s ≈ build_s`` fix).

Persistence: snapshots are written through the same manifest format as the
base class, with the shard layout recorded in the manifest's ``extra``
metadata.  Because leaves are *global* (slot-ordered) arrays, a snapshot
saved under one mesh shape restores under any other — restore simply
re-slots batches onto the new mesh's contiguous blocks.  A plain
`SketchStore` can restore a sharded snapshot (and vice versa); the formats
are identical up to ``extra``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import manager
from repro.core import rrr
from repro.graph import csr
from repro.sampling import SamplerSpec
from repro.serve.influence.sketch_store import PoolConfig, SketchStore


def _host_batch(b: rrr.RRRBatch) -> rrr.RRRBatch:
    """Stage a batch's mask to host memory (no-op if already there)."""
    return dataclasses.replace(b, visited=np.asarray(b.visited))


class ShardedSketchStore(SketchStore):
    """Epoch-tagged sketch pool with slots sharded over one mesh axis."""

    # Restored masks stay on host (see base class) — device residency is
    # only the per-shard blocks assembled by ``visited_stack``.
    _mask_array = staticmethod(np.asarray)

    def __init__(self, g: csr.Graph, config: PoolConfig | None = None,
                 mesh: Mesh | None = None, *, axis: str = "data",
                 g_rev: csr.Graph | None = None):
        if mesh is None:
            raise ValueError("ShardedSketchStore needs a mesh; use "
                             "SketchStore for single-device pools")
        if axis not in mesh.axis_names:
            raise ValueError(f"axis {axis!r} not in mesh {mesh.axis_names}")
        # Set before super().__init__: the base constructor builds the
        # sampler through ``_make_sampler``, which reads the mesh.
        self.mesh = mesh
        self.axis = axis
        super().__init__(g, config, g_rev=g_rev)

    def _make_sampler(self, g: csr.Graph, spec, g_rev):
        """Back the sampler with the store's mesh — a ``data_parallel``
        spec builds each shard's slot block on that shard's own devices; a
        ``graph_parallel`` spec additionally row-partitions the graph over
        the spec's ``model_axis`` (batch blocks follow the store's slot
        axis, so slots land exactly where ``visited_stack`` shards them)."""
        from repro import sampling
        if spec.backend in ("data_parallel", "graph_parallel") \
                and spec.mesh_axis != self.axis:
            spec = spec.replace(mesh_axis=self.axis)
        return sampling.make_sampler(g, spec, mesh=self.mesh, g_rev=g_rev)

    # ------------------------------------------------------------- layout
    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    @property
    def row_axis(self) -> str | None:
        """Mesh axis the pool's VERTEX rows shard over (the spec's
        ``model_axis``), or None when the mesh doesn't carry it / it has
        size 1 — then every device holds full-V rows as before."""
        ax = self.config.spec.model_axis
        if ax in self.mesh.axis_names and int(self.mesh.shape[ax]) > 1:
            return ax
        return None

    @property
    def row_shards(self) -> int:
        ax = self.row_axis
        return int(self.mesh.shape[ax]) if ax is not None else 1

    @property
    def padded_vertices(self) -> int:
        """Vertex count rounded up to a multiple of the row-shard count —
        the stack's second dim (== V when rows are unsharded)."""
        m = self.row_shards
        return -(-self.graph.num_vertices // m) * m

    @property
    def capacity(self) -> int:
        """Per-shard memory budget × shard count (≥ 1, like the base).

        With row sharding each device holds only V/M rows per local slot,
        so the per-device budget admits M× the batches — the 2-D story's
        memory win, priced into admission."""
        cap = self.config.max_batches
        if self.config.memory_budget_mb is not None:
            per_slot = -(-self.bytes_per_batch // self.row_shards)
            per_shard = int(self.config.memory_budget_mb * 2 ** 20
                            // per_slot)
            cap = min(cap, per_shard * self.num_shards)
        return max(cap, 1)

    @property
    def padded_batches(self) -> int:
        """Slot count rounded up to a multiple of the shard count."""
        s = self.num_shards
        return -(-len(self.batches) // s) * s

    def shard_layout(self) -> list[int]:
        """slot → owning shard (contiguous blocks over the padded slots)."""
        per = self.padded_batches // self.num_shards
        return [i // per for i in range(len(self.batches))]

    # ----------------------------------------------------------- sampling
    def _sample_block(self, batch_indices: list[int]) -> list[rrr.RRRBatch]:
        # Stage each mask to host: persistent device residency must be
        # only the sharded stack (one slot block per shard), or the
        # sampling device would accumulate the whole pool and void the
        # per-shard budget.  With the ``data_parallel`` backend the block
        # is traversed in ONE shard_map program (each shard computes its
        # own contiguous slice on its own devices — the same contiguous
        # layout ``visited_stack`` shards to) and arrives host-staged
        # already; other backends run per batch on the default device.
        return [_host_batch(b) for b in super()._sample_block(batch_indices)]

    def _clone_empty(self) -> "ShardedSketchStore":
        return type(self)(self.graph, self.config, self.mesh, axis=self.axis,
                          g_rev=self.g_rev)

    def _extend_stack(self, new_batches) -> None:
        # Growth can change ``padded_batches`` and every shard's block
        # boundaries — drop the cache and let ``visited_stack`` reassemble
        # from the host-staged batches (placement only; no resampling).
        self._stack = None

    def _truncate_stack(self, keep: int) -> None:
        self._stack = None

    # -------------------------------------------------------------- stack
    def visited_stack(self) -> jnp.ndarray:
        """(Bp, Vp, W) stack, slot dim zero-padded to ``padded_batches``
        and sharded ``P(axis)``; with row sharding the vertex dim is ALSO
        padded to ``padded_vertices`` and sharded ``P(axis, row_axis)``
        (cached per store version).

        Assembled from per-device blocks — each device receives exactly
        its own (slot block × row slice), so the full stack never
        materializes on any single device and per-device visited-row
        memory is V/M under row sharding.  Host-staged batches stay
        full-V: the row slicing is pure placement, which is what lets a
        snapshot restore onto ANY D×M mesh shape.  (Single-process meshes
        only for now; a multi-host pod would filter to addressable
        devices.)

        Offline IMM slices a prefix of this (``[:want]``); slicing a
        sharded array is fine — XLA re-gathers as needed.
        """
        if not self.batches:
            raise ValueError("empty pool — call ensure() first")
        if self._stack is None:
            bp, per = self.padded_batches, self.padded_batches // self.num_shards
            v, w = np.asarray(self.batches[0].visited).shape
            vp = self.padded_vertices
            vloc = vp // self.row_shards
            shape = (bp, vp, w)
            sharding = NamedSharding(self.mesh, P(self.axis, self.row_axis))
            blocks: dict[tuple[int, int], np.ndarray] = {}

            def block(lo: int, rlo: int) -> np.ndarray:
                if (lo, rlo) not in blocks:
                    rows = []
                    for b in self.batches[lo:lo + per]:
                        r = np.asarray(b.visited)[rlo:rlo + vloc]
                        if r.shape[0] < vloc:    # vertex pad, last shard
                            r = np.pad(r, ((0, vloc - r.shape[0]), (0, 0)))
                        rows.append(r)
                    rows += [np.zeros((vloc, w), rows[0].dtype
                                      if rows else np.uint32)
                             ] * (per - len(rows))
                    blocks[(lo, rlo)] = np.stack(rows)
                return blocks[(lo, rlo)]

            arrays = [
                jax.device_put(block(idx[0].start or 0, idx[1].start or 0),
                               dev)
                for dev, idx in sharding.devices_indices_map(shape).items()]
            self._stack = jax.make_array_from_single_device_arrays(
                shape, sharding, arrays)
        return self._stack

    def _update_stack(self, slots, new_batches) -> None:
        # The base scatter stacks full-V masks; a row-sharded stack is
        # padded to Vp rows — pad the refreshed masks to match before the
        # donated `_set_slots` scatter (which preserves the 2-D placement:
        # each device rewrites only its own row slice of the touched
        # slots).
        if self._stack is None:
            return
        vp = self._stack.shape[1]
        masks = jnp.stack([jnp.asarray(b.visited) for b in new_batches])
        if masks.shape[1] < vp:
            masks = jnp.pad(masks,
                            ((0, 0), (0, vp - masks.shape[1]), (0, 0)))
        from repro.serve.influence.sketch_store import _set_slots
        self._stack = _set_slots(self._stack,
                                 jnp.asarray(slots, jnp.int32), masks)

    # -------------------------------------------------------- persistence
    def _manifest_extra(self) -> dict:
        """Shard layout + the `SamplerSpec` (base class) in one ``extra``.

        ``mesh_shape`` records the FULL (data × model) layout the pool was
        built under — with a ``graph_parallel`` spec that is the row
        partition too, which restore validates against the new mesh.
        ``row_layout`` records the vertex-row sharding the stack served
        under (axis, shard count, padded vertex dim): because the saved
        leaves are full-V host arrays, the layout is metadata, not a
        constraint — restore re-slices rows onto ANY new D×M shape."""
        return {**super()._manifest_extra(),
                "kind": "sharded_sketch_pool",
                "mesh_axis": self.axis,
                "num_shards": self.num_shards,
                "mesh_shape": {str(a): int(self.mesh.shape[a])
                               for a in self.mesh.axis_names},
                "shard_layout": self.shard_layout(),
                "row_layout": {"axis": self.row_axis,
                               "shards": self.row_shards,
                               "padded_vertices": self.padded_vertices}}

    @staticmethod
    def saved_layout(directory: str, step: int | None = None) -> dict:
        """The ``extra`` metadata a snapshot was written under (empty dict
        for snapshots from a plain `SketchStore`)."""
        return manager.read_manifest(directory, step).get("extra", {})

    @classmethod
    def restore(cls, directory: str, g: csr.Graph,
                config: PoolConfig | None = None,
                mesh: Mesh | None = None, *, axis: str = "data",
                step: int | None = None,
                g_rev: csr.Graph | None = None) -> "ShardedSketchStore":
        """Rebuild a bit-identical pool, re-slotted onto ``mesh``.

        The new mesh may have any shape along the slot axis AND the row
        axis — the snapshot's slot-ordered, full-V global arrays are
        simply re-sliced into the new mesh's contiguous (slot block × row
        slice) blocks: a pool saved under a 2×4 mesh restores onto 4×2,
        8×1, or a single device with identical query answers (the
        recorded ``shard_layout`` / ``row_layout`` of the *saving* mesh
        are metadata, not constraints).  Masks load straight from disk to
        host (``_restored_fields`` with host placement), so restore never
        transits the pool through a single device.

        With no ``config``, the snapshot's recorded `SamplerSpec` is
        adopted wholesale — a pool built graph-parallel (because the graph
        exceeds one device) restores with a graph-parallel sampler, never
        silently falling back to a dense refresh path.  An explicit config
        still overrides (backends are interchangeable bit-for-bit, so
        re-backending a pool on restore is a legitimate choice).

        Refused layouts: a ``graph_parallel`` restore spec needs the new
        mesh to carry its model axis (future ``refresh`` calls must be
        able to row-partition the graph), and — via the base class — a
        diffusion mismatch with the snapshot always raises.
        """
        step, manifest = cls._resolve_snapshot(directory, step)
        extra = manifest.get("extra", {})
        if config is None:
            saved_spec = extra.get("sampler_spec")
            config = PoolConfig(
                spec=SamplerSpec.from_manifest(saved_spec)) \
                if saved_spec else PoolConfig()
        spec = config.spec
        if spec.backend == "graph_parallel" and (
                mesh is None or spec.model_axis not in mesh.axis_names):
            raise ValueError(
                f"layout mismatch: a graph_parallel pool needs a mesh with "
                f"model axis {spec.model_axis!r} to refresh, but the "
                f"restore mesh has axes "
                f"{mesh.axis_names if mesh is not None else ()} (snapshot "
                f"was written under mesh_shape "
                f"{extra.get('mesh_shape')}) — restore onto a "
                "(data × model) mesh or with a non-graph_parallel spec")
        config, epoch, nbi, batches, epochs, gepoch = cls._restored_fields(
            directory, config, step, manifest=manifest)
        store = cls(g, config, mesh, axis=axis, g_rev=g_rev)
        store.epoch = epoch
        store.graph_epoch = gepoch
        store.next_batch_index = nbi
        store.batches = batches
        store.batch_epochs = epochs
        return store
