"""Distributed influence-query serving: sharded pools, collective coverage
reduction, async deadline-batched front-end.

Layers over `repro.serve.influence` (which stays the single-device path):

* `ShardedSketchStore` — RRR sketch slots sharded over a mesh axis,
  bit-identical per slot to a single-device pool, per-shard memory
  budgets, elastic manifest restore onto any mesh shape.
* `DistributedQueryEngine` — shard_map query programs; each device reduces
  coverage over its local batches, ONE psum merges the partial counts, and
  greedy argmax runs on the replicated merged counts so shards agree with
  no second collective.  Drop-in for `QueryEngine` under `MicroBatcher`.
* `AsyncFrontEnd` — thread-safe request queue with futures, flush on full
  slot OR oldest-request deadline, background epoch refresh serialized
  with dispatch.

    mesh   = jax.make_mesh((8,), ("data",))
    store  = ShardedSketchStore(graph, PoolConfig(num_colors=64), mesh)
    store.ensure(16)
    fe = AsyncFrontEnd(MicroBatcher(DistributedQueryEngine(store),
                                    cache=ResultCache()),
                       default_deadline=0.02, refresh_every=30.0)
    sigma = fe.submit_sigma([3, 17, 42]).result()
"""
from repro.serve.distributed.engine import DistributedQueryEngine
from repro.serve.distributed.frontend import AsyncFrontEnd, FrontEndStats
from repro.serve.distributed.sharded_store import ShardedSketchStore

__all__ = ["AsyncFrontEnd", "DistributedQueryEngine", "FrontEndStats",
           "ShardedSketchStore"]
