"""Churn-proportional incremental pool refresh after a graph delta.

The pipeline (`incremental_refresh`, or `plan_refresh` + `apply_plan`
when one plan must sweep several bit-identical replicas):

1. apply the delta to BOTH graphs of the store's pair — the forward
   graph directly, the reversed graph via ``delta.reversed()`` (never
   `csr.transpose`, which renumbers edge ids), with LT re-normalization
   confined to the mutated destinations when the pool is LT;
2. map the REVERSED graph's touched source rows (traversals run on
   ``g_rev``) to `FrontierIndex` row-blocks and intersect with the
   `DirtySlotTracker` bitsets → the dirty slot set;
3. swap the pair into the store (`SketchStore.apply_graph_update` —
   sampler rebuilt, graph epoch bumped so `version` changes) and
   resample ONLY the dirty slots at their recorded batch indices
   (`resample_slots` — the donated `_set_slots` scatter, no epoch bump,
   no new RNG streams).

Because slot ``i`` is a pure function of ``(graph, master_seed,
batch_index_i)`` and clean slots provably reproduce on the new graph
(`dirty` module doc), the refreshed pool is bit-identical — masks and
work counters — to a cold rebuild of the same batch indices on the
mutated graph, at a cost proportional to the dirty fraction instead of
the pool (and graph) size.  `cold_rebuild_batches` computes that cold
reference; smokes, CI, and the bench assert the identity.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.stream import delta as delta_lib
from repro.stream.dirty import DirtySlotTracker

__all__ = ["DeltaPlan", "StreamReport", "plan_refresh", "apply_plan",
           "incremental_refresh", "cold_rebuild_batches"]


@dataclasses.dataclass(frozen=True)
class DeltaPlan:
    """Everything `apply_plan` needs, computed once per delta.

    A replica group computes ONE plan (replicas are bit-identical, so the
    dirty set is shared) and applies it to every replica's store.
    """
    g: object                    # mutated forward Graph
    g_rev: object                # mutated reversed Graph (delta.reversed())
    applied: delta_lib.AppliedDelta      # forward-graph op counts
    touched_row_blocks: np.ndarray       # reversed-graph blocks, sorted
    dirty_slots: list[int]
    total_slots: int

    @property
    def dirty_fraction(self) -> float:
        return len(self.dirty_slots) / max(self.total_slots, 1)


@dataclasses.dataclass(frozen=True)
class StreamReport:
    """What one applied delta did — the tier's metrics payload."""
    inserted: int
    deleted: int
    touched_row_blocks: int
    dirty_slots: int
    total_slots: int
    dirty_fraction: float
    refresh_s: float
    graph_epoch: int


def plan_refresh(store, tracker: DirtySlotTracker,
                 delta: delta_lib.EdgeDelta) -> DeltaPlan:
    """Dirty-set planning: mutate the graph pair (functionally) and
    intersect the reversed-graph touched rows with the tracker bitsets.
    The store itself is not modified."""
    tracker.sync(store)
    lt = store.spec.diffusion == "lt"
    g, applied_fwd = delta_lib.apply_delta(store.graph, delta)
    # Traversals run on the reversed graph: its touched source rows are
    # the ones slot dirtiness is judged against.  The sampler re-runs the
    # (idempotent, order-preserving) LT normalization on this array, so
    # maintaining the invariant here keeps bits AND ids stable.
    g_rev, applied_rev = delta_lib.apply_delta(store.g_rev, delta.reversed(),
                                               lt_normalized=lt)
    blocks = delta_lib.touched_row_blocks(applied_rev.touched_rows,
                                          tracker.tile_rows)
    dirty = tracker.dirty_slots(blocks)
    return DeltaPlan(g=g, g_rev=g_rev, applied=applied_fwd,
                     touched_row_blocks=blocks, dirty_slots=dirty,
                     total_slots=len(store.batches))


def apply_plan(store, plan: DeltaPlan) -> None:
    """Swap the mutated pair into ``store`` and resample its dirty slots
    (same plan → same mutation on every replica of a group).  The touched
    row blocks ride along so a values-only delta patches the sampler's
    frontier index in place (`Sampler.rebind`) instead of rebuilding it
    O(|E|) host-side."""
    store.apply_graph_update(plan.g, plan.g_rev,
                             touched_row_blocks=plan.touched_row_blocks)
    store.resample_slots(plan.dirty_slots)


def incremental_refresh(store, tracker: DirtySlotTracker,
                        delta: delta_lib.EdgeDelta) -> StreamReport:
    """Plan + apply + tracker re-sync for a single store; returns the
    metrics report.  The timed span covers graph swap, sampler rebuild,
    and dirty-slot resampling — the serving-visible cost of the delta."""
    plan = plan_refresh(store, tracker, delta)
    t0 = time.perf_counter()
    apply_plan(store, plan)
    refresh_s = time.perf_counter() - t0
    tracker.sync(store)
    tracker.note_delta(len(plan.dirty_slots))
    return StreamReport(
        inserted=plan.applied.inserted, deleted=plan.applied.deleted,
        touched_row_blocks=len(plan.touched_row_blocks),
        dirty_slots=len(plan.dirty_slots), total_slots=plan.total_slots,
        dirty_fraction=plan.dirty_fraction, refresh_s=refresh_s,
        graph_epoch=store.graph_epoch)


def cold_rebuild_batches(store) -> list:
    """Every slot of ``store`` rebuilt from scratch on its CURRENT graph
    pair — the bit-identity reference the incremental path is checked
    against (a fresh sampler, same recorded batch indices)."""
    sampler = store._make_sampler(store.graph, store.spec, store.g_rev)
    return sampler.sample_many([b.batch_index for b in store.batches])
