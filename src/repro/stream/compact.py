"""Tombstone compaction: the periodic CSR rebuild that bounds the cost of
the id-stable delta policy.

`stream.apply_delta` keeps deleted edges in place as prob-0 tombstones so
CSR edge ids (the per-edge RNG counters) stay stable — every delta is
churn-priced, but interior tombstones accumulate: they pad every gather,
ride every frontier-index block, and inflate the padded edge count.
Compaction trades ONE expensive rebuild for a clean graph: drop every
tombstone, rebuild the CSR pair, and resample EVERY pool slot (edge ids
renumber, so per-edge RNG streams move — all previous bits are suspect;
slot ``i`` remains the pure function ``(graph, master_seed,
batch_index_i)``, so the compacted pool is bit-identical to a cold build
on the compacted graph).

Policy lives in the serving tier: `ServingTier.maybe_compact` fires when
`tombstone_fraction` exceeds a threshold (default 10%), swept over every
replica from one shared rebuilt pair so the group re-converges
bit-identically.
"""
from __future__ import annotations

import numpy as np

from repro.graph import csr

__all__ = ["tombstone_fraction", "compact_graph", "compact_store"]


def tombstone_fraction(g: csr.Graph) -> float:
    """Fraction of the forward graph's real edge slots holding prob-0
    tombstones (CSR padding beyond ``num_edges`` doesn't count)."""
    e = g.num_edges
    if not e:
        return 0.0
    prob = np.asarray(g.prob)[:e]
    return float(np.count_nonzero(prob == 0.0)) / e


def compact_graph(g: csr.Graph) -> tuple[csr.Graph, csr.Graph]:
    """``(g2, g_rev2)``: the live edges of ``g`` rebuilt as a fresh CSR
    pair — tombstones dropped, edge ids renumbered.

    The live set is duplicate-free by the delta policy (a (src, dst) pair
    exists at most once, live or tombstoned), so no union-merge is needed
    and probabilities carry over bit-for-bit.  The reversed graph is a
    fresh `csr.transpose` — valid here precisely because compaction
    abandons id stability anyway.
    """
    e = g.num_edges
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    prob = np.asarray(g.prob)[:e]
    live = prob > 0
    g2 = csr.from_edges(src[live], dst[live], prob[live], g.num_vertices)
    return g2, csr.transpose(g2)


def compact_store(store) -> float:
    """Compact ``store``'s graph pair in place and resample EVERY slot.

    Returns the tombstone fraction that was reclaimed.  The sampler
    rebind sees a structural change and rebuilds its indexes; resampling
    all slots at their recorded batch indices re-derives the pool on the
    renumbered edge ids — bit-identical to a cold build of the same
    indices on the compacted graph.
    """
    frac = tombstone_fraction(store.graph)
    g2, g_rev2 = compact_graph(store.graph)
    store.apply_graph_update(g2, g_rev2)
    store.resample_slots(list(range(len(store.batches))))
    return frac
