"""Streaming graph updates: id-stable CSR deltas, visited-row-block
dirty tracking, and churn-proportional incremental pool refresh.

    from repro import stream

    delta = stream.EdgeDelta.inserts([3], [17], [0.05])
    tracker = stream.DirtySlotTracker.for_store(store)
    report = stream.incremental_refresh(store, tracker, delta)
    # store now serves the mutated graph; only dirty slots resampled,
    # bit-identical to a cold rebuild (masks and work counters).

Layer map: `delta` (EdgeDelta / apply_delta — the id-stable CSR
mutation contract), `dirty` (DirtySlotTracker — slot × row-block
bitsets), `refresh` (plan/apply + the cold-rebuild reference), `compact`
(the periodic tombstone-dropping rebuild that bounds id-stability's
cost).  The serving tier front door is `ServingTier.apply_delta`, with
`ServingTier.maybe_compact` as the compaction policy hook.
"""
from repro.stream.compact import (compact_graph, compact_store,
                                  tombstone_fraction)
from repro.stream.delta import (AppliedDelta, EdgeDelta, apply_delta,
                                random_delta, touched_row_blocks)
from repro.stream.dirty import DirtySlotTracker
from repro.stream.refresh import (DeltaPlan, StreamReport, apply_plan,
                                  cold_rebuild_batches, incremental_refresh,
                                  plan_refresh)

__all__ = [
    "AppliedDelta", "EdgeDelta", "apply_delta", "random_delta",
    "touched_row_blocks", "DirtySlotTracker", "DeltaPlan", "StreamReport",
    "apply_plan", "cold_rebuild_batches", "incremental_refresh",
    "plan_refresh", "compact_graph", "compact_store", "tombstone_fraction",
]
