"""Typed edge-delta batches and id-stable CSR delta application.

The whole streaming design hangs on one invariant: **CSR edge ids are
array positions, and the counter RNG is keyed by them** (`core.rng`
draws per ``(seed, level, eid, word)``; LT selections per destination).
A slot resampled at its recorded ``batch_index`` reproduces its old mask
bit-for-bit *iff* every edge it can touch kept its id and its bits.  So
`apply_delta` never rebuilds the edge list (``csr.from_edges`` re-sorts
and renumbers):

* **inserts** resurrect a matching tombstone in place, else extend the
  arrays by exactly the fresh-insert count — consuming k padding slots
  while appending k new ones, so the src-0 padding *population* (which
  the dense work counters see whenever row 0 is active) never changes;
* **deletes** become tombstones — ``prob = 0`` with ``(src, dst)`` kept,
  so the slot stays in its source row-block and every untouched
  traversal's work counters are untouched too; trailing tombstones are
  trimmed back into ``(0, 0, 0)`` padding with the tail sliced off by
  the same count (again population-neutral), which makes insert→delete
  round-trips restore the original arrays bit for bit, length included.

Deltas that carry fresh inserts or trims change ``num_edges`` /
``padded_edges`` — static pytree fields, so the next traversal pays one
jit recompile; delete-/resurrect-only deltas keep all shapes.
Tombstones accumulate in the interior (only trailing ones trim); the
escape hatch is a periodic full rebuild (``csr.dedupe`` + cold
``ensure``), which renumbers ids and costs a cold build by design.

After a delta the edge arrays are generally NOT src-sorted; ``indptr``
is maintained as the cumulative LIVE out-degree (prob > 0) so
``Graph.degrees`` stays meaningful.  Every traversal consumer is
order-free: the dense sweep and `core.sparse.FrontierIndex` key on the
per-edge ``src`` array (the index argsorts internally), the tile
layouts sort edges themselves, and `lt.selection_cum_before` groups by
``dst``.  ``csr.transpose``/``dedupe``/``relabel`` DO renumber ids —
never apply them to a streamed graph; maintain the reversed graph by
applying ``delta.reversed()`` to it directly.

Preconditions (checked where cheap): the graph is dedupe-clean with
strictly positive live weights — ``prob == 0`` inside ``[:num_edges]``
means *tombstone* to this layer.

Returned alongside the mutated graph, `AppliedDelta.touched_rows` is
the conservative set of source rows whose out-edge slots changed in any
way a traversal or its work counters can observe — the sources of every
structural op and trimmed tombstone, and, under ``lt_normalized=True``,
of every live in-edge of a re-normalized destination.  The
population-neutral insert/trim policy above is what keeps row 0 OFF
this list: padding slots carry ``src == 0``, so a padding-count change
would dirty every traversal that ever activates row 0.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.graph import csr

__all__ = ["EdgeDelta", "AppliedDelta", "apply_delta", "random_delta",
           "touched_row_blocks"]


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """One batch of edge mutations: inserts (with weights) and deletes.

    ``weight[i]`` must be a finite positive float where ``insert[i]``
    (streaming keeps the live-weight-positive invariant — a zero weight
    is a tombstone, not an edge); it is ignored for deletes.  A single
    delta must not name the same ``(src, dst)`` pair twice — the apply
    order within one batch would be ambiguous; split into two deltas.
    """
    src: np.ndarray      # (K,) int32
    dst: np.ndarray      # (K,) int32
    weight: np.ndarray   # (K,) float32; > 0 where insert
    insert: np.ndarray   # (K,) bool; False = delete

    def __post_init__(self):
        object.__setattr__(self, "src", np.asarray(self.src, np.int32))
        object.__setattr__(self, "dst", np.asarray(self.dst, np.int32))
        object.__setattr__(self, "weight",
                           np.asarray(self.weight, np.float32))
        object.__setattr__(self, "insert", np.asarray(self.insert, bool))
        k = len(self.src)
        if not (len(self.dst) == len(self.weight) == len(self.insert) == k):
            raise ValueError("EdgeDelta arrays must share one length")
        w = self.weight[self.insert]
        if len(w) and (not np.all(np.isfinite(w)) or np.any(w <= 0)):
            raise ValueError("insert weights must be finite and > 0 "
                             "(prob == 0 slots are tombstones)")
        pairs = self.src.astype(np.int64) << 32 | self.dst.astype(np.uint32)
        if len(np.unique(pairs)) != k:
            raise ValueError("duplicate (src, dst) pair within one delta — "
                             "apply order would be ambiguous; split it")

    # ------------------------------------------------------- constructors
    @classmethod
    def inserts(cls, src, dst, weight) -> "EdgeDelta":
        src = np.asarray(src, np.int32)
        return cls(src, np.asarray(dst, np.int32),
                   np.asarray(weight, np.float32),
                   np.ones(len(src), bool))

    @classmethod
    def deletes(cls, src, dst) -> "EdgeDelta":
        src = np.asarray(src, np.int32)
        return cls(src, np.asarray(dst, np.int32),
                   np.zeros(len(src), np.float32),
                   np.zeros(len(src), bool))

    @classmethod
    def concat(cls, *deltas: "EdgeDelta") -> "EdgeDelta":
        return cls(np.concatenate([d.src for d in deltas]),
                   np.concatenate([d.dst for d in deltas]),
                   np.concatenate([d.weight for d in deltas]),
                   np.concatenate([d.insert for d in deltas]))

    # ------------------------------------------------------------- views
    def __len__(self) -> int:
        return len(self.src)

    @property
    def num_inserts(self) -> int:
        return int(self.insert.sum())

    @property
    def num_deletes(self) -> int:
        return len(self) - self.num_inserts

    def reversed(self) -> "EdgeDelta":
        """The same delta on the transposed graph (src/dst swapped) —
        how the stream layer maintains ``g_rev`` without `csr.transpose`
        (which would renumber every edge id)."""
        return EdgeDelta(self.dst, self.src, self.weight, self.insert)

    def inverse(self) -> "EdgeDelta":
        """The delta that undoes this one — defined for all-insert
        deltas only (a delete's inverse needs the deleted weight, which
        lives in the graph, not the delta)."""
        if self.num_deletes:
            raise ValueError("inverse() is only defined for all-insert "
                             "deltas (deleted weights live in the graph)")
        return EdgeDelta.deletes(self.src, self.dst)


@dataclasses.dataclass(frozen=True)
class AppliedDelta:
    """What `apply_delta` did: the observable blast radius + op counts.

    ``touched_rows`` is sorted-unique and conservative: every source row
    whose slot population OR slot bits changed (masks or work counters
    of a traversal entering the row could change).  A traversal that
    never visited any touched row reproduces bit-identically on the new
    graph — the `DirtySlotTracker` soundness contract.
    """
    touched_rows: np.ndarray    # sorted unique int32
    inserted: int
    deleted: int
    resurrected: int            # inserts that re-filled a tombstone
    appended: int               # fresh inserts = array slots appended
    trimmed: int                # trailing tombstones sliced back off


def _pair_keys(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    return src.astype(np.int64) << 32 | dst.astype(np.uint32)


def apply_delta(g: csr.Graph, delta: EdgeDelta, *,
                lt_normalized: bool = False) \
        -> tuple[csr.Graph, AppliedDelta]:
    """Apply ``delta`` to ``g`` with stable CSR edge ids (see module doc).

    ``lt_normalized=True`` declares ``g`` an LT-normalized reversed graph
    (`lt.normalize_lt_weights` invariant: per-dst in-weights sum ≤ 1):
    after the structural ops, the live in-edges of every destination the
    delta touched are re-normalized in place with the exact
    `normalize_lt_weights` arithmetic (float64 per-dst sums in array
    order, ``scale = 1/max(1, Σ)``, float32 cast), confined to those
    destinations — untouched rows keep their bytes.  Normalization is a
    lossy projection (weights only ever scale DOWN): deleting an insert
    that pushed a sum past 1 does not restore the pre-insert bits unless
    the sums stayed ≤ 1 throughout.

    Functional: ``g`` is never mutated; arrays are copied once (O(E)
    host numpy — vectorized, and cheap next to any slot resample).
    """
    v = g.num_vertices
    e = g.num_edges
    src = np.asarray(g.src).copy()
    dst = np.asarray(g.dst).copy()
    prob = np.asarray(g.prob).copy()

    if len(delta) and (delta.src.min() < 0 or delta.dst.min() < 0
                       or delta.src.max() >= v or delta.dst.max() >= v):
        raise ValueError(f"delta names vertices outside [0, {v})")

    # ---- match delta pairs against the existing slots (live + tombstone)
    keys = _pair_keys(src[:e], dst[:e])
    order = np.argsort(keys, kind="stable")
    skeys = keys[order]
    if e and np.any(skeys[1:] == skeys[:-1]):
        raise ValueError("graph has parallel (src, dst) slots — streaming "
                         "needs a dedupe-clean graph (csr.dedupe)")
    dkeys = _pair_keys(delta.src, delta.dst)
    where = np.searchsorted(skeys, dkeys)
    cand = order[np.minimum(where, max(e - 1, 0))] if e else \
        np.zeros(len(delta), np.int64)
    found = (where < e) & (e > 0)
    found &= np.where(found, keys[cand] == dkeys, False)

    touched: list[np.ndarray] = []
    # Row-0 work-counter invariant: the dense sweep counts EVERY padded
    # slot whose source row is active, and padding slots carry src 0 — so
    # the row-0 *slot count* must never change, or every traversal that
    # activates row 0 would need a resample just to fix its counters.
    # Fresh inserts therefore EXTEND the arrays by exactly their count
    # (consuming k padding slots while appending k new ones: net zero)
    # and the trailing-tombstone trim SLICES the same number of padding
    # slots off the tail (tombstone → padding conversion: net zero).
    pad_count = len(src) - e

    # ------------------------------------------------------------ deletes
    del_mask = ~delta.insert
    bad = del_mask & (~found | (prob[np.where(found, cand, 0)] <= 0))
    if np.any(bad):
        i = int(np.nonzero(bad)[0][0])
        raise KeyError(f"delete of absent edge "
                       f"({int(delta.src[i])}, {int(delta.dst[i])})")
    del_pos = cand[del_mask]
    prob[del_pos] = 0.0
    touched.append(delta.src[del_mask])

    # ------------------------------------------------------------ inserts
    ins_mask = delta.insert
    dup = ins_mask & found & (prob[np.where(found, cand, 0)] > 0)
    if np.any(dup):
        i = int(np.nonzero(dup)[0][0])
        raise KeyError(f"insert of live edge "
                       f"({int(delta.src[i])}, {int(delta.dst[i])}) — "
                       "delete it first or use a different pair")
    res_mask = ins_mask & found            # tombstone resurrection, in place
    prob[cand[res_mask]] = delta.weight[res_mask]
    resurrected = int(res_mask.sum())

    fresh = ins_mask & ~found
    n_fresh = int(fresh.sum())
    if n_fresh:
        z32 = np.zeros(n_fresh, np.int32)
        src = np.concatenate([src, z32])
        dst = np.concatenate([dst, z32])
        prob = np.concatenate([prob, np.zeros(n_fresh, np.float32)])
        pos = np.arange(e, e + n_fresh)
        src[pos] = delta.src[fresh]
        dst[pos] = delta.dst[fresh]
        prob[pos] = delta.weight[fresh]
        e += n_fresh            # pad slots consumed == appended: net zero
    touched.append(delta.src[ins_mask])

    # ---- trim trailing tombstones back into padding (slot → (0,0,0)),
    # slicing the same number of slots off the tail so the padding count
    # — hence the row-0 population — is unchanged.  Makes insert→delete
    # round-trips restore the ORIGINAL arrays bit for bit, length included.
    trimmed = 0
    while e > 0 and prob[e - 1] == 0.0:
        touched.append(src[e - 1: e].copy())    # slot leaves its row group
        src[e - 1] = dst[e - 1] = 0
        e -= 1
        trimmed += 1
    if trimmed:
        src = src[:e + pad_count]
        dst = dst[:e + pad_count]
        prob = prob[:e + pad_count]

    # --------------------------------------- confined LT re-normalization
    if lt_normalized and len(delta):
        affected = np.unique(delta.dst)
        sel = np.isin(dst[:e], affected)
        # Exact normalize_lt_weights arithmetic on the affected dsts:
        # float64 per-dst sums accumulated in array order (tombstones add
        # an exact +0.0), scale = 1/max(1, Σ), float32 cast.
        p64 = prob[:e].astype(np.float64)
        in_sum = np.zeros(v)
        np.add.at(in_sum, dst[:e][sel], p64[sel])
        scale = 1.0 / np.maximum(in_sum[dst[:e][sel]], 1.0)
        prob[:e][sel] = (p64[sel] * scale).astype(np.float32)
        # Conservative: every live in-edge of an affected dst may have
        # been rescaled — its source row is touched.
        touched.append(src[:e][sel & (prob[:e] > 0)])

    # ------------------------------------------------- live-degree indptr
    live_src = src[:e][prob[:e] > 0]
    counts = np.bincount(live_src, minlength=v)
    indptr = np.zeros(v + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])

    g2 = csr.Graph(indptr=jnp.asarray(indptr, jnp.int32),
                   src=jnp.asarray(src), dst=jnp.asarray(dst),
                   prob=jnp.asarray(prob),
                   num_vertices=v, num_edges=int(e))
    rows = (np.unique(np.concatenate(touched).astype(np.int32))
            if touched else np.zeros(0, np.int32))
    return g2, AppliedDelta(touched_rows=rows,
                            inserted=int(ins_mask.sum()),
                            deleted=int(del_mask.sum()),
                            resurrected=resurrected,
                            appended=n_fresh, trimmed=trimmed)


def touched_row_blocks(touched_rows: np.ndarray, tile_rows: int) -> np.ndarray:
    """Sorted-unique `FrontierIndex` row-block ids covering the rows."""
    return np.unique(np.asarray(touched_rows, np.int64) // int(tile_rows))


def random_delta(g: csr.Graph, rng: np.random.Generator, *,
                 num_deletes: int, num_inserts: int,
                 dst_rows: np.ndarray | None = None,
                 weight_range: tuple[float, float] = (0.01, 0.1)) -> EdgeDelta:
    """A well-formed random delta for smokes/benchmarks: deletes sampled
    from the live edges, inserts from currently-absent pairs.

    ``dst_rows`` confines both ops to edges whose DESTINATION lies in the
    given rows — on the reversed graph those destinations are the source
    rows, so a benchmark can dial the touched-row-block fraction (churn)
    directly.
    """
    e = g.num_edges
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    prob = np.asarray(g.prob)[:e]
    live = np.nonzero(prob > 0)[0]
    if dst_rows is not None:
        allowed = np.zeros(g.num_vertices, bool)
        allowed[np.asarray(dst_rows, np.int64)] = True
        live = live[allowed[dst[live]]]
    num_deletes = min(num_deletes, len(live))
    del_pos = rng.choice(live, size=num_deletes, replace=False) \
        if num_deletes else np.zeros(0, np.int64)

    taken = set(_pair_keys(src, dst).tolist())
    pairs: list[tuple[int, int]] = []
    dst_pool = (np.asarray(dst_rows, np.int64) if dst_rows is not None
                else np.arange(g.num_vertices))
    for _ in range(20 * num_inserts + 20):
        if len(pairs) >= num_inserts:
            break
        s = int(rng.integers(0, g.num_vertices))
        d = int(dst_pool[rng.integers(0, len(dst_pool))])
        k = (s << 32) | d
        if s != d and k not in taken:
            taken.add(k)
            pairs.append((s, d))
    ins_src = np.asarray([p[0] for p in pairs], np.int32)
    ins_dst = np.asarray([p[1] for p in pairs], np.int32)
    lo, hi = weight_range
    return EdgeDelta.concat(
        EdgeDelta.deletes(src[del_pos], dst[del_pos]),
        EdgeDelta.inserts(ins_src, ins_dst,
                          rng.uniform(lo, hi, len(pairs))))
