"""Per-slot visited-row-block bitsets: delta → minimal dirty slot set.

Soundness is a lockstep argument over the traversal loop.  A slot's
visited mask covers every frontier it ever had (``visited |= frontier``
precedes each expansion), and one expansion level only *reads*

* edges whose SOURCE row holds an active frontier color — rows inside
  visited row-blocks (the sparse engine gathers exactly the active
  row-blocks' edge blocks; the dense sweep reads everything but every
  other edge contributes zero and, for the work counters, counts zero);
* ``visited[dst]`` words — traversal state, not graph data.

So if a delta's touched source rows (`delta.AppliedDelta.touched_rows`,
which conservatively includes every row whose slot population, weights,
work-counter visibility, or LT selection CDF changed) intersect none of
the row-blocks a slot visited, replaying that slot's RNG stream on the
new graph reads only bit-identical inputs at every level — masks AND
counters reproduce exactly, by induction on levels.  Such slots are
*clean*; the rest are *dirty* and must be resampled.

The tracker stores one ``np.packbits`` row-block bitset per slot
(``ceil(NRB / 8)`` bytes — a 1M-vertex graph at 128-row tiles is ~1 KB
per slot) and re-derives bits lazily from the store's own batch list:
``sync()`` compares per-slot ``(batch_index, batch_epoch, graph_epoch)``
signatures and re-records only changed slots, so ordinary refresh /
shrink / grow traffic between deltas costs one host ``any`` per changed
slot, not a rebuild.
"""
from __future__ import annotations

import numpy as np

__all__ = ["DirtySlotTracker"]


class DirtySlotTracker:
    """Slot × row-block visited bitsets for one sketch store (or one
    replica group — replicas are bit-identical, so one tracker serves
    all of them)."""

    def __init__(self, num_vertices: int, tile_rows: int):
        self.num_vertices = int(num_vertices)
        self.tile_rows = int(tile_rows)
        self.num_row_blocks = -(-self.num_vertices // self.tile_rows)
        self._nbytes = -(-self.num_row_blocks // 8)
        self._bits = np.zeros((0, self._nbytes), np.uint8)
        # (batch_index, batch_epoch, graph_epoch) per recorded slot.
        self._sig: list[tuple[int, int, int]] = []
        self.deltas_seen = 0
        self.last_dirty_fraction = 0.0

    @classmethod
    def for_store(cls, store) -> "DirtySlotTracker":
        """Tracker sized for ``store`` (row-blocks = the store spec's
        ``tile_size``, the same 128-row tiles `FrontierIndex` groups by),
        synced to its current batches."""
        t = cls(store.graph.num_vertices, store.spec.tile_size)
        t.sync(store)
        return t

    # ----------------------------------------------------------- recording
    def _record_bits(self, visited) -> np.ndarray:
        """Packed row-block bitset of one (V, W) visited mask."""
        vis = np.asarray(visited)
        row_any = (vis != 0).any(axis=1)                    # (V,) bool
        pad = (-len(row_any)) % self.tile_rows
        if pad:
            row_any = np.concatenate([row_any, np.zeros(pad, bool)])
        blocks = row_any.reshape(-1, self.tile_rows).any(axis=1)
        return np.packbits(blocks)

    def sync(self, store) -> int:
        """Bring the tracker up to date with ``store``'s batch list;
        returns how many slots were (re)recorded.

        Cheap in the steady state: a slot re-records only when its
        signature changed — refresh/ensure swap batch indices, a graph
        epoch bump (delta applied) invalidates every slot's bits.
        """
        n = len(store.batches)
        graph_epoch = getattr(store, "graph_epoch", 0)
        if n > len(self._bits):
            self._bits = np.concatenate(
                [self._bits, np.zeros((n - len(self._bits), self._nbytes),
                                      np.uint8)])
        elif n < len(self._bits):
            self._bits = self._bits[:n].copy()
            del self._sig[n:]
        recorded = 0
        for i in range(n):
            sig = (store.batches[i].batch_index, store.batch_epochs[i],
                   graph_epoch)
            if i < len(self._sig) and self._sig[i] == sig:
                continue
            self._bits[i] = self._record_bits(store.batches[i].visited)
            if i < len(self._sig):
                self._sig[i] = sig
            else:
                self._sig.append(sig)
            recorded += 1
        return recorded

    # ------------------------------------------------------------- queries
    @property
    def num_slots(self) -> int:
        return len(self._bits)

    def dirty_slots(self, row_blocks) -> list[int]:
        """Slots whose visited row-blocks intersect ``row_blocks``."""
        rb = np.asarray(row_blocks, np.int64)
        if len(rb) and (rb.min() < 0 or rb.max() >= self.num_row_blocks):
            raise ValueError(f"row block outside [0, {self.num_row_blocks})")
        query_bits = np.zeros(self.num_row_blocks, bool)
        query_bits[rb] = True
        query = np.packbits(query_bits)
        hit = (self._bits & query).any(axis=1)
        return np.nonzero(hit)[0].tolist()

    def visited_blocks(self, slot: int) -> np.ndarray:
        """Sorted row-block ids slot ``slot``'s traversal visited."""
        bits = np.unpackbits(self._bits[slot])[:self.num_row_blocks]
        return np.nonzero(bits)[0]

    def note_delta(self, dirty: int) -> None:
        """Record one applied delta's dirty fraction for `stats`."""
        self.deltas_seen += 1
        self.last_dirty_fraction = dirty / max(self.num_slots, 1)

    def stats(self) -> dict:
        """Observability payload for `ServingTier.snapshot()`."""
        per_slot = (np.unpackbits(self._bits, axis=1)
                    [:, :self.num_row_blocks].sum(axis=1)
                    if len(self._bits) else np.zeros(0))
        return {
            "slots": self.num_slots,
            "row_blocks": self.num_row_blocks,
            "tracker_bytes": int(self._bits.nbytes),
            "mean_visited_blocks": float(per_slot.mean())
            if len(per_slot) else 0.0,
            "deltas_seen": self.deltas_seen,
            "last_dirty_fraction": self.last_dirty_fraction,
        }
