"""Sharded, manifest-described, atomic checkpointing with async writes and
elastic (mesh-shape-changing) restore.

Layout per step:  ``<dir>/step_<N>/{manifest.json, leaf_<i>.npy …}``
written into ``step_<N>.tmp`` then ``os.replace``d — a crashed writer can
never produce a half checkpoint that restore would accept.

Elastic restore: leaves are saved as *global* arrays with their tree paths;
``restore(..., shardings=...)`` re-places each leaf under ANY mesh (the new
mesh may have a different data/model split or lose the "pod" axis), which is
the resize story for elastic scaling.  On a real multi-host pod each host
would write its addressable shards; the manifest format already records
per-leaf shape/dtype so that extension is additive.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str):
    """Resolve extended dtypes (bfloat16, fp8) that numpy can't name."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def save(directory: str, step: int, tree: Any, *, keep: int = 3,
         blocking: bool = True,
         extra: Optional[dict] = None) -> threading.Thread | None:
    """Write checkpoint for ``step``.  ``blocking=False`` returns the writer
    thread (async checkpointing: training continues while the host writes;
    the arrays are fetched to host *before* returning so the device buffers
    are free to be donated).

    ``extra``: JSON-serializable metadata embedded in the manifest (e.g. the
    shard layout a sharded sketch pool was saved under) — readable without
    loading any leaf via ``read_manifest``.
    """
    paths, leaves, _ = _flatten(tree)
    host_leaves = [np.asarray(x) for x in leaves]      # device→host now

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra or {}, "leaves": []}
        for i, (p, a) in enumerate(zip(paths, host_leaves)):
            fname = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fname), a)
            manifest["leaves"].append(
                {"path": p, "file": fname, "shape": list(a.shape),
                 "dtype": str(a.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                          # atomic publish
        _cleanup(directory, keep)

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _cleanup(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def read_manifest(directory: str, step: Optional[int] = None) -> dict:
    """Manifest dict (step, extra, per-leaf path/shape/dtype) without
    touching any leaf file — cheap layout/metadata inspection."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, target_tree: Any, step: Optional[int] = None,
            shardings: Any = None, as_numpy: bool = False) -> tuple[Any, int]:
    """Restore into the structure of ``target_tree`` (values ignored).

    ``shardings``: optional matching tree of NamedShardings — pass the NEW
    mesh's shardings to perform an elastic reshape on restore.
    ``as_numpy``: leave unsharded leaves as host numpy arrays instead of
    transferring them to the default device — for callers that stage
    placement themselves (e.g. a sharded sketch pool restoring a snapshot
    bigger than any single device).
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, ref, sh in zip(paths, leaves, shard_leaves):
        entry = by_path.get(p)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(os.path.join(d, entry["file"]))
        want = _np_dtype(entry["dtype"])
        if arr.dtype != want:                       # np.save stored raw bits
            arr = arr.view(want)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{p}: shape {arr.shape} != {tuple(ref.shape)}")
        out.append(jax.device_put(arr, sh) if sh is not None
                   else (arr if as_numpy else jax.numpy.asarray(arr)))
    return jax.tree_util.tree_unflatten(treedef, out), step
