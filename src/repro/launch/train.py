"""Production training launcher: ``--arch`` selects any assigned config;
mesh shape adapts to the available devices (on a real pod the runtime
provides them; on CPU pass --smoke for a reduced config).

    python -m repro.launch.train --arch llama3.2-3b --smoke --steps 50
    python -m repro.launch.train --arch nemotron-4-340b \
        --mesh 16x16 --steps 1000 --checkpoint-dir /ckpts/nemotron
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.distributed import sharding_rules as rules
from repro.models.config import SHAPES
from repro.train import loop


def parse_mesh(spec: str | None):
    if spec is None:
        n = len(jax.devices())
        return jax.make_mesh((n,), ("data",))
    dims = tuple(int(x) for x in spec.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, help="e.g. 16x16 or 2x16x16")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch) if args.smoke else registry.get(args.arch)
    shp = SHAPES[args.shape]
    batch = args.batch or (8 if args.smoke else shp.global_batch)
    seq = args.seq_len or (64 if args.smoke else shp.seq_len)

    mesh = parse_mesh(args.mesh)
    rules.set_mesh(mesh if np.prod(list(mesh.shape.values())) > 1 else None)
    try:
        res = loop.train(cfg, batch=batch, seq_len=seq, steps=args.steps,
                         lr=args.lr, checkpoint_dir=args.checkpoint_dir,
                         ckpt_every=args.ckpt_every,
                         num_microbatches=args.microbatches)
        print(f"[launch.train] done: loss {res.losses[0]:.3f} → "
              f"{res.losses[-1]:.3f} over {res.steps_run} steps")
    finally:
        rules.set_mesh(None)


if __name__ == "__main__":
    main()
