import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax pins the device count at first
# init.  Only the dry-run gets 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating a single model byte:
  * proof the sharding config is coherent (compile succeeds, no sharding
    mismatch / unsupported collective),
  * ``memory_analysis``  — per-device bytes (does it fit HBM?),
  * ``cost_analysis``    — HLO FLOPs / bytes for §Roofline,
  * parsed collective bytes (repro.launch.hlo_analysis) for the third
    roofline term,
and appends a JSON record under benchmarks/results/.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--bpt]
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed import sharding_rules as rules
from repro.launch import hlo_analysis, specs
from repro.launch.mesh import make_production_mesh
from repro.models import decode as dec
from repro.models import model
from repro.models.config import LONG_CONTEXT_FAMILIES, SHAPES
from repro.train.step import make_train_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results"

# v5e roofline constants (per assignment)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

TRAIN_MICROBATCHES = {"train_4k": 8}


def _cell_skip_reason(cfg, shape_name: str):
    if shape_name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return ("full-attention arch: 512K decode requires sub-quadratic "
                "sequence mixing (DESIGN.md §5)")
    return None


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               cfg=None, mesh=None, shape=None) -> dict:
    """Lower + compile one cell.  ``cfg``/``mesh``/``shape`` overrides let
    tests exercise the identical code path at reduced scale."""
    cfg = cfg or registry.get(arch)
    shape = shape or SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    record = {"arch": arch, "shape": shape_name,
              "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
              "axes": list(mesh.axis_names), "chips": chips,
              "kind": shape.kind}
    skip = _cell_skip_reason(cfg, shape_name)
    if skip:
        record.update(status="skipped", reason=skip)
        return record

    rules.set_mesh(mesh)
    try:
        p_shapes = specs.param_specs(cfg)
        p_sh = rules.param_shardings(mesh, p_shapes)
        t0 = time.time()
        if shape.kind == "train":
            o_shapes = specs.opt_specs(cfg, p_shapes)
            o_sh = specs.opt_shardings(mesh, o_shapes, p_sh)
            b_shapes = specs.batch_specs(cfg, shape)
            b_sh = specs.batch_shardings(mesh, b_shapes)
            M = TRAIN_MICROBATCHES.get(shape_name, 1)
            step = make_train_step(cfg, lambda s: 3e-4, num_microbatches=M)
            fn = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_shapes, o_shapes, b_shapes)
        elif shape.kind == "prefill":
            b_shapes = specs.batch_specs(cfg, shape, with_labels=False)
            b_sh = specs.batch_shardings(mesh, b_shapes)

            def prefill_fn(params, batch):
                logits, _, caches = model.forward(params, cfg, batch,
                                                  collect_cache=True)
                return logits[:, -1:], caches

            fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(p_shapes, b_shapes)
        else:                                        # decode
            c_shapes, tok, cur = specs.decode_specs(cfg, shape)
            c_sh = specs.cache_shardings(mesh, c_shapes)
            b_sh = specs.batch_shardings(mesh, {"tokens": tok})["tokens"]

            def serve_step(params, caches, token, cur_len):
                return dec.decode_step(params, cfg, caches, token, cur_len)

            from jax.sharding import NamedSharding, PartitionSpec as P
            fn = jax.jit(serve_step,
                         in_shardings=(p_sh, c_sh, b_sh,
                                       NamedSharding(mesh, P())),
                         donate_argnums=(1,))
            lowered = fn.lower(p_shapes, c_shapes, tok, cur)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, (list, tuple)):    # jax 0.4.x: per-program list
            xla_cost = xla_cost[0] if xla_cost else {}
        text = compiled.as_text()
        cost = hlo_analysis.full_cost(text)      # loop-weighted (exact for
        # scans; XLA's cost_analysis counts while bodies once — see module)
        flops_per_device = cost["flops"]
        bytes_per_device = cost["bytes"]
        record.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=flops_per_device,
            bytes_per_device=bytes_per_device,
            xla_flops_body_once=float(xla_cost.get("flops", 0.0)),
            collective=cost["collective"],
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            roofline=roofline_terms(cfg, shape, flops_per_device,
                                    bytes_per_device,
                                    cost["collective"]["per_device_bytes"],
                                    chips),
        )
    except Exception as e:                           # record the failure
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    finally:
        rules.set_mesh(None)
    return record


def roofline_terms(cfg, shape, flops_dev, bytes_dev, coll_dev, chips):
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_collective = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    n_active = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens
    hlo_total = flops_dev * chips
    terms.update(
        dominant=dominant.replace("_s", ""),
        model_flops=model_flops,
        hlo_flops_total=hlo_total,
        useful_fraction=(model_flops / hlo_total) if hlo_total else None,
        bound_step_time_s=max(terms["compute_s"], terms["memory_s"],
                              terms["collective_s"]),
    )
    return terms


# ------------------------------------------------------------- BPT workloads
def lower_bpt_cell(which: str, *, multi_pod: bool) -> dict:
    """The paper's own workload on the production mesh (DESIGN.md §3)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    record = {"arch": f"fused-bpt-{which}", "shape": which,
              "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
              "axes": list(mesh.axis_names), "chips": chips, "kind": "bpt"}
    try:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed import traversal as dtrav
        from repro.graph import csr, partition as part_lib

        if which == "sample":
            # soc-LiveJournal1 scale, graph replicated (paper's strategy):
            V, E, C = 4_847_571, 68_993_773, 512
            g = csr.Graph(
                indptr=jax.ShapeDtypeStruct((V + 1,), jnp.int32),
                src=jax.ShapeDtypeStruct((E,), jnp.int32),
                dst=jax.ShapeDtypeStruct((E,), jnp.int32),
                prob=jax.ShapeDtypeStruct((E,), jnp.float32),
                num_vertices=V, num_edges=E)
            dp_axes = rules.fsdp_axes(mesh)
            B = int(np.prod([mesh.shape[a] for a in dp_axes])) * \
                mesh.shape["model"]
            starts = jax.ShapeDtypeStruct((B, C), jnp.int32)
            seeds = jax.ShapeDtypeStruct((B,), jnp.uint32)
            all_axes = tuple(mesh.axis_names)
            sh = NamedSharding(mesh, P(all_axes))
            rep = NamedSharding(mesh, P())
            fn = jax.jit(
                lambda g, s, sd: dtrav.sample_parallel_fn(g, s, sd, C,
                                                          max_levels=64),
                in_shardings=(jax.tree.map(lambda _: rep, g), sh, sh),
                out_shardings=sh)
            lowered = fn.lower(g, starts, seeds)
        elif which in ("graph", "graph_q"):          # graph parallel
            # web-BerkStan scale tiled graph, 1-D partition over "model".
            # tiles_per_shard from the measured cluster-reordered density
            # (benchmarks/bench_reorder.py → ~32 edges/tile): E/32/S ≈ 1900.
            V, E, C, T = 685_230, 7_600_595, 64, 128
            S = mesh.shape["model"]
            nb = -(-(-(-V // T)) // S) * S           # blocks, shard-divisible
            nb_loc = nb // S
            tiles_per_shard = 1900
            starts = jax.ShapeDtypeStruct((C,), jnp.int32)
            if which == "graph":
                ptg = part_lib.PartitionedTiledGraph(
                    prob=jax.ShapeDtypeStruct((S, tiles_per_shard, T, T),
                                              jnp.float32),
                    edge_id=jax.ShapeDtypeStruct((S, tiles_per_shard, T, T),
                                                 jnp.uint32),
                    tile_src=jax.ShapeDtypeStruct((S, tiles_per_shard),
                                                  jnp.int32),
                    tile_dst=jax.ShapeDtypeStruct((S, tiles_per_shard),
                                                  jnp.int32),
                    first_of_dst=jax.ShapeDtypeStruct((S, tiles_per_shard),
                                                      jnp.int32),
                    num_vertices=V, num_edges=E, tile_size=T, num_shards=S,
                    blocks_per_shard=nb_loc)
                fn = jax.jit(lambda p, s: dtrav.graph_parallel_traversal(
                    p, s, C, 7, mesh, max_levels=64))
                lowered = fn.lower(ptg, starts)
            else:
                # §Perf B1: quantized tiles — u8 threshold, no edge-id
                # plane (8× tile bytes), 8 hashes/word instead of 32.
                from jax.sharding import PartitionSpec as P

                from repro.core import bitmask, tiles as tiles_lib
                from repro.core.traversal import init_frontier
                from repro.kernels import fused_expand_q as feq

                q8 = jax.ShapeDtypeStruct((S, tiles_per_shard, T, T),
                                          jnp.uint8)
                ts = jax.ShapeDtypeStruct((S, tiles_per_shard), jnp.int32)
                td = jax.ShapeDtypeStruct((S, tiles_per_shard), jnp.int32)
                vp = S * nb_loc * T

                def body(q8, ts, td, fr_local):
                    seed = jnp.uint32(7)

                    def cond(c):
                        fr, _, lvl = c
                        anyb = jax.lax.psum(
                            bitmask.any_set(fr).astype(jnp.int32), "model")
                        return jnp.logical_and(anyb > 0, lvl < 64)

                    def step(c):
                        fr, vis, lvl = c
                        vis = vis | fr
                        fr_g = jax.lax.all_gather(fr, "model", tiled=True)
                        nf = feq.fused_expand_q_ref(
                            q8[0], ts[0], td[0], fr_g, vis, seed,
                            lvl.astype(jnp.uint32))
                        return nf, vis, lvl + 1

                    fr, vis, lvl = jax.lax.while_loop(
                        cond, step,
                        (fr_local, jnp.zeros_like(fr_local), jnp.int32(0)))
                    return vis | fr, lvl

                from repro.distributed.compat import shard_map
                fn = shard_map(
                    body, mesh=mesh,
                    in_specs=(P("model"), P("model"), P("model"),
                              P("model")),
                    out_specs=(P("model"), P()), check=False)

                def run(q8, ts, td, starts):
                    fr = tiles_lib.pad_mask_rows(
                        init_frontier(V, C, starts), vp)
                    return fn(q8, ts, td, fr)

                lowered = jax.jit(run).lower(q8, ts, td, starts)

        t0 = time.time()
        compiled = lowered.compile()
        cost = hlo_analysis.full_cost(compiled.as_text())
        mem = compiled.memory_analysis()
        flops_dev = cost["flops"]
        bytes_dev = cost["bytes"]
        coll = cost["collective"]
        record.update(
            status="ok", compile_s=round(time.time() - t0, 1),
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            collective=coll,
            memory={"argument_bytes": getattr(mem, "argument_size_in_bytes",
                                              None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None)},
            roofline={
                "compute_s": flops_dev / PEAK_FLOPS,
                "memory_s": bytes_dev / HBM_BW,
                "collective_s": coll["per_device_bytes"] / ICI_BW,
            })
        r = record["roofline"]
        r["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: r[k]).replace("_s", "")
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    return record


def save_record(record: dict, tag: str = ""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    name = (f"dryrun_{record['arch']}_{record['shape']}_"
            f"{record['mesh']}{tag}.json")
    with open(RESULTS_DIR / name, "w") as f:
        json.dump(record, f, indent=1)
    return name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCHS + ["all"])
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--bpt", action="store_true",
                    help="lower the paper's fused-BPT workloads")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.bpt:
        for which in ("sample", "graph", "graph_q"):
            rec = lower_bpt_cell(which, multi_pod=args.multi_pod)
            print(json.dumps({k: rec.get(k) for k in
                              ("arch", "mesh", "status", "roofline",
                               "error")}, indent=1))
            save_record(rec)
        if not (args.all or args.arch):
            return

    archs = registry.ARCHS if (args.all or args.arch in (None, "all")) \
        else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    for arch in archs:
        for shape in shapes:
            rec = lower_cell(arch, shape, multi_pod=args.multi_pod)
            status = rec["status"]
            extra = (rec["roofline"]["dominant"] if status == "ok"
                     else rec.get("reason", rec.get("error", "")))
            print(f"[dryrun] {arch:28s} {shape:12s} {rec['mesh']:9s} "
                  f"{status:8s} {extra}")
            save_record(rec)
            cells.append(rec)
    ok = sum(1 for c in cells if c["status"] == "ok")
    sk = sum(1 for c in cells if c["status"] == "skipped")
    print(f"[dryrun] {ok} ok / {sk} skipped / "
          f"{len(cells) - ok - sk} failed of {len(cells)}")


if __name__ == "__main__":
    main()
