"""Influence-query serving launcher: sample a sketch pool, serve queries.

    python -m repro.launch.serve_influence --smoke

Smoke mode exercises the full pool lifecycle on a synthetic graph: sample →
serve a mixed micro-batched query load (top-k, σ(S), marginal-gain) →
refresh an epoch → persist → restore bit-identically → cross-check that
offline ``run_imm`` routed through the shared incremental max-cover kernel
and the pool reproduces the pool-less seeds exactly.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core import imm
from repro.graph import generators
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


def build_store(args) -> SketchStore:
    g = generators.powerlaw_cluster(args.n, args.degree, prob=args.prob,
                                    seed=args.graph_seed)
    cfg = PoolConfig(num_colors=args.colors, max_batches=args.max_batches,
                     memory_budget_mb=args.memory_budget_mb,
                     master_seed=args.master_seed)
    store = SketchStore(g, cfg)
    store.ensure(args.batches)
    return store


def serve_mixed_batch(store: SketchStore, engine: QueryEngine,
                      batcher: MicroBatcher, k: int, num_queries: int):
    """One micro-batched flush mixing all three query kinds."""
    rng = np.random.default_rng(0)
    n = store.graph.num_vertices
    tickets = {"top_k": [batcher.submit_top_k(k)]}
    tickets["sigma"] = [
        batcher.submit_sigma(rng.integers(0, n, rng.integers(1, 5)).tolist())
        for _ in range(num_queries)]
    tickets["marginal"] = [
        batcher.submit_marginal(rng.integers(0, n, 2).tolist())
        for _ in range(num_queries)]
    t0 = time.perf_counter()
    results = batcher.flush()
    dt = time.perf_counter() - t0
    return tickets, results, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="full lifecycle check on a synthetic graph")
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--degree", type=float, default=6.0)
    ap.add_argument("--prob", type=float, default=0.25)
    ap.add_argument("--graph-seed", type=int, default=7)
    ap.add_argument("--colors", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8,
                    help="initial pool size (fused batches)")
    ap.add_argument("--max-batches", type=int, default=64)
    ap.add_argument("--memory-budget-mb", type=float, default=None)
    ap.add_argument("--master-seed", type=int, default=0)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--ckpt-dir", default=None,
                    help="pool snapshot directory (default: temp dir)")
    args = ap.parse_args()

    t0 = time.time()
    store = build_store(args)
    print(f"[serve_influence] pool: {len(store.batches)} batches × "
          f"{store.num_colors} colors = {store.num_samples} RRR sets "
          f"({store.bytes_per_batch * len(store.batches) / 2**20:.2f} MiB, "
          f"capacity {store.capacity} batches)")

    engine = QueryEngine(store)
    batcher = MicroBatcher(engine, cache=ResultCache())
    tickets, results, dt = serve_mixed_batch(store, engine, batcher,
                                             args.k, args.queries)
    seeds, sigma_topk = results[tickets["top_k"][0]]
    n_served = sum(len(v) for v in tickets.values())
    print(f"[serve_influence] mixed batch: {n_served} queries in "
          f"{batcher.dispatches} dispatches, {dt:.2f}s")
    print(f"  top-{args.k}: seeds={seeds.tolist()} σ̂={sigma_topk:.1f}")
    print(f"  σ(S) samples: "
          f"{[round(float(results[t]), 1) for t in tickets['sigma'][:3]]}")
    gains = results[tickets["marginal"][0]]
    print(f"  marginal: best vertex {int(np.argmax(gains))} "
          f"Δσ̂={float(np.max(gains)):.1f}")

    if not args.smoke:
        return

    # ---- cached re-serve + epoch refresh invalidation
    before = batcher.dispatches
    serve_mixed_batch(store, engine, batcher, args.k, args.queries)
    assert batcher.dispatches == before, "identical batch must be all hits"
    print(f"[smoke] re-serve: 100% cache hits "
          f"({batcher.cache.hits} hits / {batcher.cache.misses} misses)")
    slots = store.refresh(0.25)
    _, results2, _ = serve_mixed_batch(store, engine, batcher,
                                       args.k, args.queries)
    assert batcher.dispatches > before, "refresh must invalidate cache"
    print(f"[smoke] refresh: epoch {store.epoch}, resampled slots {slots}, "
          f"cache invalidated")

    # ---- persist + bit-identical restore
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="sketch_pool_")
    store.save(ckpt)
    restored = SketchStore.restore(ckpt, store.graph,
                                   PoolConfig(num_colors=args.colors,
                                              max_batches=args.max_batches))
    assert np.array_equal(np.asarray(store.visited_stack()),
                          np.asarray(restored.visited_stack()))
    assert restored.epoch == store.epoch
    assert restored.next_batch_index == store.next_batch_index
    assert [b.batch_index for b in restored.batches] == \
        [b.batch_index for b in store.batches]
    r_seeds, _ = QueryEngine(restored).top_k(args.k)
    s_seeds, _ = engine.top_k(args.k)
    assert np.array_equal(r_seeds, s_seeds)
    print(f"[smoke] persist/restore: bit-identical pool at "
          f"{os.path.join(ckpt, f'step_{store.epoch:08d}')}")

    # ---- offline IMM through the shared incremental kernel + pool
    g = store.graph
    res_plain = imm.run_imm(g, k=args.k, eps=0.5, num_colors=args.colors,
                            master_seed=args.master_seed, theta_cap=1024)
    fresh = SketchStore(g, PoolConfig(num_colors=args.colors,
                                      max_batches=args.max_batches,
                                      master_seed=args.master_seed))
    res_pool = imm.run_imm(g, k=args.k, eps=0.5, num_colors=args.colors,
                           master_seed=args.master_seed, theta_cap=1024,
                           pool=fresh)
    assert np.array_equal(res_plain.seeds, res_pool.seeds)
    assert res_plain.coverage == res_pool.coverage
    ref_seeds, ref_cov = imm.greedy_max_cover_ref(
        fresh.visited_stack()[:res_plain.num_batches], args.k, args.colors)
    assert np.array_equal(res_plain.seeds, ref_seeds)
    print(f"[smoke] offline run_imm: pool-routed seeds == pool-less seeds "
          f"== host-loop reference ({res_plain.seeds.tolist()})")
    print(f"[smoke] PASS in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
