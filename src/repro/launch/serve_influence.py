"""Influence-query serving launcher: sample a sketch pool, serve queries.

    python -m repro.launch.serve_influence --smoke
    python -m repro.launch.serve_influence --smoke --diffusion lt
    python -m repro.launch.serve_influence --smoke --sampler-backend kernel
    python -m repro.launch.serve_influence --smoke --mesh 8x1 --async
    python -m repro.launch.serve_influence --smoke --mesh 2x4 \
        --sampler-backend graph_parallel

``--diffusion ic|lt`` and ``--sampler-backend dense|tiled|kernel|
data_parallel|graph_parallel`` select the `repro.sampling.SamplerSpec` the
pool samples under; ``--frontier sparse`` arms the sparse-frontier
execution mode (per-level active-tile compaction, and on graph_parallel a
compacted frontier all-gather — bit-identical to dense, work proportional
to the live frontier; ``--frontier-capacity`` tunes its buckets).  Backend defaults: ``dense`` single-device; on a
``--mesh DxM`` mesh, ``data_parallel`` when M == 1 (shard_map batch blocks,
each shard's slots built on its own devices) and **graph parallelism when
M > 1**: the graph's destination rows shard over the ``model`` axis (size
M), batches over ``data`` (size D), with a frontier all-gather per level —
the regime for graphs too big for one device.

Single-device smoke exercises the full pool lifecycle on a synthetic
graph: sample → serve a mixed micro-batched query load (top-k, σ(S),
marginal-gain) → refresh an epoch → persist → restore bit-identically →
cross-check that offline ``run_imm`` routed through the shared incremental
max-cover kernel and the pool reproduces the pool-less seeds exactly.

``--mesh DxM`` serves from a mesh-sharded pool through the distributed
engine (slots sharded over the ``data`` axis, one psum per coverage
reduction).  With ``--smoke`` the launcher forces that many host CPU
devices — the same trick the multi-device equivalence tests use — so the
full distributed path smokes on a laptop (explicit ``JAX_PLATFORMS=tpu``
etc. opts out; without ``--smoke``, real devices are required).
``--async`` fronts the batcher with the deadline-batched `AsyncFrontEnd`
and drives it from concurrent client threads.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import threading
import time

import numpy as np

from repro.core import imm
from repro.graph import csr, generators
from repro.sampling import SamplerSpec
from repro.serve.influence import (MicroBatcher, PoolConfig, QueryEngine,
                                   ResultCache, SketchStore)


def _parse_mesh(spec: str) -> tuple[int, int]:
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DxM (e.g. 8x1), got {spec!r}")
    return d, m


def _force_cpu_host_devices(n: int) -> None:
    """``--smoke --mesh``: run the distributed path on ``n`` forced host
    CPU devices (the multi-device test-suite trick), whatever the host has.

    Delegates to `repro.launch.accel` (the one owner of XLA-env mutation);
    must run before jax initializes its backend (imports above don't — the
    backend materializes on the first device query/op).  An explicit
    accelerator request (``JAX_PLATFORMS=tpu``/``cuda``...) opts out;
    production runs don't pass ``--smoke`` and use real devices.
    """
    from repro.launch import accel
    accel.set_host_device_count(n)


def build_graph(args):
    g = generators.powerlaw_cluster(args.n, args.degree, prob=args.prob,
                                    seed=args.graph_seed)
    # Dedupe unconditionally: the block-sparse tile layout needs parallel
    # edges merged, and using ONE edge list for every backend keeps the
    # facade's cross-backend bit-identity contract — the same CLI args
    # must sample the same bits whether the backend is dense or kernel
    # (a pool saved under one must refresh identically under another).
    return csr.dedupe(g)


def build_config(args, *, backend: str | None = None) -> PoolConfig:
    """One place maps CLI knobs → PoolConfig (with its `SamplerSpec`) for
    BOTH serving paths."""
    backend = backend or args.sampler_backend or "dense"
    spec = SamplerSpec(diffusion=args.diffusion, backend=backend,
                       num_colors=args.colors, master_seed=args.master_seed,
                       frontier=args.frontier,
                       frontier_capacity=args.frontier_capacity)
    return PoolConfig(max_batches=args.max_batches,
                      memory_budget_mb=args.memory_budget_mb, spec=spec)


def dense_variant(cfg: PoolConfig) -> PoolConfig:
    """Same pool under the single-device dense backend AND dense frontier
    (reference path) — with ``--frontier sparse`` the smoke's bit-identity
    assertions become a sparse-vs-dense equivalence check too."""
    return dataclasses.replace(
        cfg, spec=cfg.spec.replace(backend="dense", frontier="dense"))


def build_store(args) -> SketchStore:
    store = SketchStore(build_graph(args), build_config(args))
    store.ensure(args.batches)
    return store


def serve_mixed_batch(store, engine, batcher, k: int, num_queries: int):
    """One micro-batched flush mixing all three query kinds."""
    rng = np.random.default_rng(0)
    n = store.graph.num_vertices
    tickets = {"top_k": [batcher.submit_top_k(k)]}
    tickets["sigma"] = [
        batcher.submit_sigma(rng.integers(0, n, rng.integers(1, 5)).tolist())
        for _ in range(num_queries)]
    tickets["marginal"] = [
        batcher.submit_marginal(rng.integers(0, n, 2).tolist())
        for _ in range(num_queries)]
    t0 = time.perf_counter()
    results = batcher.flush()
    dt = time.perf_counter() - t0
    return tickets, results, dt


def _print_mixed(tag, args, tickets, results, dispatches, dt):
    seeds, sigma_topk = results[tickets["top_k"][0]]
    n_served = sum(len(v) for v in tickets.values())
    print(f"[{tag}] mixed batch: {n_served} queries in "
          f"{dispatches} dispatches, {dt:.2f}s")
    print(f"  top-{args.k}: seeds={seeds.tolist()} σ̂={sigma_topk:.1f}")
    print(f"  σ(S) samples: "
          f"{[round(float(results[t]), 1) for t in tickets['sigma'][:3]]}")
    gains = results[tickets["marginal"][0]]
    print(f"  marginal: best vertex {int(np.argmax(gains))} "
          f"Δσ̂={float(np.max(gains)):.1f}")


# ------------------------------------------------------------ single device
def run_single(args) -> None:
    t0 = time.time()
    if args.sampler_backend in ("data_parallel", "graph_parallel"):
        raise SystemExit(f"--sampler-backend {args.sampler_backend} needs "
                         "a mesh; add --mesh DxM (M>1 for graph_parallel)")
    store = build_store(args)
    print(f"[serve_influence] pool: {len(store.batches)} batches × "
          f"{store.num_colors} colors = {store.num_samples} RRR sets "
          f"({store.bytes_per_batch * len(store.batches) / 2**20:.2f} MiB, "
          f"capacity {store.capacity} batches; diffusion "
          f"{store.spec.diffusion!r}, backend {store.spec.backend!r})")

    engine = QueryEngine(store)
    batcher = MicroBatcher(engine, cache=ResultCache())
    tickets, results, dt = serve_mixed_batch(store, engine, batcher,
                                             args.k, args.queries)
    _print_mixed("serve_influence", args, tickets, results,
                 batcher.dispatches, dt)

    if not args.smoke:
        if args.async_frontend:
            _async_demo(args, engine)
        return

    # ---- cached re-serve + epoch refresh invalidation
    before = batcher.dispatches
    serve_mixed_batch(store, engine, batcher, args.k, args.queries)
    assert batcher.dispatches == before, "identical batch must be all hits"
    print(f"[smoke] re-serve: 100% cache hits "
          f"({batcher.cache.hits} hits / {batcher.cache.misses} misses)")
    slots = store.refresh(0.25)
    serve_mixed_batch(store, engine, batcher, args.k, args.queries)
    assert batcher.dispatches > before, "refresh must invalidate cache"
    print(f"[smoke] refresh: epoch {store.epoch}, resampled slots {slots}, "
          f"cache invalidated")

    # ---- persist + bit-identical restore
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="sketch_pool_")
    store.save(ckpt)
    restored = SketchStore.restore(ckpt, store.graph, build_config(args))
    assert np.array_equal(np.asarray(store.visited_stack()),
                          np.asarray(restored.visited_stack()))
    assert restored.epoch == store.epoch
    assert restored.next_batch_index == store.next_batch_index
    assert [b.batch_index for b in restored.batches] == \
        [b.batch_index for b in store.batches]
    r_seeds, _ = QueryEngine(restored).top_k(args.k)
    s_seeds, _ = engine.top_k(args.k)
    assert np.array_equal(r_seeds, s_seeds)
    print(f"[smoke] persist/restore: bit-identical pool at "
          f"{os.path.join(ckpt, f'step_{store.epoch:08d}')}")

    # ---- offline IMM through the shared incremental kernel + pool
    g = store.graph
    res_plain = imm.run_imm(g, k=args.k, eps=0.5, spec=store.spec,
                            theta_cap=1024)
    fresh = SketchStore(g, build_config(args))
    res_pool = imm.run_imm(g, k=args.k, eps=0.5, spec=store.spec,
                           theta_cap=1024, pool=fresh)
    assert np.array_equal(res_plain.seeds, res_pool.seeds)
    assert res_plain.coverage == res_pool.coverage
    ref_seeds, ref_cov = imm.greedy_max_cover_ref(
        fresh.visited_stack()[:res_plain.num_batches], args.k, args.colors)
    assert np.array_equal(res_plain.seeds, ref_seeds)
    print(f"[smoke] offline run_imm: pool-routed seeds == pool-less seeds "
          f"== host-loop reference ({res_plain.seeds.tolist()})")
    # Async demo last: its background refresh mutates the store, which
    # would invalidate the bit-identity assertions above.
    if args.async_frontend:
        _async_demo(args, engine)
    print(f"[smoke] PASS in {time.time() - t0:.1f}s")


# -------------------------------------------------------------- distributed
def run_distributed(args, shape: tuple[int, int]) -> None:
    import jax
    from repro.serve.distributed import (DistributedQueryEngine,
                                         ShardedSketchStore)

    t0 = time.time()
    d, m = shape
    if jax.device_count() < d * m:
        raise SystemExit(f"mesh {d}x{m} wants {d * m} devices, have "
                         f"{jax.device_count()}")
    # Mesh backend defaults: data_parallel shards batch blocks; M > 1
    # activates graph parallelism — rows over 'model', batches over 'data'.
    backend = args.sampler_backend or \
        ("graph_parallel" if m > 1 else "data_parallel")
    if backend == "graph_parallel" and m < 2:
        raise SystemExit("--sampler-backend graph_parallel wants a model "
                         f"axis: use --mesh DxM with M>1 (got {d}x{m})")
    mesh = jax.make_mesh((d, m), ("data", "model")) if m > 1 else \
        jax.make_mesh((d,), ("data",))
    g = build_graph(args)
    cfg = build_config(args, backend=backend)
    store = ShardedSketchStore(g, cfg, mesh)
    store.ensure(args.batches)
    layout = f"data={d}" + (f" × model={m}" if m > 1 else "")
    per_dev = (store.bytes_per_batch * store.padded_batches
               / store.num_shards / store.row_shards / 2**20)
    print(f"[serve_influence] sharded pool: {len(store.batches)} batches × "
          f"{store.num_colors} colors over {store.num_shards} shards "
          f"({layout} mesh; {per_dev:.2f} "
          f"MiB/device"
          + (f", visited rows V/{store.row_shards} per device"
             if store.row_shards > 1 else "")
          + f", capacity {store.capacity} batches; diffusion "
          f"{store.spec.diffusion!r}, backend {store.spec.backend!r})")

    engine = DistributedQueryEngine(store)
    batcher = MicroBatcher(engine, cache=ResultCache())
    tickets, results, dt = serve_mixed_batch(store, engine, batcher,
                                             args.k, args.queries)
    _print_mixed("distributed", args, tickets, results,
                 batcher.dispatches, dt)

    if not args.smoke:
        if args.async_frontend:
            _async_demo(args, engine)
        return

    # ---- sharded ≡ single-device, bit for bit (and, with a mesh backend
    # — data_parallel block builds or graph_parallel row-partitioned
    # traversals — distributed sampling ≡ dense per-batch)
    single = SketchStore(g, dense_variant(cfg))
    single.ensure(len(store.batches))
    ref = QueryEngine(single)
    s1, sig1 = ref.top_k(args.k)
    s8, sig8 = engine.top_k(args.k)
    assert np.array_equal(s1, s8) and sig1 == sig8
    sets = [[1, 2], [5, 50, 99]]
    assert np.array_equal(ref.sigma(sets), engine.sigma(sets))
    print(f"[smoke] sharded == single-device: top-{args.k} seeds "
          f"{s8.tolist()}, σ̂={sig8:.1f} bit-identical across "
          f"{store.num_shards} shards")

    # ---- row-sharded pool layout (M > 1): each device holds V/M rows
    if store.row_shards > 1:
        stack = store.visited_stack()
        vloc = store.padded_vertices // store.row_shards
        assert stack.shape[:2] == (store.padded_batches,
                                   store.padded_vertices), stack.shape
        blk = next(iter(stack.addressable_shards)).data
        assert blk.shape[1] == vloc, (blk.shape, vloc)
        print(f"[smoke] row-sharded stack {tuple(stack.shape)}: "
              f"{vloc} visited rows/device "
              f"(= V/{store.row_shards}), queries still bit-identical")
    if store.spec.backend == "graph_parallel" and \
            getattr(store.sampler, "last_gather_words", None) is not None:
        gw = np.asarray(store.sampler.last_gather_words).sum(0)
        print(f"[smoke] frontier exchange ({store.spec.frontier}): "
              f"{[int(x) for x in gw[:6]]}... packed words/level over "
              f"the model axis, {int(gw.sum())} total")

    # ---- elastic restore under a different mesh shape
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="sharded_pool_")
    store.save(ckpt)
    d2 = max(d // 2, 1)
    mesh2 = jax.make_mesh((d2, (d * m) // d2), ("data", "model"))
    restored = ShardedSketchStore.restore(ckpt, g, cfg, mesh2)
    r_seeds, r_sig = DistributedQueryEngine(restored).top_k(args.k)
    assert np.array_equal(s8, r_seeds) and sig8 == r_sig
    print(f"[smoke] elastic restore: {store.num_shards} shards → "
          f"{restored.num_shards} shards, answers bit-identical "
          f"(layout {ShardedSketchStore.saved_layout(ckpt)['shard_layout']})")

    # ---- epoch refresh: block resample ≡ dense per-batch resample
    t_r = time.perf_counter()
    slots_sharded = store.refresh(0.5)
    dt_r = time.perf_counter() - t_r
    slots_single = single.refresh(0.5)
    assert slots_sharded == slots_single
    rs, rsig = engine.top_k(args.k)
    r1, rsig1 = ref.top_k(args.k)
    assert np.array_equal(rs, r1) and rsig == rsig1
    print(f"[smoke] refresh: {len(slots_sharded)} slots resampled via "
          f"{store.spec.backend!r} in {dt_r:.2f}s, still bit-identical to "
          "the dense single-device pool")
    # Async demo last: its background refresh mutates the store, which
    # would invalidate the bit-identity assertions above.
    if args.async_frontend:
        _async_demo(args, engine)
    print(f"[smoke] PASS in {time.time() - t0:.1f}s")


# --------------------------------------------------------------------- tier
def run_tier(args) -> None:
    """Production serving tier: admission → replicas → autoscale → SLOs.

    ``--tier --tenants N --replicas R [--autoscale]`` builds a warm pool,
    fronts it with `repro.serve.tier.ServingTier` (N tenants with mixed
    quotas over R bit-identical replicas), drives a burst of per-tenant
    client threads, and prints the metrics snapshot.  With ``--smoke`` it
    asserts the tier acceptance contract: sheds carry retry-after,
    in-quota answers are bit-identical to a direct single-engine
    `QueryEngine` on the same pool epoch, a mid-stream refresh never
    yields a mixed-epoch reply, and (with ``--autoscale``) a scale event
    is an epoch swap, not a rebuild.
    """
    from repro.serve.tier import EpochMixError, ServingTier, ShedError

    if args.sampler_backend in ("data_parallel", "graph_parallel"):
        raise SystemExit("--tier serves single-device replicas; mesh "
                         "backends arrive with cross-process replicas")
    t0 = time.time()
    store = build_store(args)
    reference = QueryEngine(store.clone())      # same epoch, direct engine
    autoscale = None
    if args.autoscale:
        autoscale = {"k": args.k, "target_eps": args.target_eps,
                     "target_p99_ms": args.target_p99_ms}
    tier = ServingTier.build(store, replicas=args.replicas,
                             quota_qps=args.quota_qps,
                             autoscale=autoscale,
                             default_deadline=args.deadline)
    tenants = [f"tenant{i}" for i in range(args.tenants)]
    # Tenant 0 is deliberately starved so the shed path exercises under
    # any load: 1 token, slow refill.
    tier.set_quota(tenants[0], rate=0.5, burst=1)
    print(f"[tier] {args.replicas} replicas × {len(store.batches)} batches, "
          f"{args.tenants} tenants (quota {args.quota_qps} qps, "
          f"{tenants[0]} pinned to 0.5 qps)"
          + (", autoscale armed" if autoscale else ""))

    n = store.graph.num_vertices
    rng = np.random.default_rng(2)
    queries = [rng.integers(0, n, 3).tolist() for _ in range(8)]
    sheds, futs = [], []            # futs: (query, future) per admitted
    for q in queries:
        for t in tenants:
            try:
                futs.append((q, tier.submit_sigma(t, q)))
            except ShedError as e:
                sheds.append(e)
    values = tier.gather([f for _, f in futs])
    print(f"[tier] {len(futs)} admitted / {len(sheds)} shed; "
          f"pending per replica {tier.group.pending()}")

    if not args.smoke:
        print(tier.to_json(indent=1))
        tier.close()
        return

    # ---- sheds carry retry-after; in-quota tenants unaffected
    assert sheds, "starved tenant must shed under this burst"
    assert all(e.retry_after > 0 and e.tenant == tenants[0] for e in sheds)
    # ---- in-quota answers ≡ direct single-engine QueryEngine, same epoch
    for (q, _), val in zip(futs, values):
        assert val == reference.sigma([q])[0], \
            "tier answers must be bit-identical to the direct engine"
    print(f"[smoke] {len(values)} in-quota answers bit-identical to direct "
          f"QueryEngine; {len(sheds)} sheds with retry-after "
          f"{sheds[0].retry_after:.2f}s")

    # ---- mid-stream refresh: epoch guard refuses mixed replies
    before = tier.submit_sigma(tenants[-1], queries[0])
    before.result()
    tier.group.replicas[0].frontend.refresh_now(0.5)    # half a sweep
    after = tier.submit_sigma(tenants[-1], queries[1],
                              deadline=0.0)
    after.result()
    mixed = False
    try:
        tier.gather([before, after])
    except EpochMixError as e:
        mixed = True
        assert len(e.versions) == 2
    assert mixed or before.pool_version == after.pool_version, \
        "mixed-epoch replies must be refused"
    # finish the sweep → replicas re-converge bit-identically
    for r in tier.group.replicas[1:]:
        r.frontend.refresh_now(0.5)
    assert tier.group.consistent()
    stacks = [np.asarray(r.store.visited_stack())
              for r in tier.group.replicas]
    assert all(np.array_equal(stacks[0], s) for s in stacks[1:])
    print(f"[smoke] mid-stream refresh: mixed-epoch gather "
          f"{'refused (EpochMixError)' if mixed else 'not provoked'}; "
          f"replicas re-converged bit-identically at "
          f"{tier.group.versions()[0]}")

    # ---- autoscale: scale events swap epochs, never cold-rebuild
    if tier.autoscaler is not None:
        b0 = tier.group.num_batches
        decision = tier.autoscaler.step()
        assert tier.group.consistent()
        print(f"[smoke] autoscale: {decision.action} {b0} → "
              f"{tier.group.num_batches} batches "
              f"(ε̂={decision.eps_bound}, θ={decision.theta}) — {decision.reason}")

    snap = tier.snapshot()
    assert snap["totals"]["shed"] == len(sheds)
    assert snap["latency"]["all"]["count"] >= len(futs)
    print(f"[smoke] metrics: shed_rate={snap['totals']['shed_rate']:.2f}, "
          f"p99={snap['latency']['all']['p99'] * 1e3:.1f}ms over "
          f"{snap['latency']['all']['count']} queries")
    tier.close()
    print(f"[smoke] PASS in {time.time() - t0:.1f}s")


# ---------------------------------------------------------------- streaming
def run_stream(args, shape: tuple[int, int] | None = None) -> None:
    """``--stream-smoke``: mutate the graph mid-serve, refresh
    incrementally, assert bit-identity against a cold rebuild.

    Default mode serves through the tier (2 replicas) and drives
    `ServingTier.apply_delta`; with ``--mesh Dx1`` the delta lands on a
    `ShardedSketchStore` instead and the refreshed sharded pool is
    checked against BOTH a cold rebuild and a single-device pool on the
    mutated graph.
    """
    from repro import stream

    t0 = time.time()
    rng = np.random.default_rng(args.graph_seed + 1)

    if shape is not None:
        import jax
        from repro.serve.distributed import (DistributedQueryEngine,
                                             ShardedSketchStore)
        d, m = shape
        if m != 1:
            raise SystemExit("--stream-smoke --mesh wants Dx1 (deltas on "
                             "graph_parallel pools arrive later)")
        mesh = jax.make_mesh((d,), ("data",))
        g = build_graph(args)
        cfg = build_config(args, backend="data_parallel")
        store = ShardedSketchStore(g, cfg, mesh)
        store.ensure(args.batches)
        store.visited_stack()
        engine = DistributedQueryEngine(store)
        sig_pre = engine.sigma([[1, 2, 3]])[0]
        tracker = stream.DirtySlotTracker.for_store(store)
        delta = stream.random_delta(g, rng, num_deletes=args.queries,
                                    num_inserts=args.queries)
        report = stream.incremental_refresh(store, tracker, delta)
        print(f"[stream] sharded delta: +{report.inserted}/-{report.deleted} "
              f"edges, {report.touched_row_blocks} row-blocks → "
              f"{report.dirty_slots}/{report.total_slots} dirty slots "
              f"resampled in {report.refresh_s:.2f}s "
              f"(graph epoch {report.graph_epoch})")
        cold = stream.cold_rebuild_batches(store)
        for bi, bc in zip(store.batches, cold):
            assert np.array_equal(np.asarray(bi.visited),
                                  np.asarray(bc.visited))
            assert bi.fused_edge_visits == bc.fused_edge_visits
        single = SketchStore(store.graph, dense_variant(cfg),
                             g_rev=store.g_rev)
        single.ensure(len(store.batches))
        for bi, bs in zip(store.batches, single.batches):
            assert np.array_equal(np.asarray(bi.visited),
                                  np.asarray(bs.visited))
        sig_post = engine.sigma([[1, 2, 3]])[0]
        print(f"[stream] sharded pool ≡ cold rebuild ≡ single-device dense "
              f"on the mutated graph ({store.num_shards} shards); "
              f"σ̂(1,2,3) {sig_pre:.1f} → {sig_post:.1f}")
        print(f"[stream] PASS in {time.time() - t0:.1f}s")
        return

    # ---- tier mode: the delta is a serving event between live queries
    from repro.serve.tier import EpochMixError, ServingTier, ShedError

    store = build_store(args)
    tier = ServingTier.build(store, replicas=args.replicas,
                             quota_qps=args.quota_qps,
                             default_deadline=args.deadline)
    try:
        n = store.graph.num_vertices
        queries = [rng.integers(0, n, 3).tolist() for _ in range(4)]
        pre = [tier.submit_sigma("ops", q) for q in queries]
        pre_vals = tier.gather(pre)
        v0 = tier.group.versions()[0]

        delta = stream.random_delta(store.graph, rng,
                                    num_deletes=args.queries,
                                    num_inserts=args.queries)
        report = tier.apply_delta("ops", delta)
        print(f"[stream] tier delta: +{report.inserted}/-{report.deleted} "
              f"edges, {report.touched_row_blocks} row-blocks → "
              f"{report.dirty_slots}/{report.total_slots} dirty slots "
              f"({report.dirty_fraction:.0%}) resampled in "
              f"{report.refresh_s:.2f}s")

        # graph-epoch version bump, replicas converged bit-identically
        v1 = tier.group.versions()[0]
        assert v1[0] == v0[0] + 1 and tier.group.consistent(), (v0, v1)
        stacks = [np.asarray(r.store.visited_stack())
                  for r in tier.group.replicas]
        assert all(np.array_equal(stacks[0], s) for s in stacks[1:])

        # incremental pool ≡ cold rebuild on the mutated graph
        r0 = tier.group.replicas[0].store
        cold = stream.cold_rebuild_batches(r0)
        for bi, bc in zip(r0.batches, cold):
            assert np.array_equal(np.asarray(bi.visited),
                                  np.asarray(bc.visited))
            assert bi.fused_edge_visits == bc.fused_edge_visits
        print(f"[stream] replicas converged at graph epoch {v1[0]}, "
              f"pool ≡ cold rebuild on the mutated graph")

        # pre-delta and post-delta replies must never mix
        post = [tier.submit_sigma("ops", q) for q in queries]
        post_vals = tier.gather(post)
        mixed = False
        try:
            tier.gather([pre[0], post[0]])
        except EpochMixError as e:
            mixed = True
            assert len(e.versions) == 2
        assert mixed, "pre/post-delta replies must be refused as a mix"
        print(f"[stream] pre/post-delta gather refused (EpochMixError); "
              f"σ̂ samples {pre_vals[0]:.1f} → {post_vals[0]:.1f}")

        # deltas are admission-gated like any query
        tier.set_quota("vandal", rate=0.01, burst=1)
        tier.apply_delta("vandal", stream.EdgeDelta.deletes([], []))
        shed = False
        try:
            tier.apply_delta("vandal", stream.EdgeDelta.deletes([], []))
        except ShedError as e:
            shed = True
            assert e.retry_after > 0
        assert shed, "quota-starved tenant must shed delta spam"

        snap = tier.snapshot()
        s = snap["stream"]
        assert s["deltas_applied"] == 2 and s["tracker"]["slots"] == \
            len(r0.batches)
        print(f"[stream] admission gates deltas (1 shed); snapshot: "
              f"{s['deltas_applied']} deltas, dirty-fraction p50 "
              f"{s['dirty_fraction']['p50']:.2f}, tracker "
              f"{s['tracker']['tracker_bytes']} B")
    finally:
        tier.close()
    print(f"[stream] PASS in {time.time() - t0:.1f}s")


# -------------------------------------------------------------------- async
def _async_demo(args, engine) -> None:
    """Deadline-batched front-end under a burst of threaded clients."""
    from repro.serve.distributed import AsyncFrontEnd

    n = engine.store.graph.num_vertices
    fe = AsyncFrontEnd(MicroBatcher(engine, cache=ResultCache()),
                       default_deadline=args.deadline,
                       refresh_every=args.refresh_every)
    lone = fe.submit_sigma([1, 2, 3])
    lone.result(timeout=300)
    assert fe.stats.deadline_flushes >= 1, fe.stats

    futs: list = []
    lock = threading.Lock()
    rng = np.random.default_rng(1)
    queries = [rng.integers(0, n, 3).tolist() for _ in range(4 * 8)]

    def client(q):
        f = fe.submit_sigma(q)
        with lock:
            futs.append(f)

    threads = [threading.Thread(target=client, args=(q,)) for q in queries]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for f in futs:
        f.result(timeout=300)
    dt = time.perf_counter() - t0
    fe.close()
    assert fe.stats.max_queue_wait <= args.deadline + 2.0, fe.stats
    print(f"[async] {len(queries)} threaded clients + 1 lone request in "
          f"{dt:.2f}s: {fe.stats.flushes} flushes "
          f"({fe.stats.slot_flushes} slot / {fe.stats.deadline_flushes} "
          f"deadline / {fe.stats.drain_flushes} drain), worst queue wait "
          f"{fe.stats.max_queue_wait * 1e3:.0f} ms "
          f"(deadline {args.deadline * 1e3:.0f} ms)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="full lifecycle check on a synthetic graph")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve from a sharded pool on a DxM mesh "
                         "(forces host devices for CPU smoke)")
    ap.add_argument("--async", dest="async_frontend", action="store_true",
                    help="front the batcher with the deadline-batched "
                         "AsyncFrontEnd and drive it from client threads")
    ap.add_argument("--tier", action="store_true",
                    help="serve through the production tier: per-tenant "
                         "admission control + replica routing "
                         "(+ --autoscale); see repro.serve.tier")
    ap.add_argument("--stream-smoke", action="store_true",
                    help="mutate the graph mid-serve (repro.stream delta), "
                         "refresh the pool incrementally, and assert "
                         "bit-identity against a cold rebuild; tier mode "
                         "by default, sharded with --mesh Dx1")
    ap.add_argument("--tenants", type=int, default=3,
                    help="tier tenant count (tenant0 is quota-starved in "
                         "the smoke so the shed path exercises)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="tier engine replicas over one epoch-tagged pool")
    ap.add_argument("--autoscale", action="store_true",
                    help="arm the signal-driven pool autoscaler "
                         "(coverage-error bound + query p99)")
    ap.add_argument("--quota-qps", type=float, default=50.0,
                    help="default per-tenant admission rate (tokens/s)")
    ap.add_argument("--target-eps", type=float, default=0.35,
                    help="autoscale coverage-error target (IMM ε)")
    ap.add_argument("--target-p99-ms", type=float, default=250.0,
                    help="autoscale query-latency target")
    ap.add_argument("--deadline", type=float, default=0.05,
                    help="async flush deadline in seconds")
    ap.add_argument("--refresh-every", type=float, default=None,
                    help="async background refresh period in seconds")
    ap.add_argument("--diffusion", choices=("ic", "lt"), default="ic",
                    help="diffusion model the pool samples under")
    ap.add_argument("--sampler-backend", default=None,
                    choices=("dense", "tiled", "kernel", "data_parallel",
                             "graph_parallel"),
                    help="traversal backend (default: dense single-device; "
                         "on a --mesh DxM: data_parallel when M==1, "
                         "graph_parallel — rows sharded over the model "
                         "axis — when M>1)")
    ap.add_argument("--frontier", choices=("dense", "sparse"),
                    default="dense",
                    help="per-level execution mode: sparse compacts each "
                         "level to the active tiles (bit-identical, work "
                         "scales with the live frontier)")
    ap.add_argument("--frontier-capacity", type=int, default=0,
                    help="sparse capacity knob (0 = auto bucket ladder; "
                         "see benchmarks/bench_frontier_profile.py)")
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--degree", type=float, default=6.0)
    ap.add_argument("--prob", type=float, default=0.25)
    ap.add_argument("--graph-seed", type=int, default=7)
    ap.add_argument("--colors", type=int, default=64)
    ap.add_argument("--batches", type=int, default=8,
                    help="initial pool size (fused batches)")
    ap.add_argument("--max-batches", type=int, default=64)
    ap.add_argument("--memory-budget-mb", type=float, default=None)
    ap.add_argument("--master-seed", type=int, default=0)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--ckpt-dir", default=None,
                    help="pool snapshot directory (default: temp dir)")
    args = ap.parse_args()

    # Standard accelerator config (GPU latency-hiding flags; inert on
    # CPU/TPU) before any jax backend materializes — the smoke paths below
    # additionally force host devices through the same module.
    from repro.launch import accel
    accel.configure()

    if args.stream_smoke:
        shape = _parse_mesh(args.mesh) if args.mesh else None
        if shape is not None:
            _force_cpu_host_devices(shape[0] * shape[1])
        run_stream(args, shape)
    elif args.tier:
        if args.mesh:
            raise SystemExit("--tier serves single-device replicas; mesh "
                             "backends arrive with cross-process replicas")
        if args.tenants < 2:
            raise SystemExit("--tier wants --tenants >= 2 (tenant0 is the "
                             "quota-starved one)")
        run_tier(args)
    elif args.mesh:
        shape = _parse_mesh(args.mesh)
        if args.smoke:
            _force_cpu_host_devices(shape[0] * shape[1])
        run_distributed(args, shape)
    else:
        run_single(args)


if __name__ == "__main__":
    main()
