"""Production mesh construction.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import; tests
import this module under a single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; ("pod", "data", "model") across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    return jax.make_mesh(shape, axes)
