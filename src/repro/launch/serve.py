"""Serving launcher: batched prefill+decode for any assigned architecture.

    python -m repro.launch.serve --arch mamba2-1.3b --smoke \
        --batch 4 --new-tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import model
from repro.serve import engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = registry.smoke(args.arch) if args.smoke else registry.get(args.arch)
    cfg = dataclasses.replace(cfg, num_patches=0)
    params = model.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    shape = ((args.batch, cfg.num_codebooks, args.prompt_len)
             if cfg.num_codebooks else (args.batch, args.prompt_len))
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, shape))
    t0 = time.time()
    out = engine.generate(params, cfg, prompt, args.new_tokens,
                          key=jax.random.key(3),
                          temperature=args.temperature)
    dt = time.time() - t0
    print(f"[launch.serve] {cfg.name}: {args.batch} requests × "
          f"{args.new_tokens} tokens in {dt:.2f}s")


if __name__ == "__main__":
    main()
