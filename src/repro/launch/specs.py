"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

The shannon/kernels pattern: weak-type-correct, shardable, zero allocation.
``input_specs`` returns the exact pytrees the lowered step functions take;
``*_shardings`` return matching NamedSharding trees for ``in_shardings``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding_rules as rules
from repro.models import common, decode, model
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim import adamw


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, *, with_labels=True):
    B, L = shape.global_batch, shape.seq_len
    out = {}
    if cfg.num_codebooks:
        out["tokens"] = _sds((B, cfg.num_codebooks, L), jnp.int32)
        if with_labels:
            out["labels"] = _sds((B, cfg.num_codebooks, L), jnp.int32)
    else:
        Lt = L - cfg.num_patches
        out["tokens"] = _sds((B, Lt), jnp.int32)
        if with_labels:
            out["labels"] = _sds((B, Lt), jnp.int32)
    if cfg.num_patches:
        out["patch_embeds"] = _sds((B, cfg.num_patches,
                                    model.PATCH_EMBED_DIM), jnp.bfloat16)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(caches, tokens, cur_len) stand-ins for serve_step."""
    B, L = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(lambda: decode.init_caches(cfg, B, L))
    tok = (_sds((B, cfg.num_codebooks, 1), jnp.int32) if cfg.num_codebooks
           else _sds((B, 1), jnp.int32))
    return caches, tok, _sds((), jnp.int32)


def param_specs(cfg: ModelConfig):
    return model.param_shapes(cfg)


def opt_specs(cfg: ModelConfig, params_shapes):
    dt = common.dtype_of(cfg.optimizer_state_dtype)
    return jax.eval_shape(lambda: adamw.init(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params_shapes),
        dt))


# ------------------------------------------------------------- shardings
def batch_shardings(mesh: Mesh, batch_shapes):
    dp = rules.fsdp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        spec = P(dp, *([None] * (len(leaf.shape) - 1)))
        return NamedSharding(mesh, rules.sanitize(mesh, spec, leaf.shape))

    flat, td = jax.tree_util.tree_flatten_with_path(batch_shapes)
    return jax.tree_util.tree_unflatten(
        td, [one(p, l) for p, l in flat])


_CACHE_RULES = [
    ("k_rope", (None, "__dp__", "model", None)),
    ("conv", (None, "__dp__", None, "model")),
    ("state", (None, "__dp__", "model", None, None)),
    ("k", (None, "__dp__", "model", None, None)),
    ("v", (None, "__dp__", "model", None, None)),
    ("c", (None, "__dp__", "model", None)),
]


def cache_shardings(mesh: Mesh, cache_shapes):
    """Decode caches: sequence axis sharded over "model" (seq-parallel
    flash-decode), batch over the data axes, leading group dim replicated."""
    dp = rules.fsdp_axes(mesh)
    dp = dp if len(dp) > 1 else dp[0]

    def one(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        for key, spec in _CACHE_RULES:
            if name == key:
                spec = tuple(dp if a == "__dp__" else a for a in spec)
                spec = P(*spec[: len(leaf.shape)])
                return NamedSharding(mesh,
                                     rules.sanitize(mesh, spec, leaf.shape))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    flat, td = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(td, [one(p, l) for p, l in flat])


def opt_shardings(mesh: Mesh, opt_shapes, param_sh):
    """Adam moments shard exactly like their parameters (ZeRO)."""
    return adamw.AdamWState(
        step=NamedSharding(mesh, P()),
        m=param_sh, v=param_sh)
