"""Post-partitioning HLO analysis: loop-weighted FLOPs, HBM bytes, and
collective bytes for §Roofline.

``compiled.cost_analysis()`` counts every ``while`` body ONCE — useless for
scan-over-layers programs where ~all compute lives inside loops — and has no
collective accounting at all.  This parser walks ``compiled.as_text()``:

* **trip counts**: lax.scan lowers to ``while`` whose condition compares the
  induction variable against a literal ``constant(N)``; we read N out of the
  condition computation (max s32 constant — scan conds contain only the
  bound).  Dynamic whiles (traversal level loops) count once (documented).
* **FLOPs**: every ``dot`` contributes 2·|result|·|contraction| (operand
  shapes resolved through a per-computation SSA name→type map); recursion
  descends into fusions, calls, and loop bodies (× trips).
* **HBM bytes**: per top-level op, operand + result bytes (XLA cost-model
  semantics), skipping pure aliasing ops; fusion internals are NOT counted
  (their operands/results already are — that is the fusion's point).
* **collective bytes**: result bytes × op factor (all-reduce 2× — ring
  sends + receives every byte twice; others 1×), loop-weighted.

All numbers are per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"             # result name
    r"((?:\([^()]*\)|[\w\[\],]+(?:\{[\d,]*\})?))\s+"  # result type (+layout;
    r"([\w\-]+)\(")           # tuple types are paren-free inside + comments
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}
_ALIAS_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "iota", "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    """All array shapes in a (possibly tuple) type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append([int(d) for d in dims.split(",") if d])
    return out


def parse_computations(hlo_text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _HEADER_RE.match(line.strip())
            if m and "->" in line:
                cur = m.group(1)
                comps[cur] = []
                continue
            if line.strip() == "}":
                cur = None
            continue
        stripped = line.strip()
        if cur is not None and "=" in stripped:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo_text: str) -> str:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo_text)
    return m.group(1)


def _max_s32_constant(lines: list[str]) -> int | None:
    best = None
    for ln in lines:
        m = re.search(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)", ln)
        if m:
            v = int(m.group(1))
            best = v if best is None else max(best, v)
    return best


def _refs(line: str) -> dict[str, str]:
    out = {}
    for key in ("to_apply", "calls", "body", "condition"):
        m = re.search(key + r"=%?([\w\.\-]+)", line)
        if m:
            out[key] = m.group(1)
    return out


def _dot_flops(line: str, result_type: str, types: dict[str, str]) -> float:
    dims = _shape_dims(result_type)
    if not dims:
        return 0.0
    result_elems = 1
    for d in dims[0]:
        result_elems *= d
    # First operand, tolerating commas inside shape brackets / layout
    # braces: some XLA builds (CPU notably) print operand TYPES inline —
    # ``dot(f32[64,32]{1,0} %a, ...)`` — others just ``dot(%a, ...)``.
    m = re.search(r"dot\(((?:\[[^\]]*\]|\{[^\}]*\}|[^,()])+),", line)
    lhs_shape = None
    if m:
        lhs = m.group(1).strip()
        shapes = (_shape_dims(lhs) if "[" in lhs
                  else _shape_dims(types.get(lhs.lstrip("%"), "")))
        lhs_shape = shapes[0] if shapes else None
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contraction = 1
    if lhs_shape and cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_shape):
                contraction *= lhs_shape[i]
    return 2.0 * result_elems * contraction


class HloCost:
    """Loop-weighted per-device cost walk (see module docstring)."""

    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self.entry = _entry_name(hlo_text)
        self.types: dict[str, dict[str, str]] = {}
        for name, lines in self.comps.items():
            t = {}
            for ln in lines:
                m = _OP_RE.match(ln)
                if m:
                    t[m.group(1)] = m.group(2)
            self.types[name] = t
        self._memo: dict[str, dict] = {}

    def _visit(self, name: str) -> dict:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = defaultdict(float)     # cycle guard
        tot = defaultdict(float)
        types = self.types.get(name, {})
        for ln in self.comps.get(name, ()):
            m = _OP_RE.match(ln)
            if not m:
                continue
            _, rtype, op = m.groups()
            refs = _refs(ln)
            if op in _COLLECTIVES:
                b = _shape_bytes(rtype)
                tot["coll_" + op] += b * _FACTOR[op]
                tot["ops_" + op] += 1
                tot["bytes"] += b * 2              # also HBM in/out
            elif op == "while":
                trips = 1
                if "condition" in refs:
                    c = _max_s32_constant(
                        self.comps.get(refs["condition"], []))
                    trips = c if c else 1
                for key in ("body", "condition"):
                    if key in refs:
                        sub = self._visit(refs[key])
                        for k, v in sub.items():
                            tot[k] += v * trips
            elif op == "conditional":
                for r in refs.values():
                    sub = self._visit(r)
                    for k, v in sub.items():
                        tot[k] += v
            elif op == "dot":
                tot["flops"] += _dot_flops(ln, rtype, types)
                tot["bytes"] += self._op_bytes(ln, op, rtype, types)
            elif op == "fusion":
                # fusion's own operands/result are the HBM traffic; descend
                # only for flops + collectives hidden inside
                tot["bytes"] += self._op_bytes(ln, op, rtype, types)
                if "calls" in refs:
                    sub = self._visit(refs["calls"])
                    tot["flops"] += sub.get("flops", 0.0)
                    for k, v in sub.items():
                        if k.startswith(("coll_", "ops_")):
                            tot[k] += v
            elif op in ("call", "custom-call", "async-start"):
                tot["bytes"] += self._op_bytes(ln, op, rtype, types)
                for key in ("to_apply", "calls"):
                    if key in refs:
                        sub = self._visit(refs[key])
                        for k, v in sub.items():
                            tot[k] += v
            elif op in _ALIAS_OPS:
                continue
            else:
                tot["bytes"] += self._op_bytes(ln, op, rtype, types)
        self._memo[name] = tot
        return tot

    def _op_bytes(self, line: str, op: str, rtype: str, types: dict) -> float:
        b = float(_shape_bytes(rtype))
        m = re.search(re.escape(op) + r"\(([^)]*)\)", line)
        if m:
            args = m.group(1)
            if "[" in args:
                # Inline operand types (CPU XLA text): the shapes are right
                # in the argument list — sum them directly (comma-splitting
                # would cut ``f32[64,32]`` apart).
                b += _shape_bytes(args)
            else:
                for arg in args.split(","):
                    arg = arg.strip().lstrip("%")
                    if arg in types:
                        b += _shape_bytes(types[arg])
        return b

    def analyze(self) -> dict:
        tot = self._visit(self.entry)
        coll = {k[5:]: v for k, v in tot.items() if k.startswith("coll_")}
        ops = {k[4:]: int(v) for k, v in tot.items() if k.startswith("ops_")}
        return {
            "flops": tot.get("flops", 0.0),
            "bytes": tot.get("bytes", 0.0),
            "collective": {
                "per_device_bytes": sum(coll.values()),
                "by_kind": coll,
                "op_counts": ops,
            },
        }


def full_cost(hlo_text: str) -> dict:
    return HloCost(hlo_text).analyze()


def collective_bytes(hlo_text: str) -> dict:
    return full_cost(hlo_text)["collective"]
