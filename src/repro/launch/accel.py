"""Accelerator runtime configuration — ONE place that sets the XLA flags
and platform knobs every launcher and benchmark needs, before jax's backend
materializes.

Two concerns live here:

* **Latency hiding.**  The paper's fused traversal interleaves per-level
  collectives (frontier all-gather / butterfly exchange over the model
  axis) with tile-kernel compute; on GPU the win depends on XLA scheduling
  the collectives asynchronously so the NCCL ring overlaps the next tile
  batch.  `gpu_latency_hiding_flags` is that flag set, applied idempotently
  by `configure` whenever the target platform is (or may be) GPU.

* **Host-device shims.**  CI and `--smoke` runs exercise the mesh backends
  on forced host CPU devices (``--xla_force_host_platform_device_count``).
  `set_host_device_count` owns that dance — including the "explicit
  accelerator request wins" opt-out — so `serve_influence`, the bench
  workers and the test-suite all force devices the same way.

Everything here mutates **environment variables only** and must therefore
run before the first jax device query or op (module imports are safe — the
backend materializes lazily).  Calls after backend init are not an error,
but they only affect subsequently spawned workers; `configure` returns the
flags it applied so callers can log/propagate them to subprocesses.
"""
from __future__ import annotations

import os

# XLA flags that let GPU runs overlap the per-level model-axis collectives
# with tile-kernel compute: the latency-hiding scheduler reorders around
# async collective start/done pairs, and the dedicated high-priority stream
# keeps small frontier exchanges from queueing behind large tile matmuls.
GPU_LATENCY_HIDING_FLAGS = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def append_xla_flags(flags) -> list[str]:
    """Append ``flags`` to ``XLA_FLAGS`` (idempotent per flag NAME: a flag
    the user already set — either value — is left alone).  Returns the
    flags actually added."""
    current = os.environ.get("XLA_FLAGS", "")
    added = [f for f in flags if _flag_name(f) not in current]
    if added:
        os.environ["XLA_FLAGS"] = " ".join(filter(None, [current] + added))
    return added


def set_host_device_count(n: int) -> bool:
    """Force ``n`` host CPU devices (the multi-device smoke/CI trick).

    No-op — returning False — when ``n <= 1`` or the user explicitly
    requested a real accelerator via ``JAX_PLATFORMS``; production runs
    never call this with a real backend selected.  Must run before the jax
    backend materializes (first device query), like everything here.
    """
    if n <= 1 or os.environ.get("JAX_PLATFORMS", "cpu") not in ("", "cpu"):
        return False
    os.environ["JAX_PLATFORMS"] = "cpu"
    return bool(append_xla_flags(
        [f"--xla_force_host_platform_device_count={n}"]))


def configure(*, host_devices: int = 1, gpu: bool | None = None) -> dict:
    """Apply the standard accelerator configuration.

    ``host_devices > 1`` forces that many host CPU devices (smoke/CI
    meshes).  ``gpu=None`` auto-detects from ``JAX_PLATFORMS`` (the GPU
    latency-hiding flags are applied when a cuda/rocm platform is
    requested, or when nothing is requested — they are inert on CPU/TPU
    backends, so applying them eagerly costs nothing and covers the
    "launched bare on a GPU box" case); ``gpu=False`` skips them,
    ``gpu=True`` forces them.

    Returns ``{"xla_flags_added": [...], "host_devices_forced": bool}`` for
    launcher logs and worker-env propagation.
    """
    platforms = os.environ.get("JAX_PLATFORMS", "")
    if gpu is None:
        gpu = platforms in ("",) or any(
            p in platforms for p in ("cuda", "rocm", "gpu"))
    added: list[str] = []
    if gpu:
        added += append_xla_flags(GPU_LATENCY_HIDING_FLAGS)
    forced = set_host_device_count(host_devices)
    if forced:
        added.append(f"--xla_force_host_platform_device_count={host_devices}")
    return {"xla_flags_added": added, "host_devices_forced": forced}
