"""Int8 gradient compression with error feedback for the DP all-reduce.

At 1000+-node scale the data-parallel gradient all-reduce is the dominant
cross-pod collective; int8 quantization cuts its bytes 4× (fp32) / 2× (bf16).
Error feedback (Seide et al.) keeps the quantization residual locally and
adds it to the next step's gradient, so SGD/Adam convergence is preserved.

Usage pattern (shard_map data-parallel step):

    g_q, scale = quantize(g + err)
    g_sum  = psum(g_q.astype(int32), axis) ;  scale = pmax(scale, axis)
    g_hat  = dequantize(g_sum, scale) / n_shards
    err    = (g + err) - dequantize(g_q, scale)      # local residual

The all-reduce moves int8 instead of fp32; scales move one scalar per leaf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_LEVELS = 127.0


def quantize(x):
    """Per-tensor symmetric int8.  Returns (q int8, scale f32 scalar)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(absmax / _LEVELS, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, axis: str):
    """Tree-wise int8 all-reduce with shared (pmax) scales.

    Must run inside shard_map over ``axis``.  Returns (mean gradient tree,
    local residual tree) — caller owns carrying the residual (error
    feedback) into the next step.
    """
    n = jax.lax.psum(1, axis)

    def one(g):
        q, scale = quantize(g)
        scale = jax.lax.pmax(scale, axis)
        # re-quantize against the shared scale so the sum is coherent
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                     ).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = (total.astype(jnp.float32) * scale / n).astype(g.dtype)
        residual = (g.astype(jnp.float32) - dequantize(q, scale)
                    ).astype(g.dtype)
        return mean, residual

    out = jax.tree.map(one, grads)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return mean, res
