"""Hand-rolled AdamW (no optax in this environment).

Moment dtype is configurable (``ModelConfig.optimizer_state_dtype``): the
≥100B configs use bf16 moments so params+moments+grads fit v5e HBM at the
assigned mesh sizes (accounting in EXPERIMENTS.md §Dry-run).  Moments are
FSDP-sharded like their parameters (ZeRO: same rule tree applies).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jnp.ndarray
    m: Any
    v: Any


def init(params, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
           eps=1e-8, weight_decay=0.1, max_grad_norm=1.0):
    """One AdamW step.  Returns (params, state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        delta = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        p32 = p.astype(jnp.float32) * (1.0 - lr * weight_decay) - lr * delta
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr
