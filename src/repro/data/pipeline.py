"""Deterministic synthetic token pipeline.

The stream is a pure function of ``(seed, step)`` — the *data cursor is the
step counter*, which makes restart-after-failure trivial (restore step N ⇒
the next batch is bit-identical to what the lost run would have seen) and
removes any shared-filesystem dependency from the 1000-node story.

Tokens follow an order-1 Markov chain with a few hundred heavy transitions,
so a ~10M-param model visibly learns (examples/train_lm.py) instead of
memorizing uniform noise.  A background prefetch thread keeps ``steps``
ahead, mirroring a real host-side input pipeline.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro.models.config import ModelConfig
from repro.models.model import PATCH_EMBED_DIM


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self.seed = seed
        rng = np.random.default_rng(seed)
        v = cfg.vocab_size
        # sparse-ish Markov chain: every token has 4 likely successors
        self.succ = rng.integers(0, v, (v, 4))

    def _tokens(self, rng, shape):
        v = self.cfg.vocab_size
        flat = np.empty(int(np.prod(shape)), np.int32)
        flat[0] = rng.integers(0, v)
        jumps = rng.random(len(flat)) < 0.1
        choices = rng.integers(0, 4, len(flat))
        randoms = rng.integers(0, v, len(flat))
        for i in range(1, len(flat)):
            flat[i] = (randoms[i] if jumps[i]
                       else self.succ[flat[i - 1], choices[i]])
        return flat.reshape(shape)

    def batch_at(self, step: int) -> dict:
        """Batch for one optimizer step (pure function of step)."""
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step))
        L = self.seq_len
        if cfg.num_codebooks:
            toks = self._tokens(rng, (self.batch, cfg.num_codebooks, L + 1))
            batch = {"tokens": toks[..., :-1], "labels": toks[..., 1:]}
        else:
            Lt = L - cfg.num_patches
            toks = self._tokens(rng, (self.batch, Lt + 1))
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.num_patches:
            batch["patch_embeds"] = rng.normal(
                0, 0.3, (self.batch, cfg.num_patches, PATCH_EMBED_DIM)
            ).astype(np.float32)
        return batch


class Prefetcher:
    """Background thread producing ``batch_at(step)`` ahead of the loop."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth=2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
