"""Synthetic graph generators (host-side numpy).

The paper's §3.2 analysis uses LFR-benchmark graphs (power-law degrees and
community sizes); its main results use six SNAP graphs (Table 1).  We provide:
  * ``powerlaw_cluster`` — configuration-model graph with power-law outdegrees
    and planted communities (LFR-like: most edges fall inside a community).
  * ``erdos_renyi`` and ``rmat`` for scale/skew sweeps.
  * ``snap_clone`` — synthetic stand-ins matching Table 1's V/E/avg-degree
    (real SNAP edge lists load via ``datasets.load_snap`` when present).
"""
from __future__ import annotations

import numpy as np

from repro.graph import csr


def _power_law_degrees(rng: np.random.Generator, n: int, avg_deg: float,
                       exponent: float = 2.5, d_max: int | None = None):
    """Sample integer outdegrees ~ power law with the requested mean."""
    d_max = d_max or max(4, int(np.sqrt(n) * 4))
    raw = rng.pareto(exponent - 1.0, size=n) + 1.0
    deg = raw / raw.mean() * avg_deg
    return np.clip(deg.round().astype(np.int64), 0, d_max)


def powerlaw_cluster(n: int, avg_deg: float, *, mixing: float = 0.2,
                     n_communities: int | None = None, exponent: float = 2.5,
                     prob: float | tuple[float, float] = (0.0, 1.0),
                     seed: int = 0) -> csr.Graph:
    """LFR-like directed graph: power-law degrees, power-law community sizes,
    fraction ``mixing`` of edges crossing communities."""
    rng = np.random.default_rng(seed)
    deg = _power_law_degrees(rng, n, avg_deg, exponent)
    n_comm = n_communities or max(2, int(np.sqrt(n) / 2))
    comm_sizes = _power_law_degrees(rng, n_comm, n / n_comm, 2.0,
                                    d_max=max(4, n // 2)) + 1
    comm_of = np.repeat(np.arange(n_comm), comm_sizes)[:n]
    if len(comm_of) < n:
        comm_of = np.concatenate(
            [comm_of, rng.integers(0, n_comm, n - len(comm_of))])
    rng.shuffle(comm_of)
    # Bucket vertices per community for intra-community endpoint sampling.
    order = np.argsort(comm_of, kind="stable")
    sorted_comm = comm_of[order]
    starts = np.searchsorted(sorted_comm, np.arange(n_comm))
    ends = np.searchsorted(sorted_comm, np.arange(n_comm), side="right")

    src = np.repeat(np.arange(n, dtype=np.int64), deg)
    e = len(src)
    cross = rng.random(e) < mixing
    dst = np.empty(e, np.int64)
    dst[cross] = rng.integers(0, n, cross.sum())
    idx = np.flatnonzero(~cross)
    c = comm_of[src[idx]]
    lo, hi = starts[c], ends[c]
    width = np.maximum(hi - lo, 1)
    dst[idx] = order[lo + (rng.random(len(idx)) * width).astype(np.int64)]
    keep = src != dst                      # drop self-loops
    src, dst = src[keep], dst[keep]
    p = _edge_probs(rng, len(src), prob)
    return csr.from_edges(src, dst, p, n)


def erdos_renyi(n: int, avg_deg: float, *, prob=0.1, seed: int = 0) -> csr.Graph:
    rng = np.random.default_rng(seed)
    e = int(n * avg_deg)
    src = rng.integers(0, n, e)
    dst = rng.integers(0, n, e)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return csr.from_edges(src, dst, _edge_probs(rng, len(src), prob), n)


def rmat(scale: int, avg_deg: float, *, a=0.57, b=0.19, c=0.19,
         prob=(0.0, 1.0), seed: int = 0) -> csr.Graph:
    """Graph500-style R-MAT: recursive quadrant sampling → heavy skew."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    e = int(n * avg_deg)
    src = np.zeros(e, np.int64)
    dst = np.zeros(e, np.int64)
    for bit in range(scale):
        r = rng.random((e, 2))
        src_bit = r[:, 0] > (a + b)
        # quadrant probabilities conditioned on the row half
        thresh = np.where(src_bit, c / max(c + (1 - a - b - c), 1e-9),
                          a / max(a + b, 1e-9))
        dst_bit = r[:, 1] > thresh
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return csr.from_edges(src, dst, _edge_probs(rng, len(src), prob), n)


def _edge_probs(rng: np.random.Generator, e: int, prob) -> np.ndarray:
    if isinstance(prob, tuple):
        return rng.uniform(prob[0], prob[1], e).astype(np.float32)
    return np.full(e, prob, np.float32)
