"""Static-shape CSR graph structures for JAX.

The GPU codes traverse an in-memory CSR with dynamic frontier queues.  XLA
wants static shapes, so we carry CSR as plain dense arrays plus a flat
edge-centric view (``src[e], dst[e], prob[e]``) that the dense edge-centric
traversal path sweeps every level.  Padding edges point at a sink row with
probability 0 so they can never activate anything.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Directed graph in flat edge-list + CSR form (all static shapes).

    Attributes:
      indptr:  (V+1,) int32 CSR row pointers (sorted by src).
      src:     (E_pad,) int32 edge sources (CSR order; padding = V sentinel row
               redirected to 0 with prob 0).
      dst:     (E_pad,) int32 edge destinations.
      prob:    (E_pad,) float32 IC activation probability per edge.
      num_vertices / num_edges: static python ints (E = real edge count).
    """
    indptr: jnp.ndarray
    src: jnp.ndarray
    dst: jnp.ndarray
    prob: jnp.ndarray
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def padded_edges(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> jnp.ndarray:
        return self.indptr[1:] - self.indptr[:-1]


def from_edges(src: np.ndarray, dst: np.ndarray, prob: np.ndarray,
               num_vertices: int, pad_to: Optional[int] = None,
               dedupe: bool = False) -> Graph:
    """Build a CSR-ordered Graph from an edge list (numpy, host-side).

    ``dedupe=True`` merges parallel (src, dst) edges with the IC-preserving
    union probability — required by the dense-tile layout (core/tiles.py).
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    prob = np.asarray(prob, np.float32)
    if dedupe:
        from repro.core.tiles import dedupe_edges
        src, dst, prob = dedupe_edges(src, dst, prob)
    order = np.argsort(src, kind="stable")
    src, dst, prob = src[order], dst[order], prob[order]
    counts = np.bincount(src, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    e = len(src)
    pad_to = pad_to or e
    if pad_to < e:
        raise ValueError(f"pad_to={pad_to} < num_edges={e}")
    pad = pad_to - e
    if pad:
        src = np.concatenate([src, np.zeros(pad, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad, np.int32)])
        prob = np.concatenate([prob, np.zeros(pad, np.float32)])
    return Graph(
        indptr=jnp.asarray(indptr, jnp.int32),
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        prob=jnp.asarray(prob),
        num_vertices=int(num_vertices),
        num_edges=int(e),
    )


def dedupe(g: Graph) -> Graph:
    """``g`` rebuilt with parallel (src, dst) edges union-merged.

    THE way to get the dedupe-clean graph the tile-layout sampler backends
    (tiled/kernel/graph_parallel) require; using the result for EVERY
    backend keeps the facade's cross-backend bit-identity contract — one
    shared edge list, one set of CSR edge ids.  Idempotent, and drops any
    prob-0 edge padding (a merged edge list has its own CSR order).
    """
    e = g.num_edges
    return from_edges(np.asarray(g.src)[:e], np.asarray(g.dst)[:e],
                      np.asarray(g.prob)[:e], g.num_vertices, dedupe=True)


def transpose(g: Graph) -> Graph:
    """Reverse every edge — RRR sets run the diffusion backwards (Def. 2)."""
    src = np.asarray(g.dst)[: g.num_edges]
    dst = np.asarray(g.src)[: g.num_edges]
    prob = np.asarray(g.prob)[: g.num_edges]
    return from_edges(src, dst, prob, g.num_vertices, pad_to=g.padded_edges)


def relabel(g: Graph, perm: np.ndarray) -> Graph:
    """Apply a vertex permutation: new_id = perm[old_id] (reordering §5)."""
    perm = np.asarray(perm, np.int32)
    src = perm[np.asarray(g.src)[: g.num_edges]]
    dst = perm[np.asarray(g.dst)[: g.num_edges]]
    prob = np.asarray(g.prob)[: g.num_edges]
    return from_edges(src, dst, prob, g.num_vertices, pad_to=g.padded_edges)


def uniform_probs(rng: np.random.Generator, num_edges: int,
                  low: float = 0.0, high: float = 1.0) -> np.ndarray:
    """Paper §6: edge weights drawn uniformly, generated once and reused."""
    return rng.uniform(low, high, size=num_edges).astype(np.float32)
