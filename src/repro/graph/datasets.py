"""Paper Table-1 graphs: SNAP loaders + size-faithful synthetic clones.

Real SNAP edge lists load when present (``load_snap``, plain ``src dst``
text rows, as distributed by snap.stanford.edu); otherwise ``table1_clone``
generates a power-law-clustered stand-in with the table's V/E/avg-degree.
``scale`` shrinks clones proportionally for CPU-sized runs.
"""
from __future__ import annotations

import gzip
import os

import numpy as np

from repro.graph import csr, generators

# name → (nodes, edges, avg_degree)  — paper Table 1
TABLE1 = {
    "web-BerkStan": (685_230, 7_600_595, 22.18),
    "web-Google": (875_713, 5_105_039, 11.66),
    "soc-pokec-relationships": (1_632_803, 30_622_564, 37.51),
    "wiki-topcats": (1_791_489, 28_511_807, 31.83),
    "com-Orkut": (3_072_441, 117_185_083, 76.28),
    "soc-LiveJournal1": (4_847_571, 68_993_773, 28.47),
}


def load_snap(path: str, num_vertices: int | None = None,
              prob=(0.0, 1.0), seed: int = 0) -> csr.Graph:
    """Load a SNAP edge list (.txt or .txt.gz, '#' comments)."""
    opener = gzip.open if path.endswith(".gz") else open
    src, dst = [], []
    with opener(path, "rt") as f:
        for line in f:
            if line.startswith("#"):
                continue
            a, b = line.split()[:2]
            src.append(int(a))
            dst.append(int(b))
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    n = num_vertices or int(max(src.max(), dst.max()) + 1)
    rng = np.random.default_rng(seed)
    p = generators._edge_probs(rng, len(src), prob)
    return csr.from_edges(src, dst, p, n)


def table1_clone(name: str, scale: float = 1.0, prob=(0.0, 1.0),
                 seed: int = 0, snap_dir: str | None = None) -> csr.Graph:
    """Table-1 graph: the real edge list if ``snap_dir`` has it, else a
    synthetic clone at ``scale`` of the published size."""
    if name not in TABLE1:
        raise KeyError(f"unknown Table-1 graph {name!r}")
    if snap_dir:
        for ext in (".txt", ".txt.gz"):
            path = os.path.join(snap_dir, name + ext)
            if os.path.exists(path):
                return load_snap(path, prob=prob, seed=seed)
    v, e, deg = TABLE1[name]
    n = max(int(v * scale), 64)
    return generators.powerlaw_cluster(n, deg, prob=prob,
                                       seed=seed + hash(name) % 4096)
