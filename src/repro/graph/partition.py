"""1-D vertex partition of a TiledGraph for graph-parallel traversal.

Beyond-paper (DESIGN.md §3): the paper keeps a full graph replica per GPU;
we additionally shard the graph itself over the mesh "model" axis so inputs
larger than one HBM run at all.  Shard ``s`` owns destination blocks
``[s·nbₗ, (s+1)·nbₗ)`` — its rows of frontier/visited — plus every adjacency
tile whose *destination* falls in that range (so each shard writes only local
rows; sources arrive via an all-gather of the frontier each level).

All shards carry identical array shapes (tile lists padded to the max shard
count with inert prob-0 tiles) so the stack can live under one shard_map.

The shard assignment is a pure function of ``(tg, num_shards)``
(`_assignment`), so per-tile side arrays — e.g. the LT selection-CDF
prefixes — partition into the *same* stacked layout via
`partition_tile_values` and ride alongside the graph under one shard_map.
Callers (the `repro.sampling` ``graph_parallel`` backend) compute the
partition ONCE and cache it on the sampler; every batch reuses it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiles


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedTiledGraph:
    """Stacked per-shard tile lists (leading dim = shards)."""
    prob: jnp.ndarray        # (S, ntₘ, T, T) float32
    edge_id: jnp.ndarray     # (S, ntₘ, T, T) uint32
    tile_src: jnp.ndarray    # (S, ntₘ) int32  — GLOBAL source block
    tile_dst: jnp.ndarray    # (S, ntₘ) int32  — LOCAL destination block
    first_of_dst: jnp.ndarray  # (S, ntₘ) int32
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))
    tile_size: int = dataclasses.field(metadata=dict(static=True))
    num_shards: int = dataclasses.field(metadata=dict(static=True))
    blocks_per_shard: int = dataclasses.field(metadata=dict(static=True))

    @property
    def padded_vertices(self) -> int:
        return self.num_shards * self.blocks_per_shard * self.tile_size

    @property
    def rows_per_shard(self) -> int:
        return self.blocks_per_shard * self.tile_size


def _assignment(tg: tiles.TiledGraph, num_shards: int):
    """(shard_of (nt,), blocks_per_shard, tiles_per_shard) — THE shard
    assignment both `partition` and `partition_tile_values` follow."""
    T = tg.tile_size
    n_blocks_raw = -(-tg.num_vertices // T)
    nb_loc = -(-n_blocks_raw // num_shards)
    shard_of = np.asarray(tg.tile_dst) // nb_loc
    counts = np.bincount(shard_of, minlength=num_shards)
    return shard_of, nb_loc, max(int(counts.max()), 1)


def partition(tg: tiles.TiledGraph, num_shards: int) -> PartitionedTiledGraph:
    """Split a TiledGraph into ``num_shards`` destination-row shards."""
    T = tg.tile_size
    shard_of, nb_loc, nt_max = _assignment(tg, num_shards)

    t_src = np.asarray(tg.tile_src)
    t_dst = np.asarray(tg.tile_dst)
    prob = np.asarray(tg.prob)
    eid = np.asarray(tg.edge_id)
    first = np.asarray(tg.first_of_dst)

    P = np.zeros((num_shards, nt_max, T, T), np.float32)
    E = np.zeros((num_shards, nt_max, T, T), np.uint32)
    TS = np.zeros((num_shards, nt_max), np.int32)
    TD = np.zeros((num_shards, nt_max), np.int32)
    FI = np.zeros((num_shards, nt_max), np.int32)
    for s in range(num_shards):
        idx = np.flatnonzero(shard_of == s)
        k = len(idx)
        if k:
            P[s, :k] = prob[idx]
            E[s, :k] = eid[idx]
            TS[s, :k] = t_src[idx]
            TD[s, :k] = t_dst[idx] - s * nb_loc
            FI[s, :k] = first[idx]
            # ``first`` was computed on the global sorted order; within a
            # shard the first tile of the run is always first.
            FI[s, 0] = 1
            if k < nt_max:                      # inert padding, last local dst
                TD[s, k:] = TD[s, k - 1]
                TS[s, k:] = TS[s, k - 1]
        else:                                   # empty shard: one no-op tile
            FI[s, 0] = 1
    return PartitionedTiledGraph(
        prob=jnp.asarray(P), edge_id=jnp.asarray(E),
        tile_src=jnp.asarray(TS), tile_dst=jnp.asarray(TD),
        first_of_dst=jnp.asarray(FI),
        num_vertices=tg.num_vertices, num_edges=tg.num_edges,
        tile_size=T, num_shards=num_shards, blocks_per_shard=nb_loc)


def partition_tile_values(tg: tiles.TiledGraph, num_shards: int,
                          tile_values: np.ndarray) -> np.ndarray:
    """Scatter a per-tile ``(nt, ...)`` side array into the ``(S, ntₘ, ...)``
    stacked layout of ``partition(tg, num_shards)`` (same shard assignment,
    same within-shard tile order; padding slots are zero — inert alongside
    the prob-0 padding tiles)."""
    shard_of, _, nt_max = _assignment(tg, num_shards)
    vals = np.asarray(tile_values)
    out = np.zeros((num_shards, nt_max) + vals.shape[1:], vals.dtype)
    for s in range(num_shards):
        idx = np.flatnonzero(shard_of == s)
        if len(idx):
            out[s, : len(idx)] = vals[idx]
    return out


def partition_specs(ptg: PartitionedTiledGraph, axis: str):
    """The shard_map ``in_specs`` pytree for a partitioned graph: every tile
    stack sharded over ``axis`` on its leading (shard) dim, statics copied
    so the spec tree matches the value tree."""
    from jax.sharding import PartitionSpec as P
    return PartitionedTiledGraph(
        prob=P(axis), edge_id=P(axis), tile_src=P(axis), tile_dst=P(axis),
        first_of_dst=P(axis),
        num_vertices=ptg.num_vertices, num_edges=ptg.num_edges,
        tile_size=ptg.tile_size, num_shards=ptg.num_shards,
        blocks_per_shard=ptg.blocks_per_shard)
