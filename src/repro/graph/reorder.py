"""Vertex-reordering heuristics (paper §5).

On GPUs, reordering raises the chance that fused traversals visit shared
vertices around the same *time* (locality → color occupancy).  On TPU the
same permutations additionally concentrate edges into fewer, denser 128×128
adjacency tiles for the block-sparse expansion kernel (DESIGN.md §2).  All
heuristics return a permutation ``perm`` with ``new_id = perm[old_id]``.

Implemented: random baseline, degree sort, reverse Cuthill–McKee (BFS-based),
and a Grappolo-style clustering order via label propagation ("grappolo-lite" —
the paper found clustering-based ordering best).
"""
from __future__ import annotations

import numpy as np

from repro.graph import csr


def identity(g: csr.Graph) -> np.ndarray:
    return np.arange(g.num_vertices, dtype=np.int32)


def random_order(g: csr.Graph, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    perm = np.arange(g.num_vertices, dtype=np.int32)
    rng.shuffle(perm)
    return perm


def degree_sort(g: csr.Graph, descending: bool = True) -> np.ndarray:
    """new id by outdegree rank — hubs first (paper's degree-based sort)."""
    deg = np.asarray(g.degrees())
    order = np.argsort(-deg if descending else deg, kind="stable")
    perm = np.empty_like(order, dtype=np.int32)
    perm[order] = np.arange(len(order), dtype=np.int32)
    return perm


def _undirected_adj(g: csr.Graph):
    e = g.num_edges
    src = np.asarray(g.src)[:e]
    dst = np.asarray(g.dst)[:e]
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(g.num_vertices + 1, np.int64)
    np.cumsum(np.bincount(s, minlength=g.num_vertices), out=indptr[1:])
    return indptr, d


def rcm(g: csr.Graph) -> np.ndarray:
    """Reverse Cuthill–McKee on the symmetrized graph (BFS from low-degree
    roots, neighbors visited in increasing-degree order, order reversed)."""
    indptr, adj = _undirected_adj(g)
    n = g.num_vertices
    deg = indptr[1:] - indptr[:-1]
    visited = np.zeros(n, bool)
    order = np.empty(n, np.int64)
    pos = 0
    roots = np.argsort(deg, kind="stable")
    for root in roots:
        if visited[root]:
            continue
        visited[root] = True
        order[pos] = root
        head, pos = pos, pos + 1
        while head < pos:
            v = order[head]
            head += 1
            nbrs = adj[indptr[v]:indptr[v + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if len(nbrs):
                nbrs = np.unique(nbrs)
                nbrs = nbrs[~visited[nbrs]]
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                visited[nbrs] = True
                order[pos:pos + len(nbrs)] = nbrs
                pos += len(nbrs)
    order = order[::-1]
    perm = np.empty(n, np.int32)
    perm[order] = np.arange(n, dtype=np.int32)
    return perm


def cluster_order(g: csr.Graph, rounds: int = 5, seed: int = 0) -> np.ndarray:
    """Grappolo-lite: label-propagation communities, then order vertices by
    (community, degree) so cluster members are contiguous in memory."""
    indptr, adj = _undirected_adj(g)
    n = g.num_vertices
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int64)
    for _ in range(rounds):
        visit = rng.permutation(n)
        changed = 0
        for v in visit:
            nbrs = adj[indptr[v]:indptr[v + 1]]
            if len(nbrs) == 0:
                continue
            lab, cnt = np.unique(labels[nbrs], return_counts=True)
            best = lab[np.argmax(cnt)]
            if best != labels[v]:
                labels[v] = best
                changed += 1
        if changed == 0:
            break
    deg = indptr[1:] - indptr[:-1]
    order = np.lexsort((-deg, labels))
    perm = np.empty(n, np.int32)
    perm[order] = np.arange(n, dtype=np.int32)
    return perm


HEURISTICS = {
    "identity": identity,
    "random": random_order,
    "degree": degree_sort,
    "rcm": rcm,
    "cluster": cluster_order,
}


def apply(g: csr.Graph, name: str, **kwargs) -> tuple[csr.Graph, np.ndarray]:
    perm = HEURISTICS[name](g, **kwargs)
    return csr.relabel(g, perm), perm
