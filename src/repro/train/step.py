"""Jitted training step: microbatched gradient accumulation + AdamW.

Microbatching (``num_microbatches``) scans the global batch in chunks so the
live activation set is one microbatch — with layer-boundary remat this is
what fits the 340B/671B cells into v5e HBM.  Gradients accumulate in fp32
regardless of param dtype.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw


def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def make_train_step(cfg: ModelConfig, lr_fn: Callable,
                    num_microbatches: int = 1,
                    weight_decay: float = 0.1,
                    max_grad_norm: float = 1.0):
    """Returns train_step(params, opt_state, batch) → (params, opt, metrics).

    Not jitted here — the launcher jits with in/out shardings (dry-run) or
    plain jit (examples/tests)."""
    M = num_microbatches

    def loss_of(p, mb):
        return model.loss_fn(p, cfg, mb)[0]

    def train_step(params, opt_state: adamw.AdamWState, batch):
        from repro.distributed.sharding_rules import constrain_params
        if M == 1:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
            grads = constrain_params(grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(M, x.shape[0] // M, *x.shape[1:]), batch)

            def micro(carry, mb):
                gacc, lacc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                # Constrain per-microbatch grads to the PARAM sharding:
                # without this the accumulator is replicated and XLA emits
                # full-size fp32 all-reduces per (layer × microbatch) —
                # nemotron-340b: 13.2 TB/device/step (§Perf N1).  With it,
                # each microbatch reduce-scatters into the ZeRO shards.
                g32 = constrain_params(jax.tree.map(
                    lambda a: a.astype(jnp.float32), g))
                return (_tree_add(gacc, g32), lacc + l), None

            zeros = constrain_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)),
                                           mbs)
            grads = jax.tree.map(lambda g: g / M, gsum)
            loss = lsum / M
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = adamw.update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            max_grad_norm=max_grad_norm)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}

    return train_step
