"""Fault-tolerant training loop: checkpoint/restart, async writes, failure
injection, deterministic resume.

The restart contract tested in tests/test_fault_tolerance.py: a run killed
at an arbitrary step and restarted from its latest checkpoint produces the
SAME final parameters as an uninterrupted run — determinism comes from (a)
the step-indexed synthetic data pipeline (cursor == step), (b) counter-based
RNG everywhere, (c) XLA CPU determinism.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train.step import make_train_step


class SimulatedCrash(RuntimeError):
    pass


@dataclasses.dataclass
class TrainResult:
    params: dict
    opt_state: adamw.AdamWState
    losses: list
    resumed_from: Optional[int]
    steps_run: int


def train(cfg: ModelConfig, *, batch: int, seq_len: int, steps: int,
          lr: float = 3e-4, warmup: int = 10, seed: int = 0,
          checkpoint_dir: Optional[str] = None, ckpt_every: int = 10,
          async_ckpt: bool = True, num_microbatches: int = 1,
          crash_at_step: Optional[int] = None,
          log_every: int = 10, print_fn: Callable = print) -> TrainResult:
    """Run (or resume) training.  ``crash_at_step`` raises SimulatedCrash
    AFTER that step's update but BEFORE its checkpoint — the worst case."""
    params = model.init_params(jax.random.key(seed), cfg)
    opt = adamw.init(params, jax.numpy.float32)
    start = 0
    resumed = None
    if checkpoint_dir and ckpt.latest_step(checkpoint_dir) is not None:
        (params, opt), start = ckpt.restore(checkpoint_dir, (params, opt))
        resumed = start
        print_fn(f"[train] resumed from step {start}")

    lr_fn = adamw.cosine_schedule(lr, warmup, steps)
    step_fn = jax.jit(make_train_step(cfg, lr_fn, num_microbatches))

    data = SyntheticLM(cfg, batch, seq_len, seed=seed + 1)
    prefetch = Prefetcher(data, start_step=start)
    losses = []
    writer = None
    try:
        for step in range(start, steps):
            got_step, b = prefetch.get()
            assert got_step == step, (got_step, step)
            b = {k: jax.numpy.asarray(v) for k, v in b.items()}
            params, opt, metrics = step_fn(params, opt, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % log_every == 0:
                print_fn(f"[train] step {step:5d} loss {loss:.4f} "
                         f"gnorm {float(metrics['grad_norm']):.3f}")
            if checkpoint_dir and (step + 1) % ckpt_every == 0:
                if writer is not None:
                    writer.join()                 # previous async write
                writer = ckpt.save(checkpoint_dir, step + 1, (params, opt),
                                   blocking=not async_ckpt)
            if crash_at_step is not None and step == crash_at_step:
                raise SimulatedCrash(f"injected crash after step {step}")
    finally:
        prefetch.close()
        if writer is not None:
            writer.join()
    return TrainResult(params=params, opt_state=opt, losses=losses,
                       resumed_from=resumed, steps_run=steps - start)


def train_with_restarts(cfg: ModelConfig, *, steps: int, checkpoint_dir: str,
                        crash_schedule: tuple = (), **kw) -> TrainResult:
    """Driver that restarts after every SimulatedCrash — the single-process
    analogue of a cluster controller rescheduling a failed job."""
    crashes = list(crash_schedule)
    while True:
        crash_at = crashes.pop(0) if crashes else None
        try:
            return train(cfg, steps=steps, checkpoint_dir=checkpoint_dir,
                         crash_at_step=crash_at, **kw)
        except SimulatedCrash:
            continue
