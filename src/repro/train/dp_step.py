"""Data-parallel training step with int8-compressed gradient all-reduce.

The cross-pod DP all-reduce is the dominant collective at 1000+-node scale;
this step runs the whole update under shard_map so the reduction is
explicit and swappable:

    exact      — pmean(grads)                        (fp32 wire bytes)
    compressed — int8 quantize + psum + error feedback (≈¼ wire bytes)

Params/optimizer state are replicated across the DP axis (this step is the
*pure-DP* regime — small/medium models or the pod axis of a larger mesh);
the per-device quantization residual rides in the optimizer extras with a
leading device axis, sharded on the DP axis, so it stays device-local.

Convergence with compression is protected by error feedback — validated in
tests/test_dp_compression.py (loss curve within noise of the exact step).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw, compress


def make_dp_train_step(cfg: ModelConfig, lr_fn, mesh, axis: str = "data",
                       compressed: bool = True, weight_decay: float = 0.1):
    """Returns (step_fn, init_residual).  step_fn(params, opt, err, batch)
    → (params, opt, err, metrics); batch's leading dim is sharded on
    ``axis``; err leaves have leading dim = axis size (device-local)."""
    n_dev = mesh.shape[axis]

    def loss_of(p, mb):
        return model.loss_fn(p, cfg, mb)[0]

    def body(params, opt_state, err, batch):
        loss, g = jax.value_and_grad(loss_of)(params, batch)
        loss = jax.lax.pmean(loss, axis)
        if compressed:
            err0 = jax.tree.map(lambda e: e[0], err)
            g_in = jax.tree.map(lambda a, b: a.astype(jnp.float32) + b,
                                g, err0)
            g_hat, res = compress.compressed_psum(g_in, axis)
            err = jax.tree.map(lambda r: r[None], res)
        else:
            g_hat = jax.tree.map(lambda a: jax.lax.pmean(
                a.astype(jnp.float32), axis), g)
        lr = lr_fn(opt_state.step)
        params, opt_state, gnorm = adamw.update(
            params, g_hat, opt_state, lr=lr, weight_decay=weight_decay)
        return params, opt_state, err, {"loss": loss, "grad_norm": gnorm}

    rep = P()
    err_spec = jax.tree.map(lambda _: P(axis), _err_structure(cfg))
    from repro.distributed.compat import shard_map
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(rep, rep, err_spec, P(axis)),
        out_specs=(rep, rep, err_spec, rep),
        check=False)

    def init_residual(params):
        return jax.tree.map(
            lambda p: jax.device_put(
                jnp.zeros((n_dev, *p.shape), jnp.float32),
                NamedSharding(mesh, P(axis))), params)

    return jax.jit(fn), init_residual


def _err_structure(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: model.init_params(jax.random.key(0), cfg))
