"""Public jit'd wrappers over the Pallas kernels.

``interpret`` auto-detection: kernels run compiled on TPU backends and in
interpret mode (Python evaluation of the kernel body) everywhere else — this
container is CPU-only, so tests/benches exercise interpret mode while the
BlockSpecs/grids target real TPU lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tiles as tiles_lib
from repro.kernels import coverage as _coverage
from repro.kernels import fused_expand as _fused_expand
from repro.kernels import flash_attention as _flash


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def fused_expand(tg: tiles_lib.TiledGraph, frontier, visited, seed, level):
    """One fused-BPT expansion level on a TiledGraph (padded row masks)."""
    return _fused_expand.fused_expand(
        tg.prob, tg.edge_id, tg.tile_src, tg.tile_dst, tg.first_of_dst,
        frontier, visited, jnp.uint32(seed), jnp.uint32(level),
        interpret=_interpret())


def cover_counts(visited, active):
    """Marginal-gain counts for greedy max-k-cover (rows padded to 128)."""
    Vp = visited.shape[0]
    pad = (-Vp) % 128
    if pad:
        visited = jnp.pad(visited, ((0, pad), (0, 0)))
    out = _coverage.cover_counts(visited, active, interpret=_interpret())
    return out[:Vp] if pad else out


def cover_counts_batched(visited, active):
    """Per-batch marginal-gain counts: (B, V, W) × (B, W) → (B, V).

    vmap of the coverage kernel over the batch axis — the per-batch grid and
    BlockSpecs are unchanged, so the TPU lowering is the same row sweep with
    a batched outer grid dimension.  Shared by the incremental greedy kernel
    (`core.imm.greedy_extend`) and the online query engine.
    """
    return jax.vmap(cover_counts)(visited, active)


def flash_attention(q, k, v, *, causal=True, scale=None, kv_offset=0,
                    block_q=128, block_k=128):
    """Blocked online-softmax attention (prefill hot-spot)."""
    return _flash.flash_attention(
        q, k, v, causal=causal, scale=scale, kv_offset=kv_offset,
        block_q=block_q, block_k=block_k, interpret=_interpret())
