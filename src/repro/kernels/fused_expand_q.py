"""Quantized fused-BPT expansion kernel (§Perf iteration B1, beyond-paper).

The f32 kernel's working set is 8 B per tile slot (prob f32 + edge-id u32)
and needs 32 hash calls per (tile, word).  This variant:

* quantizes activation probability to a u8 threshold ``q`` with the
  *endpoint-exact* rule  accept ⇔ (u8 ≤ q) ∧ (q > 0),  p̂ = (q+1)/256 for
  q>0 — p=1.0 and p=0.0 stay exact, max quantization error 1/256 ≪ the IC
  Monte-Carlo noise (validated statistically in tests);
* derives the RNG counter from the (tile, row, col) grid position instead
  of a stored edge id — the edge-id tile disappears entirely;
* extracts FOUR u8 lanes from every 32-bit hash → 8 hash calls per
  (tile, word) instead of 32.

Net: 1 B per tile slot (8× memory), 4× fewer hash ops.  The price is that
draws no longer couple bit-for-bit with the CSR/f32 paths — this kernel
validates against its own oracle (ref) + statistical agreement with the
exact path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import expand_grid_params

from repro.core import rng


def quantize_probs(prob: jnp.ndarray) -> jnp.ndarray:
    """f32 prob in [0,1] → u8 threshold (module docstring semantics):
    p̂ = (q+1)/256 for q>0, exactly 0 for q==0; p=1 → q=255 → exact."""
    q = jnp.clip(jnp.round(prob * 256.0) - 1.0, 0, 255)
    return jnp.where(prob > 0, q, 0).astype(jnp.uint8)


def _bern_word_q(seed, level, cell_id, word, q8):
    """Packed 32-lane Bernoulli word from 8 hashes (4 u8 lanes per hash).

    Lane c draws byte (c % 4) of hash(seed, level, cell_id, word·8 + c//4);
    accept ⇔ u8 ≤ q8 ∧ q8 > 0.
    """
    out = jnp.zeros(q8.shape, jnp.uint32)
    valid = (q8 > 0)
    q16 = q8.astype(jnp.uint32)
    for h in range(8):
        bits = rng.hash_u32(seed, level, cell_id,
                            word * jnp.uint32(8) + jnp.uint32(h))
        for byte in range(4):
            u = (bits >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)
            c = h * 4 + byte
            accept = jnp.logical_and(u <= q16, valid)
            out = out | (accept.astype(jnp.uint32) << jnp.uint32(c))
    return out


def _expand_q_kernel(tile_src_ref, tile_dst_ref, first_ref, scalar_ref,
                     q_ref, frontier_ref, visited_ref, out_ref,
                     *, num_words: int, tile_size: int):
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seed = scalar_ref[0]
    level = scalar_ref[1]
    q8 = q_ref[0]                            # (T, T) uint8
    fr = frontier_ref[...]                   # (T, W)
    vis = visited_ref[...]                   # (T, W)
    T = tile_size
    row = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 1)
    cell = (t.astype(jnp.uint32) * jnp.uint32(T * T)
            + row * jnp.uint32(T) + col)

    for w in range(num_words):
        rand_w = _bern_word_q(seed, level, cell, jnp.uint32(w), q8)
        x = fr[:, w][:, None] & rand_w
        n = T
        while n > 1:
            n //= 2
            x = x[:n] | x[n:]
        out_ref[:, w] |= x[0] & ~vis[:, w]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_expand_q(q8_tiles, tile_src, tile_dst, first_of_dst,
                   frontier, visited, seed, level, *, interpret=True):
    """Quantized one-level expansion; same contract as fused_expand."""
    nt, T, _ = q8_tiles.shape
    _, W = frontier.shape
    Vp = visited.shape[0]
    n_blocks = Vp // T
    scalars = jnp.asarray([seed, level], jnp.uint32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, T), lambda t, ts, td, fi, sc: (t, 0, 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (ts[t], 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (td[t], 0)),
        ],
        out_specs=pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (td[t], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_expand_q_kernel, num_words=W, tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Vp, W), jnp.uint32),
        interpret=interpret,
        compiler_params=expand_grid_params(),
    )(tile_src, tile_dst, first_of_dst, scalars,
      q8_tiles, frontier, visited)
    covered = jnp.zeros((n_blocks,), jnp.uint32).at[tile_dst].set(1)
    return out * jnp.repeat(covered, T)[:, None]


def _expand_q_gathered_kernel(ids_ref, tile_src_ref, tile_dst_ref,
                              first_ref, scalar_ref, q_ref, frontier_ref,
                              visited_ref, out_ref,
                              *, num_words: int, tile_size: int):
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seed = scalar_ref[0]
    level = scalar_ref[1]
    q8 = q_ref[0]
    fr = frontier_ref[...]
    vis = visited_ref[...]
    T = tile_size
    row = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 1)
    # RNG counters derive from the ORIGINAL tile id (prefetched), not the
    # grid position — the compacted grid must draw the dense grid's bits.
    cell = (ids_ref[t].astype(jnp.uint32) * jnp.uint32(T * T)
            + row * jnp.uint32(T) + col)

    for w in range(num_words):
        rand_w = _bern_word_q(seed, level, cell, jnp.uint32(w), q8)
        x = fr[:, w][:, None] & rand_w
        n = T
        while n > 1:
            n //= 2
            x = x[:n] | x[n:]
        out_ref[:, w] |= x[0] & ~vis[:, w]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_expand_q_gathered(q8_gathered, tile_ids, tile_src, tile_dst,
                            first_of_dst, frontier, visited, seed, level,
                            *, interpret=True):
    """Sparse-grid variant of `fused_expand_q`: the grid iterates a
    compacted (dst-sorted, null-padded) tile list; ``tile_ids`` carries
    each slot's ORIGINAL tile id so the position-derived RNG counters
    match the dense grid bit for bit."""
    nt, T, _ = q8_gathered.shape
    _, W = frontier.shape
    Vp = visited.shape[0]
    n_blocks = Vp // T
    scalars = jnp.asarray([seed, level], jnp.uint32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, T), lambda t, ids, ts, td, fi, sc: (t, 0, 0)),
            pl.BlockSpec((T, W), lambda t, ids, ts, td, fi, sc: (ts[t], 0)),
            pl.BlockSpec((T, W), lambda t, ids, ts, td, fi, sc: (td[t], 0)),
        ],
        out_specs=pl.BlockSpec(
            (T, W), lambda t, ids, ts, td, fi, sc: (td[t], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_expand_q_gathered_kernel, num_words=W,
                          tile_size=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Vp, W), jnp.uint32),
        interpret=interpret,
        compiler_params=expand_grid_params(),
    )(tile_ids, tile_src, tile_dst, first_of_dst, scalars,
      q8_gathered, frontier, visited)
    covered = jnp.zeros((n_blocks,), jnp.uint32).at[tile_dst].set(1)
    return out * jnp.repeat(covered, T)[:, None]


def fused_expand_q_ref(q8_tiles, tile_src, tile_dst, frontier, visited,
                       seed, level):
    """Pure-jnp oracle with identical counters/quantization semantics."""
    nt, T, _ = q8_tiles.shape
    W = frontier.shape[1]
    n_blocks = visited.shape[0] // T
    fr_blocks = frontier.reshape(-1, T, W)
    vis_blocks = visited.reshape(n_blocks, T, W)
    row = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 0)
    col = jax.lax.broadcasted_iota(jnp.uint32, (T, T), 1)

    def one_tile(tid, q8, ts, td):
        cell = (tid.astype(jnp.uint32) * jnp.uint32(T * T)
                + row * jnp.uint32(T) + col)
        F = fr_blocks[ts]
        V = vis_blocks[td]

        def one_word(w):
            rand_w = _bern_word_q(seed, level, cell, jnp.uint32(w), q8)
            x = F[:, w][:, None] & rand_w
            return jax.lax.reduce(x, jnp.uint32(0), jnp.bitwise_or, (0,))

        contrib = jax.vmap(one_word, out_axes=1)(
            jnp.arange(W, dtype=jnp.uint32))
        return contrib & ~V

    contribs = jax.vmap(one_tile)(jnp.arange(nt), q8_tiles, tile_src,
                                  tile_dst)
    from repro.core import bitmask
    out = jnp.zeros_like(visited).reshape(n_blocks, T, W)
    out = bitmask.pack_bits(
        bitmask.unpack_bits(out).at[tile_dst].max(
            bitmask.unpack_bits(contribs)))
    return out.reshape(-1, W)
