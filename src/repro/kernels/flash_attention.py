"""Pallas TPU kernel: blocked online-softmax (flash) attention.

The LM substrate's prefill at 32K context cannot materialize (L, L) score
matrices (32768² × 2B ≈ 2 GiB per head); this kernel streams K/V blocks
through VMEM with the online-softmax recurrence, so the working set is
O(block_q · block_k) per grid step.  Matmul dims are MXU-aligned (blocks are
multiples of 128; D is the head dim).

Layout: q (Lq, H, D), k/v (Lk, H, D), grid (H, Lq/bq, Lk/bk) with the K axis
innermost and sequential (accumulation).  ``kv_offset`` shifts query
positions for decode: query i attends to keys ≤ i + kv_offset.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, kv_offset: int,
                  block_q: int, block_k: int, num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[:, 0, :].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[:, 0, :].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + kv_offset
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)

    m_prev = m_ref[...]                                     # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)              # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                         # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    m_ref[...] = m_new
    v = v_ref[:, 0, :].astype(jnp.float32)                  # (bk, D)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[:, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "kv_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, scale=None, kv_offset=0,
                    block_q=128, block_k=128, interpret=True):
    """See module docstring. q: (Lq, H, D); k, v: (Lk, H, D)."""
    Lq, H, D = q.shape
    Lk = k.shape[0]
    scale = float(scale) if scale is not None else D ** -0.5
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, "pad sequence to block multiples"
    nq, nk = Lq // bq, Lk // bk

    grid = (H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, kv_offset=kv_offset,
        block_q=bq, block_k=bk, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1, D), lambda h, i, j: (i, h, 0)),
            pl.BlockSpec((bk, 1, D), lambda h, i, j: (j, h, 0)),
            pl.BlockSpec((bk, 1, D), lambda h, i, j: (j, h, 0)),
        ],
        out_specs=pl.BlockSpec((bq, 1, D), lambda h, i, j: (i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Lq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v)
