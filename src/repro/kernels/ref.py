"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *definition* of its kernel's semantics; kernel tests
sweep shapes/dtypes and assert bit-exact (integer kernels) or allclose
(float kernels) agreement in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitmask, rng


def _tile_expand(gate_fn, gate_args, tile_src, tile_dst, frontier, visited):
    """Shared tile-expansion scaffolding for the traversal oracles:

        out[dst] = OR over tiles( OR_src(frontier[src] & gate) ) & ~visited[dst]

    ``gate_fn((per-tile arrays), td) -> (T, T, W)`` packed gate words — the
    IC Bernoulli draw or the LT live-edge selection; ``gate_args`` is a
    tuple of (nt, T, T) arrays vmapped alongside tile_src/tile_dst.  One
    scaffolding, so IC and LT can never diverge on the reshape / OR-reduce
    / scatter-max mechanics the kernel tests pin down.
    """
    T = gate_args[0].shape[1]
    W = frontier.shape[1]
    n_blocks = visited.shape[0] // T
    fr_blocks = frontier.reshape(-1, T, W)
    vis_blocks = visited.reshape(n_blocks, T, W)

    def one_tile(args, ts, td):
        F = fr_blocks[ts]                                   # (T, W)
        V = vis_blocks[td]                                  # (T, W)
        x = F[:, None, :] & gate_fn(args, td)               # (T, T, W)
        contrib = jax.lax.reduce(x, jnp.uint32(0),
                                 jnp.bitwise_or, (0,))      # (T, W) per dst
        return contrib & ~V

    contribs = jax.vmap(one_tile)(gate_args, tile_src, tile_dst)  # (nt,T,W)
    out = jnp.zeros_like(visited).reshape(n_blocks, T, W)
    out = bitmask.pack_bits(
        bitmask.unpack_bits(out).at[tile_dst].max(bitmask.unpack_bits(contribs)))
    return out.reshape(-1, W)


def fused_expand_ref(prob, edge_id, tile_src, tile_dst, frontier, visited,
                     seed, level):
    """Oracle for kernels.fused_expand — one level of tile-based expansion.

    Args:
      prob:     (nt, T, T) f32 tile activation probabilities (0 ⇒ no edge).
      edge_id:  (nt, T, T) uint32 CSR edge ids (RNG counters).
      tile_src: (nt,) i32 source block per tile (indexes ``frontier``).
      tile_dst: (nt,) i32 destination block per tile (indexes ``visited``).
      frontier: (Vf, W) uint32 packed color mask (padded rows).
      visited:  (Vo, W) uint32 — ALREADY folded with the current frontier.
                Vo == Vf single-device; Vo = shard rows graph-parallel.
      seed, level: uint32 RNG counters.
    Returns:
      next_frontier (Vo, W) uint32 = OR over tiles of
        OR_i( frontier[src_i] & Bernoulli_word(edge) ) & ~visited[dst]
    """
    W = frontier.shape[1]

    def gate(args, td):
        p, eid = args
        word_ids = jnp.arange(W, dtype=jnp.uint32)
        # (T, T, W): Bernoulli word for every (src-lane, dst-lane, word).
        return jax.vmap(
            lambda w: rng.bernoulli_word(seed, level, eid, w, p),
            out_axes=-1)(word_ids)

    return _tile_expand(gate, (prob, edge_id), tile_src, tile_dst,
                        frontier, visited)


def lt_selection_uniforms(seed, num_rows: int, num_colors: int, row_base=0):
    """(num_rows, W·32) f32 LT selection uniforms ``u(dst, color)`` — the
    same (seed, 0x17, dst, color) counters as `lt.selection_mask_from_cb`,
    one per (destination vertex, color lane).  Level-independent, so
    callers compute this ONCE per traversal and reuse it across every level
    and tile (tiles sharing a destination block would otherwise redo
    identical hash work).  ``row_base`` is the global vertex id of row 0 —
    0 single-device, ``shard · rows_per_shard`` under a graph-parallel row
    partition (the hash needs GLOBAL ids).  Lanes pad to full words like
    the dense path; padded lanes never meet a live frontier bit."""
    seed = jnp.asarray(seed, jnp.uint32)
    dstv = (row_base + jnp.arange(num_rows, dtype=jnp.int32)) \
        .astype(jnp.uint32)
    lanes = jnp.arange(bitmask.num_words(num_colors) * 32, dtype=jnp.uint32)
    return rng.uniform_from_u32(
        rng.hash_u32(seed, jnp.uint32(0x17), dstv[:, None], lanes[None, :]))


def lt_select_expand_ref(prob, cb, tile_src, tile_dst, frontier, visited, u):
    """One level of tile-based expansion under the LT live-edge selection.

    Same tile formulation as `fused_expand_ref`, but the per-(edge, color)
    Bernoulli gate is replaced by the fixed LT selection (`core.lt`): edge
    ``(src, dst)`` carries color ``c`` iff ``cb ≤ u(dst, c) < cb + prob``
    — bit-identical to the dense `lt.selection_mask_from_cb` sweep without
    materializing the (E, W) selection mask.

    Args:
      prob:     (nt, T, T) f32 LT-normalized in-weights (0 ⇒ no edge).
      cb:       (nt, T, T) f32 selection-CDF prefix per edge slot
                (`tiles.edge_values_to_tiles` of `lt.selection_cum_before`).
      tile_src: (nt,) i32 source block per tile (indexes ``frontier``).
      tile_dst: (nt,) i32 destination block per tile (indexes ``visited``).
      frontier: (Vf, W) uint32 packed color mask (padded rows).
      visited:  (Vo, W) uint32 — ALREADY folded with the current frontier.
                Vo == Vf single-device; Vo = shard rows graph-parallel.
      u:        (Vo, W·32) f32 from `lt_selection_uniforms` — rows aligned
                with ``visited``, computed once per traversal by the caller.
    """
    T = prob.shape[1]
    u_blocks = u.reshape(-1, T, u.shape[1])

    def gate(args, td):
        p, cbt = args
        U = u_blocks[td]                                    # (T_dst, W·32)
        # One broadcast compare for every (src, dst, color) at once —
        # colors group row-major into words, lane c%32 = bit c%32, exactly
        # the per-lane packing order of the dense path.
        sel = jnp.logical_and(U[None, :, :] >= cbt[:, :, None],
                              U[None, :, :] < (cbt + p)[:, :, None])
        return rng.pack_bool_word(
            sel.reshape(T, T, -1, 32))                      # (T, T, W)

    return _tile_expand(gate, (prob, cb), tile_src, tile_dst,
                        frontier, visited)


def cover_counts_ref(visited, active):
    """Oracle for kernels.coverage — marginal-gain counts for max-k-cover.

    counts[v] = |{colors c : visited[v, c] ∧ active[c]}|
    """
    return jnp.sum(bitmask.popcount(visited & active[None, :]),
                   axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal=True, scale=None, kv_offset=0):
    """Oracle for kernels.flash_attention — plain softmax attention.

    q: (Lq, H, D), k/v: (Lk, H, D).  ``kv_offset`` shifts query positions for
    decode (query i attends keys ≤ i + kv_offset).
    """
    d = q.shape[-1]
    scale = scale or d ** -0.5
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(q.shape[0])[:, None] + kv_offset
        ki = jnp.arange(k.shape[0])[None, :]
        logits = jnp.where((ki <= qi)[None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v.astype(jnp.float32)).astype(q.dtype)
