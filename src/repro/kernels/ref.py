"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the *definition* of its kernel's semantics; kernel tests
sweep shapes/dtypes and assert bit-exact (integer kernels) or allclose
(float kernels) agreement in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitmask, rng


def fused_expand_ref(prob, edge_id, tile_src, tile_dst, frontier, visited,
                     seed, level):
    """Oracle for kernels.fused_expand — one level of tile-based expansion.

    Args:
      prob:     (nt, T, T) f32 tile activation probabilities (0 ⇒ no edge).
      edge_id:  (nt, T, T) uint32 CSR edge ids (RNG counters).
      tile_src: (nt,) i32 source block per tile (indexes ``frontier``).
      tile_dst: (nt,) i32 destination block per tile (indexes ``visited``).
      frontier: (Vf, W) uint32 packed color mask (padded rows).
      visited:  (Vo, W) uint32 — ALREADY folded with the current frontier.
                Vo == Vf single-device; Vo = shard rows graph-parallel.
      seed, level: uint32 RNG counters.
    Returns:
      next_frontier (Vo, W) uint32 = OR over tiles of
        OR_i( frontier[src_i] & Bernoulli_word(edge) ) & ~visited[dst]
    """
    T = prob.shape[1]
    W = frontier.shape[1]
    n_blocks = visited.shape[0] // T
    fr_blocks = frontier.reshape(-1, T, W)
    vis_blocks = visited.reshape(n_blocks, T, W)

    def one_tile(p, eid, ts, td):
        F = fr_blocks[ts]                                   # (T, W)
        V = vis_blocks[td]                                  # (T, W)
        word_ids = jnp.arange(W, dtype=jnp.uint32)
        # (T, T, W): Bernoulli word for every (src-lane, dst-lane, word).
        rand = jax.vmap(
            lambda w: rng.bernoulli_word(seed, level, eid, w, p),
            out_axes=-1)(word_ids)
        x = F[:, None, :] & rand                            # (T, T, W)
        contrib = jax.lax.reduce(x, jnp.uint32(0),
                                 jnp.bitwise_or, (0,))      # (T, W) per dst
        return contrib & ~V

    contribs = jax.vmap(one_tile)(prob, edge_id, tile_src, tile_dst)  # (nt,T,W)
    out = jnp.zeros_like(visited).reshape(n_blocks, T, W)
    out = bitmask.pack_bits(
        bitmask.unpack_bits(out).at[tile_dst].max(bitmask.unpack_bits(contribs)))
    return out.reshape(-1, W)


def cover_counts_ref(visited, active):
    """Oracle for kernels.coverage — marginal-gain counts for max-k-cover.

    counts[v] = |{colors c : visited[v, c] ∧ active[c]}|
    """
    return jnp.sum(bitmask.popcount(visited & active[None, :]),
                   axis=-1).astype(jnp.int32)


def flash_attention_ref(q, k, v, *, causal=True, scale=None, kv_offset=0):
    """Oracle for kernels.flash_attention — plain softmax attention.

    q: (Lq, H, D), k/v: (Lk, H, D).  ``kv_offset`` shifts query positions for
    decode (query i attends keys ≤ i + kv_offset).
    """
    d = q.shape[-1]
    scale = scale or d ** -0.5
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qi = jnp.arange(q.shape[0])[:, None] + kv_offset
        ki = jnp.arange(k.shape[0])[None, :]
        logits = jnp.where((ki <= qi)[None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", p, v.astype(jnp.float32)).astype(q.dtype)
