"""jax version compat for the Pallas TPU kernels.

jax 0.4.x names the compiler-params dataclass ``TPUCompilerParams``; newer
jax renamed it to ``CompilerParams``.  Resolved once here so every kernel
runs on both (the shard_map analogue lives in `repro.distributed.compat`).
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)


def expand_grid_params():
    """Compiler params shared by every tile-expansion kernel (fused_expand,
    fused_expand_q, lt_select_expand): a sequential ("arbitrary") grid, so
    the revisiting accumulation over dst-sorted tiles is legal.  One
    constructor, so the next jax params rename is a one-line change here
    (flash_attention declares its own — its semantics differ)."""
    return CompilerParams(dimension_semantics=("arbitrary",))
