"""jax version compat for the Pallas TPU kernels.

jax 0.4.x names the compiler-params dataclass ``TPUCompilerParams``; newer
jax renamed it to ``CompilerParams``.  Resolved once here so every kernel
runs on both (the shard_map analogue lives in `repro.distributed.compat`).
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)
