"""Pallas TPU kernel: LT live-edge selection + frontier expansion per tile.

The LT analogue of `kernels.fused_expand`: one grid step processes one
non-empty T×T adjacency tile entirely in VMEM, but the per-(edge, color)
Bernoulli gate is replaced by the *fixed* LT live-edge selection — edge
``(src, dst)`` carries color ``c`` iff

    cb[src, dst] ≤ u(dst, c) < cb[src, dst] + prob[src, dst]

where ``cb`` is the per-edge selection-CDF prefix
(`tiles.edge_values_to_tiles(tg, lt.selection_cum_before(g))`) and ``u`` is
the level-independent per-(dst, color) uniform table
(`kernels.ref.lt_selection_uniforms`), computed ONCE per traversal by the
caller and block-sliced per grid step by destination block.  No RNG runs
inside the kernel at all: the selection is a pure f32 interval test, so the
tile needs only two f32 stencils (prob, cb) plus a (T, W·32) slice of the
uniform table.

Tiles are pre-sorted by destination block (revisiting accumulation,
zero-init on ``first_of_dst``) exactly like the IC kernel, and the gate
computation reproduces `ref.lt_select_expand_ref` term for term, so the
kernel is bit-for-bit equal to the oracle and to the dense
``lt.run_fused_lt`` sweep.

VMEM budget per grid step (T=128, W words):
    prob + cb tiles        2·128·128·4   = 128 KiB
    uniform slice          128·W·32·4    = 16·W KiB
    frontier/visited/out   3·128·W·4
    transient sel lanes    128·128·32·4  = 2 MiB    (dominates; fits 16 MiB)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng
from repro.kernels.compat import expand_grid_params
from repro.kernels.fused_expand import _or_reduce_rows


def _lt_kernel(tile_src_ref, tile_dst_ref, first_ref,
               prob_ref, cb_ref, u_ref, frontier_ref, visited_ref, out_ref,
               *, num_words: int):
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    prob = prob_ref[0]                      # (T, T) f32, rows = src lanes
    cb = cb_ref[0]                          # (T, T) f32 selection-CDF prefix
    u = u_ref[...]                          # (T, W·32) f32, rows = dst lanes
    fr = frontier_ref[...]                  # (T, W) u32, rows = src lanes
    vis = visited_ref[...]                  # (T, W) u32, rows = dst lanes
    hi = cb + prob

    for w in range(num_words):              # static unroll over color words
        U = u[:, w * 32:(w + 1) * 32]       # (T_dst, 32) lane uniforms
        # Fixed live-edge selection for every (src, dst, color) at once —
        # identical interval test (and f32 rounding) to the ref oracle.
        sel = jnp.logical_and(U[None, :, :] >= cb[:, :, None],
                              U[None, :, :] < hi[:, :, None])
        gate = rng.pack_bool_word(sel)      # (T, T): src lane i → dst lane j
        x = fr[:, w][:, None] & gate
        contrib = _or_reduce_rows(x)        # (T,) per-dst OR over sources
        out_ref[:, w] |= contrib & ~vis[:, w]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lt_select_expand(tg_prob, cb_tiles, tile_src, tile_dst, first_of_dst,
                     frontier, visited, u, *, interpret=True):
    """One fused-LT level on the tiled graph.  See module docstring.

    ``frontier`` is (Vf, W) and ``visited`` (Vo, W), both multiples of T;
    ``u`` is (Vo, W·32) from `ref.lt_selection_uniforms`, rows aligned with
    ``visited`` (global-id hashed, so graph-parallel shards pass their row
    slice).  ``visited`` must already include the current frontier.
    """
    nt, T, _ = tg_prob.shape
    _, W = frontier.shape
    Vp = visited.shape[0]
    n_blocks = Vp // T
    UW = u.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, T), lambda t, ts, td, fi: (t, 0, 0)),
            pl.BlockSpec((1, T, T), lambda t, ts, td, fi: (t, 0, 0)),
            pl.BlockSpec((T, UW), lambda t, ts, td, fi: (td[t], 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi: (ts[t], 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi: (td[t], 0)),
        ],
        out_specs=pl.BlockSpec((T, W), lambda t, ts, td, fi: (td[t], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_lt_kernel, num_words=W),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Vp, W), jnp.uint32),
        interpret=interpret,
        compiler_params=expand_grid_params(),
    )(tile_src, tile_dst, first_of_dst,
      tg_prob, cb_tiles, u, frontier, visited)

    # Destination blocks with no incoming tile were never written; Pallas
    # leaves them undefined — mask them via the tile_dst coverage set.
    covered = jnp.zeros((n_blocks,), jnp.uint32).at[tile_dst].set(1)
    return out * jnp.repeat(covered, T)[:, None]
