"""Pallas TPU kernel: max-k-cover marginal-gain counts.

Seed selection (Listing 1 lines 18-21 + IMM's greedy max-cover) reduces the
(V, W) visited bitmask against the mask of still-uncovered colors:

    counts[v] = Σ_w popcount(visited[v, w] & active[w])

On GPUs this is the atomic-append RRR-set construction; on TPU it is a
bandwidth-bound row sweep — one grid step reduces a (T, W) row block in VMEM
with SWAR popcounts and writes a (1, T) count row (lane dim = T = 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitmask


def _coverage_kernel(vis_ref, act_ref, out_ref):
    vis = vis_ref[...]                       # (T, W) uint32
    act = act_ref[...]                       # (1, W) uint32
    counts = jnp.sum(bitmask.popcount(vis & act), axis=-1)
    out_ref[0, :] = counts.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def cover_counts(visited, active, *, block_rows: int = 128, interpret=True):
    """counts[v] = popcount(visited[v] & active) — see module docstring.

    visited: (Vp, W) uint32 with Vp a multiple of ``block_rows``.
    active:  (W,) uint32 mask of not-yet-covered colors.
    """
    Vp, W = visited.shape
    T = block_rows
    assert Vp % T == 0, f"pad rows to a multiple of {T}"
    n_blocks = Vp // T
    out = pl.pallas_call(
        _coverage_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((T, W), lambda i: (i, 0)),
            pl.BlockSpec((1, W), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, T), jnp.int32),
        interpret=interpret,
    )(visited, active[None, :])
    return out.reshape(Vp)
