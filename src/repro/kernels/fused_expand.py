"""Pallas TPU kernel: fused-BPT frontier expansion over block-sparse tiles.

This is the compute hot-spot the paper optimizes (its GPU kernels in §4).
TPU adaptation (DESIGN.md §2): one grid step processes one non-empty T×T
adjacency tile entirely in VMEM —

    out[dst_blk] |= ( OR_i frontier[src_blk][i] & Bernoulli_word(edge ij) )
                    & ~visited[dst_blk]

Tiles are pre-sorted by destination block, so all grid steps writing one
output block are consecutive and the kernel uses the Pallas *revisiting*
accumulation pattern (zero-init on ``first_of_dst``).  The per-(edge, color)
Bernoulli draws use the same counter hash as the pure-JAX paths, so the
kernel is bit-for-bit equal to ``ref.fused_expand_ref`` and to the CSR
edge-centric traversal.

VMEM budget per grid step (T=128, W words):
    prob tile        128·128·4      =  64 KiB
    edge-id tile     128·128·4      =  64 KiB
    frontier/visited/out blocks     3·128·W·4
    transient rand lanes 128·128·32·4 = 2 MiB      (dominates; fits 16 MiB)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import expand_grid_params

from repro.core import rng


def _or_reduce_rows(x: jnp.ndarray) -> jnp.ndarray:
    """OR-fold axis 0 (length power-of-two) with a log2 tree of full-lane ops."""
    n = x.shape[0]
    while n > 1:
        n //= 2
        x = x[:n] | x[n:]
    return x[0]


def _expand_kernel(tile_src_ref, tile_dst_ref, first_ref, scalar_ref,
                   prob_ref, eid_ref, frontier_ref, visited_ref, out_ref,
                   *, num_words: int):
    t = pl.program_id(0)

    @pl.when(first_ref[t] == 1)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seed = scalar_ref[0]
    level = scalar_ref[1]
    prob = prob_ref[0]                      # (T, T) f32
    eid = eid_ref[0]                        # (T, T) u32
    fr = frontier_ref[...]                  # (T, W) u32, rows = src lanes
    vis = visited_ref[...]                  # (T, W) u32, rows = dst lanes

    for w in range(num_words):              # static unroll over color words
        # Independent Bernoulli(p_e) per (edge, color lane): 32 hash lanes.
        rand_w = rng.bernoulli_word(seed, level, eid, jnp.uint32(w), prob)
        x = fr[:, w][:, None] & rand_w      # (T, T): src lane i → dst lane j
        contrib = _or_reduce_rows(x)        # (T,) per-dst OR over sources
        out_ref[:, w] |= contrib & ~vis[:, w]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_expand(tg_prob, tg_eid, tile_src, tile_dst, first_of_dst,
                 frontier, visited, seed, level, *, interpret=True):
    """One fused-BPT level on the tiled graph.  See module docstring.

    ``frontier`` is (Vf, W) and ``visited`` (Vo, W), both multiples of T.
    ``tile_src`` indexes frontier blocks, ``tile_dst`` visited/output blocks;
    on the single-device path Vf == Vo, on the graph-parallel path the
    frontier is the all-gathered global mask while visited/output are the
    shard-local rows.  ``visited`` must already include the current frontier
    (level-sync semantics).
    """
    nt, T, _ = tg_prob.shape
    _, W = frontier.shape
    Vp = visited.shape[0]
    n_blocks = Vp // T
    scalars = jnp.asarray([seed, level], jnp.uint32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, T, T), lambda t, ts, td, fi, sc: (t, 0, 0)),
            pl.BlockSpec((1, T, T), lambda t, ts, td, fi, sc: (t, 0, 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (ts[t], 0)),
            pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (td[t], 0)),
        ],
        out_specs=pl.BlockSpec((T, W), lambda t, ts, td, fi, sc: (td[t], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_expand_kernel, num_words=W),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Vp, W), jnp.uint32),
        interpret=interpret,
        compiler_params=expand_grid_params(),      # sequential: accumulation
    )(tile_src, tile_dst, first_of_dst, scalars,
      tg_prob, tg_eid, frontier, visited)

    # Destination blocks with no incoming tile were never written; Pallas
    # leaves them undefined — mask them via the tile_dst coverage set.
    covered = jnp.zeros((n_blocks,), jnp.uint32).at[tile_dst].set(1)
    return out * jnp.repeat(covered, T)[:, None]
