"""Distributed fused-BPT traversal (DESIGN.md §3).

Two orthogonal axes, composable on one mesh:

* **Sample parallelism** (paper's multi-node axis, Fig. 10): independent
  fused batches sharded over ``data`` (and ``pod``).  Zero collectives
  during traversal; one reduction at seed selection.  This is what scaled
  to 32,768 GPUs in the paper.
* **Graph parallelism** (beyond-paper): 1-D destination-row partition over
  ``model``.  Each level all-gathers the (sparse, packed) frontier and
  expands only locally-owned tiles — the collective-bound cell of the
  roofline analysis.

``graph_parallel_block`` composes the two on ONE mesh: batches sharded over
``data``, rows over ``model``, every collective naming only the model axis
— the program behind the `repro.sampling` ``graph_parallel`` backend (IC
and LT; LT derives its live-edge selection shard-locally from global
destination ids — one local-rows-sized uniform table per traversal — so
no (E, W) selection mask is ever replicated).

All paths reuse the exact single-device expansion math (coupled RNG), so
distributed results are bit-for-bit equal to single-device runs; tests
assert this under a forced multi-device host platform.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bitmask, rng, tiles
from repro.core.traversal import init_frontier
from repro.graph import csr, partition as part_lib
from repro.kernels import ref as kref


# ------------------------------------------------------------ sample parallel
def run_batch(g: csr.Graph, starts, seed, num_colors: int,
              max_levels: int = 64):
    """One fused batch as a jit-friendly pure function of (graph, starts,
    seed) — the unit that sample parallelism vmaps/shards."""
    from repro.core.traversal import fused_step

    frontier = init_frontier(g.num_vertices, num_colors, starts)
    visited = jnp.zeros_like(frontier)

    def cond(c):
        fr, _, lvl = c
        return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

    def body(c):
        fr, vis, lvl = c
        nf, nv, _ = fused_step(g, fr, vis, lvl, seed)
        return nf, nv, lvl + 1

    fr, vis, _ = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0)))
    return vis | fr


def sample_parallel_fn(g: csr.Graph, all_starts, batch_seeds,
                       num_colors: int, max_levels: int = 64):
    """vmapped batch sweep; shard the batch dim over data axes, replicate
    the graph — exactly the paper's node-level strategy."""
    return jax.vmap(
        lambda s, sd: run_batch(g, s, sd, num_colors, max_levels)
    )(all_starts, batch_seeds)


def sample_parallel_visited(g: csr.Graph, all_starts: jnp.ndarray,
                            batch_seeds: jnp.ndarray, num_colors: int,
                            mesh: Mesh, axes=("data",),
                            max_levels: int = 64) -> jnp.ndarray:
    """Run B independent fused batches, sharded over ``axes``.

    all_starts: (B, C) start vertices; batch_seeds: (B,) uint32.
    Returns visited (B, V, W) sharded over the batch dim.
    """
    sharding = NamedSharding(mesh, P(axes))
    replicated = NamedSharding(mesh, P())
    fn = jax.jit(
        partial(sample_parallel_fn, num_colors=num_colors,
                max_levels=max_levels),
        in_shardings=(jax.tree.map(lambda _: replicated, g),
                      sharding, sharding),
        out_shardings=sharding)
    return fn(g, jax.device_put(all_starts, sharding),
              jax.device_put(batch_seeds, sharding))


def distributed_greedy_max_cover(visited: jnp.ndarray, k: int,
                                 num_colors: int, mesh: Mesh,
                                 axes=("data",)):
    """Greedy max-k-cover with the RRR collection sharded over batches.

    The marginal-gain reduction over the batch axis becomes an all-reduce
    (GSPMD inserts it); selection state (``active``) is sharded alongside.
    """
    b, v, w = visited.shape
    sharding = NamedSharding(mesh, P(axes))
    visited = jax.device_put(visited, sharding)
    active = jax.device_put(
        jnp.broadcast_to(jnp.asarray(bitmask.color_tail_mask(num_colors)),
                         (b, w)), sharding)

    @jax.jit
    def gain_counts(vis, act):
        return jnp.sum(bitmask.popcount(vis & act[:, None, :]), axis=(0, 2),
                       dtype=jnp.int32)          # (V,) — cross-batch psum

    @jax.jit
    def knock_out(act, vis_row):
        return act & ~vis_row

    seeds = []
    for _ in range(k):
        counts = gain_counts(visited, active)
        sel = int(jnp.argmax(counts))
        seeds.append(sel)
        active = knock_out(active, visited[:, sel, :])
    theta = b * num_colors
    covered = theta - int(jnp.sum(bitmask.popcount(active)))
    return np.asarray(seeds, np.int32), covered / theta


# ------------------------------------------------------------- graph parallel
def gather_capacity_words(rows: int, num_words: int, capacity: int = 0) -> int:
    """Per-shard capacity (packed words) of the sparse frontier all-gather.

    ``capacity = 0`` (auto) budgets an eighth of the shard's ``rows × W``
    words, rounded up to a power of two — levels above it (the dense early
    levels of Fig. 9) take the full all-gather, levels below it (the
    collapsed tail, where ButterFly BFS shows full gathers waste
    bandwidth) ship only the active words."""
    n = rows * num_words
    want = capacity if capacity > 0 else max(n // 8, 1)
    k = 1
    while k < min(want, n):
        k *= 2
    return min(k, n)


def _frontier_gather_loop(expand, frontier_local, max_levels: int, axis: str,
                          num_shards: int = 1, sparse_words: int = 0,
                          sync_axes: tuple = ()):
    """THE graph-parallel level loop: per-level frontier exchange over
    ``axis``, local expansion, psum-agreed termination.  ``expand`` maps
    (fr_global (Vp, W), vis_local (rows, W), level) → new local frontier.
    Returns (visited_local, levels, gather_words) where ``gather_words``
    is a (max_levels,) int32 vector of the packed words each level moved
    over ``axis`` (summed across shards; replicated, zero past the last
    level) — the interconnect-traffic observable `bench_pool_build`
    records per level.  The exchange collectives name only ``axis``, but
    the loop's CONTROL decisions (keep going? sparse or dense leg?)
    reduce over ``sync_axes`` (default: just ``axis``): every mesh axis
    named there runs the level loop in lockstep, which real SPMD
    execution implies anyway and the host-device emulation REQUIRES —
    ``ppermute`` lowers to one collective-permute spanning every device,
    so shards that diverge on trip count or branch deadlock the
    rendezvous.  A shard whose frontier drained early just exchanges
    zeros until the slowest sibling finishes (recorded in its
    ``gather_words`` — that traffic really moves in lockstep SPMD).

    ``sparse_words > 0`` arms the ButterFly-BFS-style sparse leg: each
    level, every shard counts its nonzero frontier words and a pmax over
    ``sync_axes`` agrees on the global maximum; when it fits the
    capacity, shards compact their frontier to ``(word_idx, word)`` pairs
    and run the ``⌈log₂ S⌉``-stage pairwise exchange
    (`_butterfly_exchange`) — each stage ships only the pairs accumulated
    so far, so tail levels stop paying the ``S × rows × W`` dense gather.
    Overflowing levels fall back to the dense all-gather via ``lax.cond``
    — the pmax'd count is replicated, so every shard takes the same
    branch.  Either leg reconstructs the exact global frontier:
    bit-identical by construction.
    """
    rows, num_words = frontier_local.shape
    n = rows * num_words
    s = num_shards
    sync = sync_axes or (axis,)
    # Dense all-gather semantic traffic: every shard ships its n words to
    # the S-1 peers (0 when the model axis is trivial).
    dense_words = jnp.int32(s * (s - 1) * n)

    def dense_gather(fr):
        return jax.lax.all_gather(fr, axis, tiled=True)

    def dense_leg(fr):
        return dense_gather(fr), dense_words

    def butterfly_leg(fr):
        buf_i, buf_w, sent = _butterfly_exchange(fr, axis, s, n, sparse_words)
        return (_scatter_pairs(buf_i, buf_w, rows, num_words, s),
                jax.lax.psum(sent, axis))

    def cond(carry):
        fr, _, lvl, _ = carry
        any_local = bitmask.any_set(fr)
        any_global = jax.lax.psum(any_local.astype(jnp.int32), sync) > 0
        return jnp.logical_and(any_global, lvl < max_levels)

    def body(carry):
        fr, vis, lvl, gw = carry
        vis = vis | fr
        if sparse_words and sparse_words < n:
            nz = jnp.count_nonzero(fr).astype(jnp.int32)
            fits = jax.lax.pmax(nz, sync) <= sparse_words
            fr_global, words = jax.lax.cond(fits, butterfly_leg, dense_leg,
                                            fr)
        else:
            # THE collective: gather every shard's (rows, W) frontier words.
            fr_global = dense_gather(fr)
            words = dense_words
        gw = gw.at[lvl].set(words)
        nf = expand(fr_global, vis, lvl.astype(jnp.uint32))
        return nf, vis, lvl + 1, gw

    visited = jnp.zeros_like(frontier_local)
    fr, vis, lvl, gather_words = jax.lax.while_loop(
        cond, body, (frontier_local, visited, jnp.int32(0),
                     jnp.zeros((max_levels,), jnp.int32)))
    return vis | fr, lvl, gather_words


def _scatter_pairs(buf_i, buf_w, rows: int, num_words: int, num_shards: int):
    """Reconstruct the (S·rows, W) global frontier from the exchanged
    ``(global_word_idx, word)`` pairs (sentinel-padded capacity slots).

    Pad slots target a per-slot scratch word past the real rows, keeping
    EVERY scattered index globally unique (the packed fast path's
    contract); real global indices are disjoint per source shard."""
    s = num_shards
    n = rows * num_words
    rows_g = s * rows
    cap = buf_i.shape[0]
    sentinel = jnp.uint32(s * n)
    tgt = jnp.where(buf_i < sentinel, buf_i,
                    sentinel + jnp.arange(cap, dtype=jnp.uint32))
    scratch = -(-cap // num_words)
    buf = jnp.zeros((rows_g + scratch, num_words), jnp.uint32)
    full = bitmask.scatter_or_words(
        buf, (tgt // num_words).astype(jnp.int32),
        (tgt % num_words).astype(jnp.int32), buf_w, unique=True)
    return full[:rows_g]


def _butterfly_exchange(fr, axis: str, num_shards: int, n: int, k: int):
    """ButterFly-BFS-style dissemination all-gather of the compacted
    frontier (arXiv 2103.13577): ``⌈log₂ S⌉`` pairwise ``ppermute``
    stages instead of one flat all-gather.

    Each shard compacts its frontier to ≤ ``k`` ``(global_word_idx,
    word)`` pairs (the caller guarantees the fit via the pmax'd count).
    Stage ``t`` sends the WHOLE accumulated pair set to shard
    ``(i − 2ᵗ) mod S`` and receives from ``(i + 2ᵗ) mod S`` — after
    stage ``t`` every shard holds the pairs of source shards
    ``[i, i + 2ᵗ⁺¹)`` (mod S), so ⌈log₂ S⌉ stages cover any S,
    power-of-two or not.  A per-shard ``have`` bitmap drops re-delivered
    source blocks exactly (non-power-of-two schedules overlap on the
    last stage), and received pairs compact onto the end of the real
    prefix — the buffer doubles per stage (static shapes, capped at
    ``S·k``) so early stages ship tiny buffers.

    Returns ``(buf_idx (≤S·k,) uint32, buf_word (≤S·k,) uint32, sent)``
    — global word indices (pad slots carry the ``S·n`` sentinel), their
    words, and the packed words THIS shard shipped (pairs + count/have
    metadata); psum ``sent`` for the level's total traffic.  Real pair
    indices are globally unique: each global word index originates on
    exactly one shard and block dedup delivers it once.
    """
    s = num_shards
    flat = fr.reshape(-1)
    idx = jnp.nonzero(flat, size=k, fill_value=n)[0].astype(jnp.int32)
    w = jnp.where(idx < n, flat[jnp.minimum(idx, n - 1)], jnp.uint32(0))
    me = jax.lax.axis_index(axis).astype(jnp.int32)
    sentinel = jnp.uint32(s * n)
    buf_i = jnp.where(idx < n, (me * n + idx).astype(jnp.uint32), sentinel)
    buf_w = w
    count = jnp.count_nonzero(fr).astype(jnp.int32)
    have = jnp.zeros((s,), jnp.int32).at[me].set(1)
    sent = jnp.int32(0)
    shift = 1
    while shift < s:                     # static: unrolled ⌈log₂ S⌉ stages
        cap = buf_i.shape[0]
        perm = [(i, (i - shift) % s) for i in range(s)]
        payload = jnp.stack([buf_i, buf_w])                    # (2, cap)
        meta = jnp.concatenate([count[None], have])            # (S+1,)
        r_pay = jax.lax.ppermute(payload, axis, perm)
        r_meta = jax.lax.ppermute(meta, axis, perm)
        sent = sent + 2 * count + (s + 1)
        r_i, r_w = r_pay[0], r_pay[1]
        r_have = r_meta[1:]
        src_shard = jnp.minimum(r_i // n, s - 1).astype(jnp.int32)
        keep = (r_i < sentinel) & (have[src_shard] == 0)
        new_cap = min(2 * cap, s * k)
        ni = jnp.full((new_cap,), sentinel).at[:cap].set(buf_i)
        nw = jnp.zeros((new_cap,), jnp.uint32).at[:cap].set(buf_w)
        # Compact kept pairs onto the end of the real prefix; dropped
        # ones target new_cap (out of bounds → mode="drop").
        pos = count + jnp.cumsum(keep.astype(jnp.int32)) - 1
        pos = jnp.where(keep, pos, new_cap)
        buf_i = ni.at[pos].set(r_i, mode="drop")
        buf_w = nw.at[pos].set(r_w, mode="drop")
        count = count + jnp.sum(keep.astype(jnp.int32))
        have = jnp.minimum(have + r_have, 1)
        shift *= 2
    return buf_i, buf_w, sent


def _local_expand(ptg_local, diffusion: str, cb_local, seed, dst_block_base,
                  num_colors: int, use_kernel: bool = False,
                  interpret: bool = True):
    """Per-shard expansion closure over the shard's (leading-dim-1) tile
    stacks: IC draws per-(edge, color, level) Bernoullis keyed by CSR edge
    id; LT derives the fixed live-edge selection from GLOBAL destination
    vertex ids (``dst_block_base`` rebases the shard's local blocks), with
    the level-independent uniform table built ONCE here — before the level
    loop — and reused by every level's expansion.

    ``use_kernel=True`` runs each shard's partitioned tile stack through
    the Pallas tile kernels (`fused_expand` / `lt_select_expand`) instead
    of the jnp oracles — the tiles are dst-sorted within a shard with
    ``first_of_dst`` rebased per shard, and the kernels already accept a
    global frontier with shard-local visited rows, so the kernel grid is
    exactly the single-device one on the local stack (padding tiles are
    prob-0 and share the last real tile's dst block: inert).  Bits are
    identical either way."""
    if diffusion == "lt":
        from repro.kernels import lt_select_expand as lse
        rows = ptg_local.blocks_per_shard * ptg_local.tile_size
        u = kref.lt_selection_uniforms(
            seed, rows, num_colors,
            row_base=dst_block_base * ptg_local.tile_size)

        def expand(fr_global, vis_local, level):
            if use_kernel:
                return lse.lt_select_expand(
                    ptg_local.prob[0], cb_local[0], ptg_local.tile_src[0],
                    ptg_local.tile_dst[0], ptg_local.first_of_dst[0],
                    fr_global, vis_local, u, interpret=interpret)
            return kref.lt_select_expand_ref(
                ptg_local.prob[0], cb_local[0], ptg_local.tile_src[0],
                ptg_local.tile_dst[0], fr_global, vis_local, u)
    else:
        from repro.kernels import fused_expand as fe

        def expand(fr_global, vis_local, level):
            if use_kernel:
                return fe.fused_expand(
                    ptg_local.prob[0], ptg_local.edge_id[0],
                    ptg_local.tile_src[0], ptg_local.tile_dst[0],
                    ptg_local.first_of_dst[0], fr_global, vis_local,
                    seed, level, interpret=interpret)
            return kref.fused_expand_ref(
                ptg_local.prob[0], ptg_local.edge_id[0],
                ptg_local.tile_src[0], ptg_local.tile_dst[0],
                fr_global, vis_local, seed, level)
    return expand


def graph_parallel_traversal(ptg: part_lib.PartitionedTiledGraph,
                             starts, num_colors: int, seed, mesh: Mesh,
                             axis: str = "model", max_levels: int = 64):
    """Fused BPT with the graph sharded across ``axis`` (1-D row partition).

    Returns (visited (V, W), levels).  Tile stacks enter shard_map with their
    leading shard dim consumed by the mesh axis.
    """
    from repro.distributed.compat import shard_map

    vp = ptg.padded_vertices
    frontier = tiles.pad_mask_rows(
        init_frontier(ptg.num_vertices, num_colors, starts), vp)
    seed = jnp.uint32(seed)

    def body(ptg_local, frontier_local):
        base = (jax.lax.axis_index(axis).astype(jnp.int32)
                * ptg_local.blocks_per_shard)
        expand = _local_expand(ptg_local, "ic", None, seed, base,
                               num_colors)
        vis, levels, _ = _frontier_gather_loop(expand, frontier_local,
                                               max_levels, axis,
                                               num_shards=ptg.num_shards)
        return vis, levels

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(part_lib.partition_specs(ptg, axis), P(axis)),
        out_specs=(P(axis), P()),
        check=False)
    visited, levels = jax.jit(fn)(ptg, frontier)
    return visited[: ptg.num_vertices], levels


# Module-level cache of compiled 2-D block programs, keyed on (mesh, axes,
# spec knobs, partition STATICS) — mirroring the data_parallel
# `_DP_BLOCK_FNS` fix: the partitioned graph is a traced argument and the
# program closes over statics only, so streaming deltas that rebind tile
# VALUES (same partition shape) reuse the compiled program instead of
# re-tracing per delta.
_GP_BLOCK_FNS: dict = {}


def graph_parallel_block(ptg: part_lib.PartitionedTiledGraph, mesh: Mesh, *,
                         data_axis: str = "data", model_axis: str = "model",
                         num_colors: int, max_levels: int = 64,
                         diffusion: str = "ic", frontier: str = "dense",
                         gather_capacity: int = 0, use_kernel: bool = False,
                         interpret: bool = True):
    """Build (or fetch the cached) 2-D (data × model) fused-BPT block program.

    The composition the `repro.sampling` ``graph_parallel`` backend runs:
    a block of B independent batches is sharded over ``data_axis`` while
    the graph's destination rows are sharded over ``model_axis`` — every
    device holds only its (batch slice × row slice).  The per-level
    frontier exchange names ONLY the model axis; the level loop's control
    decisions (termination, sparse-vs-dense leg) sync over BOTH axes so
    the whole mesh steps levels in lockstep — what SPMD execution implies
    anyway, and what keeps the butterfly's collective-permutes from
    deadlocking when data shards drain at different depths.

    Returns a jitted ``fn(ptg, starts, seeds)`` (IC) or
    ``fn(ptg, cb_tiles, starts, seeds)`` (LT, ``cb_tiles`` =
    `partition_tile_values` of the selection-CDF prefixes) mapping
    starts (B, C) int32 / seeds (B,) uint32, both sharded ``P(data_axis)``,
    to ``(visited, gather_words)``: visited (B, Vp, W) uint32 sharded
    ``P(data_axis, model_axis)`` and gather_words (B, max_levels) int32
    sharded ``P(data_axis)`` — per batch, the packed words each level
    moved over the model axis (replicated across model shards).
    B must be a multiple of the data-axis size (callers pad).

    The tile stacks are runtime ARGUMENTS (closing over them would bake
    them into the jit program as replicated constants, defeating the row
    partition); the program itself closes over partition STATICS only
    (vertex/row counts, tile size, shard counts), so any
    ``PartitionedTiledGraph`` with the same statics — e.g. a streaming
    rebind that swapped tile values in place — runs through the same
    cached program.

    ``frontier="sparse"`` arms the ButterFly-style sparse leg of
    `_frontier_gather_loop` (log(M)-stage pairwise exchange of compacted
    (word_idx, word) pairs whenever the pmax'd active-word count fits
    ``gather_capacity`` words per shard, `gather_capacity_words` default)
    — same bits, less model-axis traffic on the collapsed late levels.

    ``use_kernel=True`` swaps each shard's local tile expansion from the
    jnp oracle to the Pallas kernels (`_local_expand`'s kernel leg);
    ``interpret`` is forwarded to them (True = emulate off-TPU).  Both are
    part of the compile cache key.
    """
    key = (mesh, data_axis, model_axis, num_colors, max_levels, diffusion,
           frontier, gather_capacity, use_kernel, interpret,
           ptg.num_vertices, ptg.num_edges,
           ptg.tile_size, ptg.num_shards, ptg.blocks_per_shard)
    fn = _GP_BLOCK_FNS.get(key)
    if fn is None:
        fn = _build_graph_parallel_block(
            ptg, mesh, data_axis=data_axis, model_axis=model_axis,
            num_colors=num_colors, max_levels=max_levels,
            diffusion=diffusion, frontier=frontier,
            gather_capacity=gather_capacity, use_kernel=use_kernel,
            interpret=interpret)
        _GP_BLOCK_FNS[key] = fn
    return fn


def _build_graph_parallel_block(ptg, mesh, *, data_axis, model_axis,
                                num_colors, max_levels, diffusion, frontier,
                                gather_capacity, use_kernel=False,
                                interpret=True):
    from repro.distributed.compat import shard_map

    v, vp = ptg.num_vertices, ptg.padded_vertices
    rows, tile = ptg.rows_per_shard, ptg.tile_size
    num_shards = ptg.num_shards
    tile_specs = part_lib.partition_specs(ptg, model_axis)
    sparse_words = (gather_capacity_words(rows, bitmask.num_words(num_colors),
                                          gather_capacity)
                    if frontier == "sparse" else 0)

    def block_body(ptg_local, cb_local, starts_local, seeds_local):
        base = (jax.lax.axis_index(model_axis).astype(jnp.int32)
                * ptg_local.blocks_per_shard)

        def one(starts, seed):
            # Full (Vp, W) frontier is a transient; persistent state is the
            # (rows, W) local slice each shard keeps through the loop.
            fr = tiles.pad_mask_rows(init_frontier(v, num_colors, starts), vp)
            fr_local = jax.lax.dynamic_slice_in_dim(fr, base * tile, rows)
            expand = _local_expand(ptg_local, diffusion, cb_local, seed,
                                   base, num_colors, use_kernel=use_kernel,
                                   interpret=interpret)
            vis, _, gw = _frontier_gather_loop(
                expand, fr_local, max_levels, model_axis,
                num_shards=num_shards, sparse_words=sparse_words,
                sync_axes=(data_axis, model_axis))
            return vis, gw

        # Sequential over the shard's local batch slice: one traversal's
        # transients at a time per device, parallel across data shards.
        return jax.lax.map(lambda a: one(*a), (starts_local, seeds_local))

    out_specs = (P(data_axis, model_axis), P(data_axis))
    if diffusion == "lt":
        fn = shard_map(
            block_body, mesh=mesh,
            in_specs=(tile_specs, P(model_axis), P(data_axis), P(data_axis)),
            out_specs=out_specs, check=False)
    else:
        fn = shard_map(
            lambda ptg_l, st, sd: block_body(ptg_l, None, st, sd),
            mesh=mesh,
            in_specs=(tile_specs, P(data_axis), P(data_axis)),
            out_specs=out_specs, check=False)
    return jax.jit(fn)
