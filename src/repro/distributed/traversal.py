"""Distributed fused-BPT traversal (DESIGN.md §3).

Two orthogonal axes, composable on one mesh:

* **Sample parallelism** (paper's multi-node axis, Fig. 10): independent
  fused batches sharded over ``data`` (and ``pod``).  Zero collectives
  during traversal; one reduction at seed selection.  This is what scaled
  to 32,768 GPUs in the paper.
* **Graph parallelism** (beyond-paper): 1-D destination-row partition over
  ``model``.  Each level all-gathers the (sparse, packed) frontier and
  expands only locally-owned tiles — the collective-bound cell of the
  roofline analysis.

Both paths reuse the exact single-device expansion math (coupled RNG), so
distributed results are bit-for-bit equal to single-device runs; tests
assert this under a forced multi-device host platform.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bitmask, rng, tiles
from repro.core.traversal import init_frontier
from repro.graph import csr, partition as part_lib
from repro.kernels import ref as kref


# ------------------------------------------------------------ sample parallel
def run_batch(g: csr.Graph, starts, seed, num_colors: int,
              max_levels: int = 64):
    """One fused batch as a jit-friendly pure function of (graph, starts,
    seed) — the unit that sample parallelism vmaps/shards."""
    from repro.core.traversal import fused_step

    frontier = init_frontier(g.num_vertices, num_colors, starts)
    visited = jnp.zeros_like(frontier)

    def cond(c):
        fr, _, lvl = c
        return jnp.logical_and(bitmask.any_set(fr), lvl < max_levels)

    def body(c):
        fr, vis, lvl = c
        nf, nv, _ = fused_step(g, fr, vis, lvl, seed)
        return nf, nv, lvl + 1

    fr, vis, _ = jax.lax.while_loop(
        cond, body, (frontier, visited, jnp.int32(0)))
    return vis | fr


def sample_parallel_fn(g: csr.Graph, all_starts, batch_seeds,
                       num_colors: int, max_levels: int = 64):
    """vmapped batch sweep; shard the batch dim over data axes, replicate
    the graph — exactly the paper's node-level strategy."""
    return jax.vmap(
        lambda s, sd: run_batch(g, s, sd, num_colors, max_levels)
    )(all_starts, batch_seeds)


def sample_parallel_visited(g: csr.Graph, all_starts: jnp.ndarray,
                            batch_seeds: jnp.ndarray, num_colors: int,
                            mesh: Mesh, axes=("data",),
                            max_levels: int = 64) -> jnp.ndarray:
    """Run B independent fused batches, sharded over ``axes``.

    all_starts: (B, C) start vertices; batch_seeds: (B,) uint32.
    Returns visited (B, V, W) sharded over the batch dim.
    """
    sharding = NamedSharding(mesh, P(axes))
    replicated = NamedSharding(mesh, P())
    fn = jax.jit(
        partial(sample_parallel_fn, num_colors=num_colors,
                max_levels=max_levels),
        in_shardings=(jax.tree.map(lambda _: replicated, g),
                      sharding, sharding),
        out_shardings=sharding)
    return fn(g, jax.device_put(all_starts, sharding),
              jax.device_put(batch_seeds, sharding))


def distributed_greedy_max_cover(visited: jnp.ndarray, k: int,
                                 num_colors: int, mesh: Mesh,
                                 axes=("data",)):
    """Greedy max-k-cover with the RRR collection sharded over batches.

    The marginal-gain reduction over the batch axis becomes an all-reduce
    (GSPMD inserts it); selection state (``active``) is sharded alongside.
    """
    b, v, w = visited.shape
    sharding = NamedSharding(mesh, P(axes))
    visited = jax.device_put(visited, sharding)
    active = jax.device_put(
        jnp.broadcast_to(jnp.asarray(bitmask.color_tail_mask(num_colors)),
                         (b, w)), sharding)

    @jax.jit
    def gain_counts(vis, act):
        return jnp.sum(bitmask.popcount(vis & act[:, None, :]), axis=(0, 2),
                       dtype=jnp.int32)          # (V,) — cross-batch psum

    @jax.jit
    def knock_out(act, vis_row):
        return act & ~vis_row

    seeds = []
    for _ in range(k):
        counts = gain_counts(visited, active)
        sel = int(jnp.argmax(counts))
        seeds.append(sel)
        active = knock_out(active, visited[:, sel, :])
    theta = b * num_colors
    covered = theta - int(jnp.sum(bitmask.popcount(active)))
    return np.asarray(seeds, np.int32), covered / theta


# ------------------------------------------------------------- graph parallel
def _graph_parallel_body(ptg: part_lib.PartitionedTiledGraph,
                         frontier_local, *, seed, max_levels: int, axis: str):
    """shard_map body: level loop with per-level frontier all-gather."""

    def expand_local(fr_global, vis_local, level):
        return kref.fused_expand_ref(
            ptg.prob[0], ptg.edge_id[0], ptg.tile_src[0], ptg.tile_dst[0],
            fr_global, vis_local, seed, level)

    def cond(carry):
        fr, _, lvl = carry
        any_local = bitmask.any_set(fr)
        any_global = jax.lax.psum(any_local.astype(jnp.int32), axis) > 0
        return jnp.logical_and(any_global, lvl < max_levels)

    def body(carry):
        fr, vis, lvl = carry
        vis = vis | fr
        # THE collective: gather every shard's (rows, W) frontier words.
        fr_global = jax.lax.all_gather(fr, axis, tiled=True)
        nf = expand_local(fr_global, vis, lvl.astype(jnp.uint32))
        return nf, vis, lvl + 1

    visited = jnp.zeros_like(frontier_local)
    fr, vis, lvl = jax.lax.while_loop(
        cond, body, (frontier_local, visited, jnp.int32(0)))
    return vis | fr, lvl


def graph_parallel_traversal(ptg: part_lib.PartitionedTiledGraph,
                             starts, num_colors: int, seed, mesh: Mesh,
                             axis: str = "model", max_levels: int = 64):
    """Fused BPT with the graph sharded across ``axis`` (1-D row partition).

    Returns (visited (V, W), levels).  Tile stacks enter shard_map with their
    leading shard dim consumed by the mesh axis.
    """
    from repro.distributed.compat import shard_map

    vp = ptg.padded_vertices
    frontier = tiles.pad_mask_rows(
        init_frontier(ptg.num_vertices, num_colors, starts), vp)
    seed = jnp.uint32(seed)

    tile_specs = part_lib.PartitionedTiledGraph(
        prob=P(axis), edge_id=P(axis), tile_src=P(axis), tile_dst=P(axis),
        first_of_dst=P(axis),
        num_vertices=ptg.num_vertices, num_edges=ptg.num_edges,
        tile_size=ptg.tile_size, num_shards=ptg.num_shards,
        blocks_per_shard=ptg.blocks_per_shard)

    fn = shard_map(
        partial(_graph_parallel_body, seed=seed, max_levels=max_levels,
                axis=axis),
        mesh=mesh,
        in_specs=(tile_specs, P(axis)),
        out_specs=(P(axis), P()),
        check=False)
    visited, levels = jax.jit(fn)(ptg, frontier)
    return visited[: ptg.num_vertices], levels
