"""Sharding rules for the LM substrate (DESIGN.md §3).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  Strategy:

* **FSDP/ZeRO-3**: parameters and optimizer state sharded over the composite
  ``fsdp = (pod, data)`` axes on their largest non-tensor-parallel dim;
  GSPMD inserts the per-layer all-gathers.
* **TP (megatron)**: heads / FFN width / vocab / experts sharded on
  ``model``; paired projections are sharded in/out so each block needs one
  reduce per direction.
* **SP**: layer-boundary activations shard sequence on ``model``.

Rules are *path-pattern → logical spec*; an axis that does not divide the
mesh (e.g. 8 KV heads on 16-way model) silently drops to replicated — the
fallback every production framework needs for odd head counts.

``set_mesh`` installs a process-global mesh so model code can annotate
activations without threading a mesh argument through every call.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]):
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


def fsdp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def sanitize(mesh: Mesh, spec: P, shape) -> P:
    """Drop spec axes that are absent from the mesh or don't divide; trim
    specs longer than the value's rank (e.g. MLP applied to pre-flattened
    (N, D) tokens)."""
    out = []
    spec = P(*tuple(spec)[: len(shape)])
    for dim, axis in enumerate(spec):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        if not axes:
            out.append(None)
            continue
        axes = axes if len(axes) > 1 else axes
        if dim < len(shape) and shape[dim] % _axis_size(mesh, axes) == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return P(*out)


def shard(x, *spec_axes):
    """Activation sharding constraint; no-op when no mesh installed."""
    if _MESH is None:
        return x
    spec = sanitize(_MESH, P(*spec_axes), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def shard_first(x, candidates):
    """Constrain with the first candidate spec whose every axis divides —
    e.g. attention: shard heads if they divide the model axis, else shard
    query rows (sequence).  Candidates are tuples of spec axes."""
    if _MESH is None:
        return x
    for cand in candidates:
        spec = P(*cand)
        if sanitize(_MESH, spec, x.shape) == spec:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(_MESH, spec))
    return shard(x, *candidates[-1])


def batch_axes() -> tuple:
    """Logical batch axes — ('pod','data') shrunk to whatever exists."""
    if _MESH is None:
        return ("data",)
    return fsdp_axes(_MESH)


# --------------------------------------------------------------- param rules
# Pattern → spec builder(shape) using logical names; leading layer-stack dims
# are padded with None automatically (match is on the trailing rank).
_F = "__fsdp__"          # placeholder replaced by the mesh's fsdp axes


def _rules():
    """pattern → candidate specs, best-first.  Secondary candidates shard
    head_dim / alternate axes when head counts don't divide the model axis
    (e.g. 24 Q heads or 8 KV heads on a 16-way model axis)."""
    return [
        (r"embedding$", [(None, _F)]),          # (V, D): vocab rep, D fsdp
        (r"unembed$", [(_F, "model")]),         # (D, V)
        (r"patch_proj$", [(_F, None)]),
        (r"wq$", [(_F, "model", None), (_F, None, "model")]),
        (r"wk$", [(_F, "model", None), (_F, None, "model")]),
        (r"wv$", [(_F, "model", None), (_F, None, "model")]),
        (r"bq$", [("model", None), (None, "model")]),
        (r"bk$", [("model", None), (None, "model")]),
        (r"bv$", [("model", None), (None, "model")]),
        (r"wo$", [("model", None, _F), (None, "model", _F)]),
        (r"w_dq$", [(_F, None)]),               # MLA down projections
        (r"w_dkv$", [(_F, None)]),
        (r"w_uq$", [(None, "model", None), (None, None, "model")]),
        (r"w_uk$", [(None, "model", None), (None, None, "model")]),
        (r"w_uv$", [(None, "model", None), (None, None, "model")]),
        (r"w1$", [(_F, "model")]),              # (D, F)
        (r"w3$", [(_F, "model")]),
        (r"w2$", [("model", _F)]),              # (F, D)
        (r"router$", [(_F, None)]),             # (D, E)
        (r"experts_w1$", [("model", _F, None)]),  # (E, D, Fe): EP on experts
        (r"experts_w3$", [("model", _F, None)]),
        (r"experts_w2$", [("model", None, _F)]),  # (E, Fe, D)
        (r"in_proj$", [(_F, "model")]),         # mamba (D, inner-cat)
        (r"out_proj$", [("model", _F)]),        # (di, D)
        (r"conv$", [(None, "model")]),          # (w, channels)
        (r"(a_log|d_skip|dt_bias)$", [("model",)]),
        (r"(scale|norm.*)$", [(None,)]),        # norms replicated
    ]


def spec_candidates(path: str, shape) -> list[P]:
    """Candidate PartitionSpecs for one param leaf (mesh-independent)."""
    for pat, cands in _rules():
        if re.search(pat, path):
            out = []
            for spec in cands:
                pad = len(shape) - len(spec)
                out.append(P(*((None,) * pad + tuple(spec))))
            return out
    return [P(*(None,) * len(shape))]


def spec_for(path: str, shape) -> P:
    return spec_candidates(path, shape)[0]


def _concretize_one(mesh: Mesh, spec: P, shape) -> P:
    fs = fsdp_axes(mesh)
    fs = fs if len(fs) > 1 else (fs[0] if fs else None)
    spec = P(*(fs if a == _F else a for a in spec))
    return sanitize(mesh, spec, shape)


def _shard_ways(mesh: Mesh, spec: P) -> int:
    ways = 1
    for a in spec:
        if a is not None:
            ways *= _axis_size(mesh, a)
    return ways


def concretize(mesh: Mesh, path: str, shape) -> P:
    """Pick the candidate that keeps the most sharding after sanitize
    (best-first on ties)."""
    best, best_ways = None, 0
    for cand in spec_candidates(path, shape):
        spec = _concretize_one(mesh, cand, shape)
        ways = _shard_ways(mesh, spec)
        if ways > best_ways:
            best, best_ways = spec, ways
    return best if best is not None else P(*(None,) * len(shape))


def constrain_params(tree):
    """Re-assert each param leaf's rule sharding INSIDE a scan body.

    Without this, GSPMD hoists the FSDP all-gather of the whole stacked
    layer array out of the scan — params for every layer sit gathered in
    HBM at once (nemotron-340b: +33 GB/device temp).  Constraining the
    *sliced* per-layer tree forces slice-first-gather-later: one layer
    gathered at a time (§Perf iteration N1)."""
    if _MESH is None:
        return tree
    flat, td = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec = concretize(_MESH, name, leaf.shape)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(_MESH, spec)))
    return jax.tree_util.tree_unflatten(td, out)


def param_shardings(mesh: Mesh, param_shapes) -> dict:
    """NamedSharding tree matching a params pytree (of ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append(NamedSharding(mesh, concretize(mesh, name, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)
