"""Version-compat wrapper for ``shard_map``.

``jax.shard_map`` (with ``check_vma``) only exists on newer jax; this
container's jax 0.4.37 ships ``jax.experimental.shard_map.shard_map`` (with
``check_rep``).  Every shard_map in the repo routes through here so the
distributed layers run unmodified on both APIs.
"""
from __future__ import annotations

try:                                        # jax >= 0.6: public API
    from jax import shard_map as _shard_map
    _CHECK_KW = "check_vma"
except ImportError:                         # jax 0.4.x: experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` with replication checking toggled portably.

    ``check=False`` matches the repo's usage: outputs declared replicated
    (``P()``) are made replicated by an explicit ``psum`` in the body, which
    the static checker cannot always prove.
    """
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})
