"""The unified `Sampler` facade — one traversal-spec entry point over every
(diffusion × backend) combination.

All backends honor one RNG contract, owned here: batch ``b`` under
``master_seed`` draws its roots from ``rrr.batch_starts`` and its counter
seed from ``rrr.batch_seed``, so a given ``(master_seed, batch_index)`` is
**bit-identical across every backend that supports the diffusion** — dense,
tiled, Pallas-kernel and shard_map data-parallel runs all reproduce the
same ``(V, W)`` visited mask.  That invariant is what lets a sketch pool be
built under one backend, extended under another, and served from any mesh
shape without changing a single answer.

Backends:

* ``dense``          — CSR edge-centric sweep (`core.traversal.run_fused` /
                       `core.lt.run_fused_lt`), one batch per call on the
                       default device.
* ``tiled``          — block-sparse tile expansion, pure-jnp oracle
                       (`core.tiled_traversal.run_fused_tiled`; LT via
                       `run_fused_lt_tiled`).
* ``kernel``         — same tile layout through the Pallas kernels
                       (``fused_expand`` for IC, ``lt_select_expand`` for
                       LT).
* ``data_parallel``  — batch *blocks* over a mesh axis via ``shard_map``:
                       each shard traverses its own contiguous slice of the
                       block with per-batch RNG streams, on its own device
                       — pool builds parallelize across the mesh instead of
                       staging one batch at a time through the default
                       device (the ROADMAP's distributed-sampling item).
* ``graph_parallel`` — the graph itself partitioned: destination rows shard
                       over ``spec.model_axis`` (1-D tile partition, cached
                       on the sampler), batch blocks over ``spec.mesh_axis``
                       — so graphs bigger than one device's memory sample
                       at all, and sample parallelism still composes on the
                       same 2-D (data × model) mesh.  Per-level collectives
                       (frontier all-gather + termination psum) name only
                       the model axis.

LT diffusion: the facade owns live-edge weight normalization
(`lt.normalize_lt_weights`, idempotent) on the reversed graph, so consumers
can hand any IC-weighted graph to an LT sampler.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lt, rrr, tiled_traversal, tiles
from repro.graph import csr
from repro.sampling.spec import SamplerSpec

__all__ = ["Sampler", "make_sampler"]


class Sampler:
    """Backend-agnostic sampling handle bound to one (graph, spec) pair.

    ``sample(batch_index)`` returns one `rrr.RRRBatch`;
    ``sample_many(batch_indices)`` a list of them (backends may batch the
    work); ``sample_stacked(batch_indices)`` the stacked ``(B, V, W)``
    visited masks (sharded over the mesh for the data_parallel backend).
    """

    def __init__(self, g: csr.Graph | None, spec: SamplerSpec, *,
                 g_rev: csr.Graph | None = None):
        if g is None and g_rev is None:
            raise ValueError("need g or g_rev")
        self.graph = g
        self.spec = spec
        g_rev = g_rev if g_rev is not None else csr.transpose(g)
        if spec.diffusion == "lt":
            # Idempotent: an already-normalized graph passes through.
            g_rev = lt.normalize_lt_weights(g_rev)
        self.g_rev = g_rev

    # ------------------------------------------------------------ RNG
    def batch_starts(self, batch_index: int) -> jnp.ndarray:
        """(num_colors,) roots — the shared cross-backend derivation."""
        return rrr.batch_starts(self.g_rev.num_vertices, self.spec.num_colors,
                                self.spec.master_seed, batch_index,
                                sort=self.spec.sort_starts)

    def batch_seed(self, batch_index: int) -> jnp.ndarray:
        return rrr.batch_seed(self.spec.master_seed, batch_index)

    # ------------------------------------------------------- sampling
    def sample(self, batch_index: int) -> rrr.RRRBatch:
        raise NotImplementedError

    def sample_many(self, batch_indices) -> list[rrr.RRRBatch]:
        return [self.sample(int(b)) for b in batch_indices]

    def sample_stacked(self, batch_indices) -> jnp.ndarray:
        """(B, V, W) stacked visited masks for the given batch indices."""
        return rrr.stack_visited(self.sample_many(batch_indices))

    # ------------------------------------------------------- rebinding
    def rebind(self, g: csr.Graph, g_rev: csr.Graph,
               touched_row_blocks=None) -> "Sampler":
        """Sampler for the delta-mutated ``(g, g_rev)`` pair under the SAME
        spec (and mesh, for mesh backends) — the `repro.stream` hook.

        The default is a full rebuild.  Backends with expensive host-side
        graph indexes override this with a values-only fast path: when the
        delta kept the edge arrays' layout (tombstone / resurrect / LT
        renorm — `_same_edge_layout`), they patch probabilities in place,
        confined to ``touched_row_blocks`` where an index is row-tiled,
        and return ``self``.  Either way the result is bit-identical to a
        fresh ``make_sampler`` on the new graphs.
        """
        return make_sampler(g, self.spec, getattr(self, "mesh", None),
                            g_rev=g_rev)

    def _try_patch_fidx(self, g, g_rev, touched_row_blocks) -> bool:
        """Shared sparse-frontier fast path: patch the cached
        `FrontierIndex` (and LT prefixes) in place when the delta is
        values-only and names its touched row blocks.  True on success."""
        spec = self.spec
        if (spec.frontier != "sparse" or touched_row_blocks is None
                or getattr(self, "_fidx", None) is None):
            return False
        if spec.diffusion == "lt":
            g_rev = lt.normalize_lt_weights(g_rev)   # idempotent
        if not _same_edge_layout(self.g_rev, g_rev):
            return False
        from repro.core import sparse
        self.graph = g
        self.g_rev = g_rev
        cb = None
        if spec.diffusion == "lt":
            self._cb = jnp.asarray(lt.selection_cum_before(self.g_rev))
            cb = np.asarray(self._cb)
        self._fidx = sparse.patch_frontier_index(
            self._fidx, self.g_rev, touched_row_blocks, cb=cb)
        return True

    # -------------------------------------------- sparse-frontier shared
    def _sparse_index(self, cb=None):
        """(FrontierIndex, bucket ladder) for ``spec.frontier == "sparse"``
        — ONE construction path for every backend that compacts edge
        blocks (tile_rows follows ``spec.tile_size``, capacity follows
        ``spec.frontier_capacity``).  ``cb`` attaches the LT
        selection-CDF prefixes."""
        from repro.core import sparse
        fidx = sparse.build_frontier_index(
            self.g_rev, tile_rows=self.spec.tile_size, cb=cb)
        return fidx, sparse.bucket_ladder(fidx.num_blocks,
                                          self.spec.frontier_capacity)

    # ------------------------------------------------- mesh-backend shared
    def _block_inputs(self, idx: list[int], shards: int):
        """(padded_len, starts (Bp, C), seeds (Bp,)) for a block padded to a
        multiple of ``shards`` with repeats of the last index (identical
        work, result dropped).  Roots come from the EXACT scalar
        ``jax.random.key(...)`` path the dense backend uses — the
        cross-backend bit-identity contract — so they are derived per batch
        and stacked ((B, C) ints, cheap next to the (B, V, W) traversal);
        seeds are pure uint32 arithmetic and vectorize host-side."""
        padded = -(-len(idx) // shards) * shards
        full = idx + [idx[-1]] * (padded - len(idx))
        starts = jnp.stack([self.batch_starts(b) for b in full])
        seeds = jnp.asarray(rrr.batch_seeds(self.spec.master_seed, full))
        return padded, starts, seeds


def _same_edge_layout(a: csr.Graph, b: csr.Graph) -> bool:
    """True when ``b`` kept ``a``'s exact edge-array layout (same shapes,
    same (src, dst) at every slot) — i.e. the mutation only changed
    probabilities in place, so per-position structures (tile slots, edge
    blocks, RNG edge ids) carry over unchanged."""
    return (a.num_edges == b.num_edges
            and a.padded_edges == b.padded_edges
            and np.array_equal(np.asarray(a.src), np.asarray(b.src))
            and np.array_equal(np.asarray(a.dst), np.asarray(b.dst)))


class DenseSampler(Sampler):
    """CSR edge-centric path — IC and LT.

    ``spec.frontier == "sparse"`` swaps the per-level edge sweep for the
    `core.sparse` active-tile compaction engine (edge blocks grouped by
    source row-block, gathered per level through a capacity-bucket
    ladder) — bit-identical masks AND work counters, per-level cost
    proportional to the live frontier instead of E.

    ``sample_many`` fuses the whole block into ONE dispatch (``lax.map``
    over batches inside one jit — `traversal.run_fused_block` /
    `sparse.sparse_block` / `lt.run_fused_lt_block`), so pool builds and
    refreshes stop paying per-batch dispatch.  IC blocks keep real
    edge-visit totals; LT carries the usual -1 sentinel.
    """

    def __init__(self, g, spec, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        self._fidx = None
        self._ladder = None
        self._cb = None

    # ----------------------------------------------------- lazy indexes
    def _lt_cb(self):
        if self._cb is None:
            self._cb = jnp.asarray(lt.selection_cum_before(self.g_rev))
        return self._cb

    def _frontier_index(self):
        if self._fidx is None:
            cb = (np.asarray(self._lt_cb())
                  if self.spec.diffusion == "lt" else None)
            self._fidx, self._ladder = self._sparse_index(cb)
        return self._fidx

    # -------------------------------------------------------- sampling
    def sample(self, batch_index: int) -> rrr.RRRBatch:
        if self.spec.frontier == "sparse":
            from repro.core import sparse
            fidx = self._frontier_index()
            starts = self.batch_starts(batch_index)
            seed = self.batch_seed(batch_index)
            if self.spec.diffusion == "lt":
                visited = sparse.run_fused_lt_sparse(
                    fidx, starts, self.spec.num_colors, seed,
                    max_levels=self.spec.max_iters, ladder=self._ladder)
                return rrr.RRRBatch(visited, np.asarray(starts),
                                    int(batch_index), -1, -1)
            res = sparse.run_fused_sparse(
                fidx, starts, self.spec.num_colors, seed,
                max_levels=self.spec.max_iters, ladder=self._ladder)
            return rrr.RRRBatch(
                res.visited, np.asarray(starts), int(batch_index),
                int(res.stats.fused_edge_visits.sum()),
                int(res.stats.unfused_edge_visits.sum()))
        return rrr.sample_batch(
            self.g_rev, self.spec.num_colors, self.spec.master_seed,
            int(batch_index), sort_starts=self.spec.sort_starts,
            max_levels=self.spec.max_iters, model=self.spec.diffusion)

    def sample_many(self, batch_indices) -> list[rrr.RRRBatch]:
        idx = [int(b) for b in batch_indices]
        if len(idx) <= 1:
            return [self.sample(b) for b in idx]
        starts = jnp.stack([self.batch_starts(b) for b in idx])
        seeds = jnp.asarray(rrr.batch_seeds(self.spec.master_seed, idx))
        spec = self.spec
        if spec.frontier == "sparse":
            from repro.core import sparse
            fidx = self._frontier_index()
            vis, fused, unfused = sparse.sparse_block(
                fidx, starts, seeds, spec.num_colors, spec.max_iters,
                self._ladder, diffusion=spec.diffusion)
        elif spec.diffusion == "lt":
            vis = lt.run_fused_lt_block(self.g_rev, self._lt_cb(), starts,
                                        seeds, spec.num_colors,
                                        max_levels=spec.max_iters)
            fused = unfused = np.full(len(idx), -1)
        else:
            from repro.core import traversal
            vis, fused, unfused = traversal.run_fused_block(
                self.g_rev, starts, seeds, spec.num_colors,
                max_levels=spec.max_iters)
        roots = np.asarray(starts)
        return [rrr.RRRBatch(vis[i], roots[i], b, int(fused[i]),
                             int(unfused[i]))
                for i, b in enumerate(idx)]

    def rebind(self, g, g_rev, touched_row_blocks=None):
        if self._try_patch_fidx(g, g_rev, touched_row_blocks):
            return self
        return make_sampler(g, self.spec, g_rev=g_rev)


def _tile_graph(g_rev: csr.Graph, spec: SamplerSpec) -> tiles.TiledGraph:
    """Tile layout of the reversed graph, with the shared dedupe diagnosis
    (tile-layout backends need parallel edges merged)."""
    try:
        return tiles.from_graph(g_rev, tile_size=spec.tile_size)
    except ValueError as e:
        raise ValueError(
            f"the {spec.backend!r} backend needs a dedupe-clean graph "
            "(build it with csr.from_edges(..., dedupe=True)); "
            f"tiling failed with: {e}") from e


class TiledSampler(Sampler):
    """Block-sparse tile path (jnp oracle or Pallas kernel).

    The tile layout is built once per sampler from the reversed graph; the
    counter RNG is keyed by *CSR edge id* (IC) / global destination vertex
    (LT selection), so results stay bit-identical to the dense path.
    Requires a parallel-edge-free graph
    (``csr.from_edges(..., dedupe=True)``).

    ``spec.frontier == "sparse"`` compacts each level's expansion to the
    tiles with an active source block (`tiled_traversal` sparse legs) —
    the Pallas kernel grid then iterates exactly the compacted tile list.
    """

    def __init__(self, g, spec, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        self.tg_rev = _tile_graph(self.g_rev, spec)
        # LT carries the selection-CDF prefixes alongside the tiles (the
        # per-graph host precompute, done once like the layout itself).
        self._cb_tiles = (jnp.asarray(tiles.edge_values_to_tiles(
            self.tg_rev, lt.selection_cum_before(self.g_rev)))
            if spec.diffusion == "lt" else None)
        if spec.frontier == "sparse":
            from repro.core import sparse
            self._ladder = sparse.bucket_ladder(self.tg_rev.num_tiles,
                                                spec.frontier_capacity)
        # Grid-work observability (benchmarks' active_grid_frac column):
        # per-sample totals from the last `sample()` call.
        self.last_levels = 0
        self.last_grid_steps = 0

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        spec = self.spec
        starts = self.batch_starts(batch_index)
        seed = self.batch_seed(batch_index)
        ladder = self._ladder if spec.frontier == "sparse" else None
        use_kernel = (spec.backend == "kernel")
        if spec.diffusion == "lt":
            visited, levels, gs = tiled_traversal.run_fused_lt_tiled(
                self.tg_rev, self._cb_tiles, starts, spec.num_colors,
                seed, max_levels=spec.max_iters, use_kernel=use_kernel,
                frontier=spec.frontier, ladder=ladder)
        else:
            visited, levels, gs = tiled_traversal.run_fused_tiled(
                self.tg_rev, starts, spec.num_colors, seed,
                max_levels=spec.max_iters, use_kernel=use_kernel,
                frontier=spec.frontier, ladder=ladder)
        self.last_levels = int(levels)
        self.last_grid_steps = int(gs)
        return rrr.RRRBatch(visited, np.asarray(starts),
                            int(batch_index), -1, -1)


class _BlockSampler(Sampler):
    """Shared block protocol of the mesh backends: subclasses implement
    ``_block(idx) -> (visited, roots)`` — visited ``(B, Vp≥V, W)`` sharded
    on the subclass's mesh layout (row padding still attached for the
    graph-parallel case), roots ``(B, C)`` host numpy."""

    def _block(self, idx: list[int]):
        raise NotImplementedError

    def sample_stacked(self, batch_indices) -> jnp.ndarray:
        """(B, V, W) visited for the block, mesh-sharded; any row padding
        trimmed (an exact-fit block keeps its sharded layout untouched)."""
        idx = [int(b) for b in batch_indices]
        v = self.g_rev.num_vertices
        if not idx:
            return jnp.zeros((0, v, _num_words(self.spec.num_colors)),
                             jnp.uint32)
        vis = self._block(idx)[0]
        return vis if vis.shape[1] == v else vis[:, :v]

    def sample_many(self, batch_indices) -> list[rrr.RRRBatch]:
        """Block-sample, then host-stage `RRRBatch`es (each device
        contributes only its own slice of the block — the full block never
        transits a single device).  Edge-visit stats carry the -1 "not
        instrumented" sentinel, like the tiled and LT paths."""
        idx = [int(b) for b in batch_indices]
        if not idx:
            return []
        vis_sharded, roots = self._block(idx)
        vis = np.asarray(jax.device_get(vis_sharded))
        vis = vis[:, : self.g_rev.num_vertices]     # no-op when unpadded
        return [rrr.RRRBatch(vis[i], roots[i], b, -1, -1)
                for i, b in enumerate(idx)]


_DP_BLOCK_FNS: dict = {}


def _data_parallel_block_fn(mesh, axis: str, spec: SamplerSpec, ladder):
    """jit(shard_map) block traversal for the data_parallel backend.

    Cached at MODULE level on (mesh, statics), with the graph / frontier
    index passed as a traced ARGUMENT rather than baked into the closure
    as a trace-time constant — so rebinding a sampler to a mutated graph
    of the same shape (the `repro.stream` delta path builds one per
    delta) reuses the compiled program instead of recompiling it, and an
    incremental refresh stays churn-priced.  jit retraces per input
    shape, so one entry serves every padded block size and graph shape.
    """
    key = (mesh, axis, spec.diffusion, spec.frontier, spec.num_colors,
           spec.max_iters, ladder)
    fn = _DP_BLOCK_FNS.get(key)
    if fn is None:
        from jax.sharding import PartitionSpec as P

        from repro.distributed.compat import shard_map
        from repro.distributed.traversal import run_batch

        def one(data, starts, seed):
            if spec.frontier == "sparse":
                # The sparse engine is fully traced (capacity-bucket
                # conds are shard-local — no collectives), so it drops
                # straight into the shard_map body; fidx rides along
                # replicated like the graph.
                from repro.core import sparse
                (fidx,) = data
                if spec.diffusion == "lt":
                    return sparse.run_fused_lt_sparse(
                        fidx, starts, spec.num_colors, seed,
                        max_levels=spec.max_iters, ladder=ladder)
                return sparse.run_fused_sparse(
                    fidx, starts, spec.num_colors, seed,
                    max_levels=spec.max_iters, ladder=ladder).visited
            if spec.diffusion == "lt":
                g, cb = data
                sel = lt.selection_mask_from_cb(g, cb, spec.num_colors,
                                                seed)
                return lt.lt_traversal_program(g, sel, starts,
                                               spec.num_colors,
                                               spec.max_iters)
            (g,) = data
            return run_batch(g, starts, seed, spec.num_colors,
                             max_levels=spec.max_iters)

        def body(data, starts_local, seeds_local):
            # Sequential over the shard's local slice: one (V, W)
            # transient at a time per device, parallel across shards.
            return jax.lax.map(lambda a: one(data, *a),
                               (starts_local, seeds_local))

        fn = jax.jit(shard_map(body, mesh,
                               in_specs=(P(), P(axis), P(axis)),
                               out_specs=P(axis)))
        _DP_BLOCK_FNS[key] = fn
    return fn


class DataParallelSampler(_BlockSampler):
    """Batch blocks over a mesh axis via ``shard_map`` — IC and LT.

    A block of B batch indices is padded to the shard count and sharded
    ``P(axis)`` over its leading dim; each shard runs a sequential
    ``lax.map`` of full traversals over its local slice (its own devices,
    its own RNG streams — zero collectives).  Slot blocks land exactly
    where `ShardedSketchStore` shards them, so pool builds and refreshes
    parallelize across the mesh with no default-device staging.
    """

    def __init__(self, g, spec, mesh, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        if mesh is None:
            raise ValueError("data_parallel backend needs a mesh")
        if spec.mesh_axis not in mesh.axis_names:
            raise ValueError(f"axis {spec.mesh_axis!r} not in mesh "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.axis = spec.mesh_axis
        self._cb = (jnp.asarray(lt.selection_cum_before(self.g_rev))
                    if spec.diffusion == "lt" else None)
        if spec.frontier == "sparse":
            self._fidx, self._ladder = self._sparse_index(
                None if self._cb is None else np.asarray(self._cb))
        else:
            self._fidx = self._ladder = None

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    # ----------------------------------------------------- block program
    def _block_data(self):
        """The graph-dependent pytree the block program takes as a traced
        INPUT — what a streaming update swaps out under the cached
        program (`repro.stream` rebinds samplers per delta)."""
        if self.spec.frontier == "sparse":
            return (self._fidx,)
        if self.spec.diffusion == "lt":
            return (self.g_rev, self._cb)
        return (self.g_rev,)

    def _block(self, idx: list[int]):
        """(visited, roots) for one padded block: visited (B, V, W) sharded
        ``P(axis)``, roots (B, C) host numpy — starts are derived once and
        shared by the traversal and the returned `RRRBatch` roots."""
        padded, starts, seeds = self._block_inputs(idx, self.num_shards)
        fn = _data_parallel_block_fn(self.mesh, self.axis, self.spec,
                                     self._ladder)
        vis = fn(self._block_data(), starts, seeds)
        # Slicing a sharded array re-gathers; keep the P(axis) layout when
        # the block divides evenly (the pool-build case).
        if padded != len(idx):
            vis = vis[: len(idx)]
        return vis, np.asarray(starts)[: len(idx)]

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        """Single batch: go through the dense path — padding a 1-batch
        block to the shard count would traverse the same batch on every
        shard for one kept result.  Bit-identical by the facade contract."""
        if not hasattr(self, "_dense"):
            self._dense = DenseSampler(self.graph,
                                       self.spec.replace(backend="dense"),
                                       g_rev=self.g_rev)
        return self._dense.sample(batch_index)

    def rebind(self, g, g_rev, touched_row_blocks=None):
        if self._try_patch_fidx(g, g_rev, touched_row_blocks):
            # The lazily built single-batch helper binds the old graph.
            self.__dict__.pop("_dense", None)
            return self
        return make_sampler(g, self.spec, self.mesh, g_rev=g_rev)


def _gp_use_kernel() -> bool:
    """Env knob: ``REPRO_GP_KERNEL=1`` routes the graph_parallel backend's
    per-shard tile expansion through the Pallas kernels instead of the jnp
    oracle.  An env var rather than a `SamplerSpec` field because it does
    not change a single output bit — it selects an execution engine for the
    same partitioned layout, like ``interpret`` — so specs embedded in pool
    manifests stay portable across machines with and without kernel
    support."""
    return os.environ.get("REPRO_GP_KERNEL", "0") == "1"


class GraphParallelSampler(_BlockSampler):
    """Graph rows sharded over ``spec.model_axis``, batch blocks over
    ``spec.mesh_axis`` — the 2-D (data × model) composition for graphs
    bigger than one device's memory.  IC and LT.

    The destination-row partition (`graph.partition.partition` of the tile
    layout, plus the LT selection-CDF tiles) is computed ONCE here and
    cached for the sampler's lifetime; every block reuses it.  Each device
    persistently holds only its row slice of the tile stacks and, during a
    block, its (batch slice × row slice) of the visited masks; the full
    (V, W) mask of a batch only materializes when a consumer asks for it
    (`sample_many` host-stages, which is exactly where `ShardedSketchStore`
    wants the mask anyway).
    """

    def __init__(self, g, spec, mesh, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        if mesh is None:
            raise ValueError("graph_parallel backend needs a mesh")
        for ax, role in ((spec.mesh_axis, "mesh_axis (batches)"),
                         (spec.model_axis, "model_axis (graph rows)")):
            if ax not in mesh.axis_names:
                raise ValueError(f"{role} {ax!r} not in mesh "
                                 f"{mesh.axis_names}")
        from repro.graph import partition as part_lib

        self.mesh = mesh
        self.data_axis = spec.mesh_axis
        self.model_axis = spec.model_axis
        tg = _tile_graph(self.g_rev, spec)
        # Partition ONCE; cached — the whole point of binding a sampler.
        self.ptg = part_lib.partition(tg, int(mesh.shape[spec.model_axis]))
        self._cb_tiles = None
        if spec.diffusion == "lt":
            cb = tiles.edge_values_to_tiles(
                tg, lt.selection_cum_before(self.g_rev))
            self._cb_tiles = jnp.asarray(part_lib.partition_tile_values(
                tg, self.ptg.num_shards, cb))
        # Rebind fast path: the tile layout and shard assignment are pure
        # functions of (src, dst, tile_size), so cache the CSR-edge →
        # flat-tile-slot map and the per-shard tile index lists — a
        # values-only delta then re-derives the prob/CDF stacks by direct
        # scatter + gather with NO re-sort / re-partition.
        self._slot_of_eid, self._num_tiles = tiles.edge_slot_map(
            self.g_rev, spec.tile_size)
        shard_of, _, self._tiles_per_shard = part_lib._assignment(
            tg, self.ptg.num_shards)
        self._shard_tiles = [np.flatnonzero(shard_of == s)
                             for s in range(self.ptg.num_shards)]
        # Per-batch per-level words moved over the model axis by the most
        # recent `_block` call — (B, max_iters) host int32, the traffic
        # observable `bench_pool_build` records.
        self.last_gather_words = None

    @property
    def data_shards(self) -> int:
        return int(self.mesh.shape[self.data_axis])

    def _block_fn(self):
        # Module-level cache keyed on (mesh, spec knobs, partition
        # statics) — a dict hit after the first build, shared across
        # rebound samplers so streaming deltas never re-trace.
        from repro.distributed.traversal import graph_parallel_block
        from repro.kernels import ops
        return graph_parallel_block(
            self.ptg, self.mesh, data_axis=self.data_axis,
            model_axis=self.model_axis,
            num_colors=self.spec.num_colors,
            max_levels=self.spec.max_iters,
            diffusion=self.spec.diffusion,
            frontier=self.spec.frontier,
            gather_capacity=self.spec.frontier_capacity,
            use_kernel=_gp_use_kernel(), interpret=ops._interpret())

    def _block(self, idx: list[int]):
        """(visited (B, Vp, W) sharded P(data, model), roots (B, C) numpy)
        for one padded block — row padding still attached."""
        padded, starts, seeds = self._block_inputs(idx, self.data_shards)
        args = ((self.ptg, self._cb_tiles, starts, seeds)
                if self.spec.diffusion == "lt"
                else (self.ptg, starts, seeds))
        vis, words = self._block_fn()(*args)
        self.last_gather_words = np.asarray(jax.device_get(words))[: len(idx)]
        if padded != len(idx):
            vis = vis[: len(idx)]
        return vis, np.asarray(starts)[: len(idx)]

    def _partition_edge_values(self, values: np.ndarray) -> np.ndarray:
        """Per-CSR-edge ``values`` → the ``(S, ntₘ, T, T)`` stacked layout,
        through the cached slot map + shard assignment (no sorting)."""
        t = self.spec.tile_size
        flat = np.zeros(self._num_tiles * t * t, values.dtype)
        flat[self._slot_of_eid] = values[: self.g_rev.num_edges]
        tiles_v = flat.reshape(self._num_tiles, t, t)
        out = np.zeros((self.ptg.num_shards, self._tiles_per_shard, t, t),
                       values.dtype)
        for s, tidx in enumerate(self._shard_tiles):
            if len(tidx):
                out[s, : len(tidx)] = tiles_v[tidx]
        return out

    def rebind(self, g, g_rev, touched_row_blocks=None):
        """Values-only deltas swap the prob (and LT CDF) tile stacks under
        the cached partition layout and compiled block program; structural
        deltas fall back to a full rebuild."""
        import dataclasses as _dc

        g_rev_n = (lt.normalize_lt_weights(g_rev)
                   if self.spec.diffusion == "lt" else g_rev)
        if not _same_edge_layout(self.g_rev, g_rev_n):
            return make_sampler(g, self.spec, self.mesh, g_rev=g_rev)
        self.graph = g
        self.g_rev = g_rev_n
        prob = np.asarray(self.g_rev.prob)
        self.ptg = _dc.replace(
            self.ptg,
            prob=jnp.asarray(self._partition_edge_values(
                prob.astype(np.float32))))
        if self.spec.diffusion == "lt":
            # Fresh-build parity: `edge_values_to_tiles` masks slots by
            # prob > 0, so a resurrected tombstone's CDF value must land
            # and a fresh tombstone's must zero out.
            cb = np.where(prob[: self.g_rev.num_edges] > 0,
                          np.asarray(lt.selection_cum_before(self.g_rev),
                                     np.float32)[: self.g_rev.num_edges],
                          np.float32(0))
            self._cb_tiles = jnp.asarray(self._partition_edge_values(cb))
        return self

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        """Single batch through the SAME row-partitioned program (padding
        replicates the batch across data shards — wasteful but the graph
        never has to fit on one device, which is the backend's contract)."""
        return self.sample_many([int(batch_index)])[0]


def _num_words(num_colors: int) -> int:
    return -(-num_colors // 32)


def make_sampler(g: csr.Graph | None, spec: SamplerSpec, mesh=None, *,
                 g_rev: csr.Graph | None = None) -> Sampler:
    """Build the `Sampler` for ``spec``.

    ``g_rev``: prebuilt transpose(g) (skips one reversal; for LT it may be
    raw or already LT-normalized — normalization is idempotent).  ``mesh``
    is required by (and only used by) the ``data_parallel`` and
    ``graph_parallel`` backends.
    """
    if spec.backend == "graph_parallel":
        return GraphParallelSampler(g, spec, mesh, g_rev=g_rev)
    if spec.backend == "data_parallel":
        return DataParallelSampler(g, spec, mesh, g_rev=g_rev)
    if spec.backend in ("tiled", "kernel"):
        return TiledSampler(g, spec, g_rev=g_rev)
    return DenseSampler(g, spec, g_rev=g_rev)
