"""The unified `Sampler` facade — one traversal-spec entry point over every
(diffusion × backend) combination.

All backends honor one RNG contract, owned here: batch ``b`` under
``master_seed`` draws its roots from ``rrr.batch_starts`` and its counter
seed from ``rrr.batch_seed``, so a given ``(master_seed, batch_index)`` is
**bit-identical across every backend that supports the diffusion** — dense,
tiled, Pallas-kernel and shard_map data-parallel runs all reproduce the
same ``(V, W)`` visited mask.  That invariant is what lets a sketch pool be
built under one backend, extended under another, and served from any mesh
shape without changing a single answer.

Backends:

* ``dense``          — CSR edge-centric sweep (`core.traversal.run_fused` /
                       `core.lt.run_fused_lt`), one batch per call on the
                       default device.
* ``tiled``          — block-sparse tile expansion, pure-jnp oracle
                       (`core.tiled_traversal.run_fused_tiled`).  IC only.
* ``kernel``         — same tile layout through the Pallas ``fused_expand``
                       kernel.  IC only.
* ``data_parallel``  — batch *blocks* over a mesh axis via ``shard_map``:
                       each shard traverses its own contiguous slice of the
                       block with per-batch RNG streams, on its own device
                       — pool builds parallelize across the mesh instead of
                       staging one batch at a time through the default
                       device (the ROADMAP's distributed-sampling item).

LT diffusion: the facade owns live-edge weight normalization
(`lt.normalize_lt_weights`, idempotent) on the reversed graph, so consumers
can hand any IC-weighted graph to an LT sampler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lt, rrr, tiles
from repro.graph import csr
from repro.sampling.spec import SamplerSpec

__all__ = ["Sampler", "make_sampler"]


class Sampler:
    """Backend-agnostic sampling handle bound to one (graph, spec) pair.

    ``sample(batch_index)`` returns one `rrr.RRRBatch`;
    ``sample_many(batch_indices)`` a list of them (backends may batch the
    work); ``sample_stacked(batch_indices)`` the stacked ``(B, V, W)``
    visited masks (sharded over the mesh for the data_parallel backend).
    """

    def __init__(self, g: csr.Graph | None, spec: SamplerSpec, *,
                 g_rev: csr.Graph | None = None):
        if g is None and g_rev is None:
            raise ValueError("need g or g_rev")
        self.graph = g
        self.spec = spec
        g_rev = g_rev if g_rev is not None else csr.transpose(g)
        if spec.diffusion == "lt":
            # Idempotent: an already-normalized graph passes through.
            g_rev = lt.normalize_lt_weights(g_rev)
        self.g_rev = g_rev

    # ------------------------------------------------------------ RNG
    def batch_starts(self, batch_index: int) -> jnp.ndarray:
        """(num_colors,) roots — the shared cross-backend derivation."""
        return rrr.batch_starts(self.g_rev.num_vertices, self.spec.num_colors,
                                self.spec.master_seed, batch_index,
                                sort=self.spec.sort_starts)

    def batch_seed(self, batch_index: int) -> jnp.ndarray:
        return rrr.batch_seed(self.spec.master_seed, batch_index)

    # ------------------------------------------------------- sampling
    def sample(self, batch_index: int) -> rrr.RRRBatch:
        raise NotImplementedError

    def sample_many(self, batch_indices) -> list[rrr.RRRBatch]:
        return [self.sample(int(b)) for b in batch_indices]

    def sample_stacked(self, batch_indices) -> jnp.ndarray:
        """(B, V, W) stacked visited masks for the given batch indices."""
        return rrr.stack_visited(self.sample_many(batch_indices))


class DenseSampler(Sampler):
    """CSR edge-centric path — IC and LT."""

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        return rrr.sample_batch(
            self.g_rev, self.spec.num_colors, self.spec.master_seed,
            int(batch_index), sort_starts=self.spec.sort_starts,
            max_levels=self.spec.max_iters, model=self.spec.diffusion)


class TiledSampler(Sampler):
    """Block-sparse tile path (jnp oracle or Pallas kernel) — IC only.

    The tile layout is built once per sampler from the reversed graph; the
    counter RNG is keyed by *CSR edge id*, so results stay bit-identical to
    the dense path.  Requires a parallel-edge-free graph
    (``csr.from_edges(..., dedupe=True)``)."""

    def __init__(self, g, spec, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        try:
            self.tg_rev = tiles.from_graph(self.g_rev,
                                           tile_size=spec.tile_size)
        except ValueError as e:
            raise ValueError(
                f"the {spec.backend!r} backend needs a dedupe-clean graph "
                "(build it with csr.from_edges(..., dedupe=True)); "
                f"tiling failed with: {e}") from e

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        return rrr.sample_batch(
            self.g_rev, self.spec.num_colors, self.spec.master_seed,
            int(batch_index), sort_starts=self.spec.sort_starts,
            max_levels=self.spec.max_iters, tg_rev=self.tg_rev,
            use_kernel=(self.spec.backend == "kernel"))


class DataParallelSampler(Sampler):
    """Batch blocks over a mesh axis via ``shard_map`` — IC and LT.

    A block of B batch indices is padded to the shard count and sharded
    ``P(axis)`` over its leading dim; each shard runs a sequential
    ``lax.map`` of full traversals over its local slice (its own devices,
    its own RNG streams — zero collectives).  Slot blocks land exactly
    where `ShardedSketchStore` shards them, so pool builds and refreshes
    parallelize across the mesh with no default-device staging.
    """

    def __init__(self, g, spec, mesh, *, g_rev=None):
        super().__init__(g, spec, g_rev=g_rev)
        if mesh is None:
            raise ValueError("data_parallel backend needs a mesh")
        if spec.mesh_axis not in mesh.axis_names:
            raise ValueError(f"axis {spec.mesh_axis!r} not in mesh "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.axis = spec.mesh_axis
        self._cb = (jnp.asarray(lt.selection_cum_before(self.g_rev))
                    if spec.diffusion == "lt" else None)
        self._block_fns: dict[int, object] = {}

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    # ----------------------------------------------------- block program
    def _block_fn(self, padded: int):
        """jit(shard_map) traversing ``padded`` batches, cached per size."""
        fn = self._block_fns.get(padded)
        if fn is None:
            from jax.sharding import PartitionSpec as P

            from repro.distributed.compat import shard_map
            from repro.distributed.traversal import run_batch

            g, spec, cb = self.g_rev, self.spec, self._cb

            def one(starts, seed):
                if spec.diffusion == "lt":
                    sel = lt.selection_mask_from_cb(g, cb, spec.num_colors,
                                                    seed)
                    return lt.lt_traversal_program(g, sel, starts,
                                                   spec.num_colors,
                                                   spec.max_iters)
                return run_batch(g, starts, seed, spec.num_colors,
                                 max_levels=spec.max_iters)

            def body(starts_local, seeds_local):
                # Sequential over the shard's local slice: one (V, W)
                # transient at a time per device, parallel across shards.
                return jax.lax.map(lambda a: one(*a),
                                   (starts_local, seeds_local))

            fn = jax.jit(shard_map(body, self.mesh,
                                   in_specs=(P(self.axis), P(self.axis)),
                                   out_specs=P(self.axis)))
            self._block_fns[padded] = fn
        return fn

    def _block(self, idx: list[int]):
        """(visited, roots) for one padded block: visited (B, V, W) sharded
        ``P(axis)``, roots (B, C) host numpy — starts are derived once and
        shared by the traversal and the returned `RRRBatch` roots."""
        s = self.num_shards
        padded = -(-len(idx) // s) * s
        # Pad with repeats of the last index: identical work, result dropped.
        full = idx + [idx[-1]] * (padded - len(idx))
        # Roots must come from the EXACT scalar jax.random.key(...) path the
        # dense backend uses — the cross-backend bit-identity contract —
        # so they are derived per batch and stacked ((B, C) ints, cheap
        # next to the (B, V, W) traversal).  Seeds are pure uint32
        # arithmetic and vectorize host-side.
        starts = jnp.stack([self.batch_starts(b) for b in full])
        seeds = jnp.asarray(rrr.batch_seeds(self.spec.master_seed, full))
        vis = self._block_fn(padded)(starts, seeds)
        # Slicing a sharded array re-gathers; keep the P(axis) layout when
        # the block divides evenly (the pool-build case).
        if padded != len(idx):
            vis = vis[: len(idx)]
        return vis, np.asarray(starts)[: len(idx)]

    def sample_stacked(self, batch_indices) -> jnp.ndarray:
        """(B, V, W) visited for the block, sharded ``P(axis)`` over B."""
        idx = [int(b) for b in batch_indices]
        if not idx:
            return jnp.zeros((0, self.g_rev.num_vertices,
                              _num_words(self.spec.num_colors)), jnp.uint32)
        return self._block(idx)[0]

    def sample_many(self, batch_indices) -> list[rrr.RRRBatch]:
        """Block-sample, then split into host-staged `RRRBatch`es (each
        shard's slice is fetched from its own device — the full block never
        transits a single device).  Edge-visit stats carry the -1 "not
        instrumented" sentinel, like the tiled and LT paths."""
        idx = [int(b) for b in batch_indices]
        if not idx:
            return []
        vis_sharded, roots = self._block(idx)
        vis = np.asarray(jax.device_get(vis_sharded))
        return [rrr.RRRBatch(vis[i], roots[i], b, -1, -1)
                for i, b in enumerate(idx)]

    def sample(self, batch_index: int) -> rrr.RRRBatch:
        """Single batch: go through the dense path — padding a 1-batch
        block to the shard count would traverse the same batch on every
        shard for one kept result.  Bit-identical by the facade contract."""
        if not hasattr(self, "_dense"):
            self._dense = DenseSampler(self.graph,
                                       self.spec.replace(backend="dense"),
                                       g_rev=self.g_rev)
        return self._dense.sample(batch_index)


def _num_words(num_colors: int) -> int:
    return -(-num_colors // 32)


def make_sampler(g: csr.Graph | None, spec: SamplerSpec, mesh=None, *,
                 g_rev: csr.Graph | None = None) -> Sampler:
    """Build the `Sampler` for ``spec``.

    ``g_rev``: prebuilt transpose(g) (skips one reversal; for LT it may be
    raw or already LT-normalized — normalization is idempotent).  ``mesh``
    is required by (and only used by) the ``data_parallel`` backend.
    """
    if spec.backend == "data_parallel":
        return DataParallelSampler(g, spec, mesh, g_rev=g_rev)
    if spec.backend in ("tiled", "kernel"):
        return TiledSampler(g, spec, g_rev=g_rev)
    return DenseSampler(g, spec, g_rev=g_rev)
