"""Unified sampling facade: one typed traversal spec over every
(diffusion × backend) combination.

    from repro import sampling

    spec    = sampling.SamplerSpec(diffusion="ic", backend="data_parallel",
                                   num_colors=64, master_seed=3)
    sampler = sampling.make_sampler(graph, spec, mesh=mesh)
    batch   = sampler.sample(0)                  # one rrr.RRRBatch
    stack   = sampler.sample_stacked(range(16))  # (16, V, W), mesh-sharded

    # graphs bigger than one device: rows over "model", batches over "data"
    gp = sampling.make_sampler(
        graph, spec.replace(backend="graph_parallel"),
        mesh=jax.make_mesh((4, 2), ("data", "model")))

Every pool consumer (``core.rrr.sample_collection``, ``core.imm.run_imm``,
``serve.influence.SketchStore``, ``serve.distributed.ShardedSketchStore``,
``core.driver.SamplingDriver``) routes RRR sampling through here; the
low-level ``rrr.sample_batch`` primitive is private to this package (CI
grep guard).  The cross-backend contract: a given ``(master_seed,
batch_index)`` yields bit-identical visited masks on every backend that
supports the diffusion.
"""
from repro.sampling.sampler import Sampler, make_sampler
from repro.sampling.spec import (BACKENDS, DIFFUSIONS, FRONTIERS,
                                 SamplerSpec, resolve_spec,
                                 spec_from_sample_kw, supported)

__all__ = ["BACKENDS", "DIFFUSIONS", "FRONTIERS", "Sampler", "SamplerSpec",
           "make_sampler", "resolve_spec", "spec_from_sample_kw",
           "supported"]
