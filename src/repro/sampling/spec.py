"""Typed sampler specification — ONE description of a fused-BPT sampling
configuration, shared by every consumer of RRR batches.

``SamplerSpec`` is frozen and hashable (all-primitive fields) so it can key
jit caches, be embedded in ``PoolConfig``, and round-trip through checkpoint
manifests.  The (diffusion × backend) support matrix:

    backend \\ diffusion |  ic  |  lt
    --------------------+------+------
    dense               |  ✓   |  ✓     CSR edge-centric sweep
    tiled               |  ✓   |  ✓     block-sparse tiles, jnp oracle
    kernel              |  ✓   |  ✓     block-sparse tiles, Pallas kernels
                                        (`fused_expand` / `lt_select_expand`)
    data_parallel       |  ✓   |  ✓     shard_map batch blocks over a mesh
    graph_parallel      |  ✓   |  ✓     rows over ``model`` + batches over
                                        ``data`` on a 2-D mesh (frontier
                                        all-gather per level; honors the
                                        kernel leg via REPRO_GP_KERNEL=1)

The RNG contract every backend honors: batch ``b`` under ``master_seed`` is
a pure function of ``(graph, master_seed, b)`` — the same ``(seed, starts)``
derivation everywhere — so supported backends are bit-identical per batch
index and a pool may be built under one backend and extended under another.
"""
from __future__ import annotations

import dataclasses
import warnings

DIFFUSIONS = ("ic", "lt")
BACKENDS = ("dense", "tiled", "kernel", "data_parallel", "graph_parallel")
FRONTIERS = ("dense", "sparse")

# (diffusion, backend) pairs with an implementation behind them — the
# matrix is complete: LT's per-(dst, color) live-edge selection has its own
# Pallas kernel (`kernels.lt_select_expand`) mirroring the IC expand kernel.
_SUPPORTED = frozenset(
    (d, b) for d in DIFFUSIONS for b in BACKENDS)


def supported(diffusion: str, backend: str) -> bool:
    """True iff the (diffusion, backend) cell of the matrix is implemented."""
    return (diffusion, backend) in _SUPPORTED


@dataclasses.dataclass(frozen=True)
class SamplerSpec:
    """Complete description of one traversal-sampling configuration.

    ``max_iters`` is the level cap of the level-synchronous traversal (the
    paper's ``max_levels``).  ``tile_size`` only matters to the tile-layout
    backends (tiled/kernel/graph_parallel); ``mesh_axis`` is the batch axis
    of the mesh backends (``data_parallel`` shards batch blocks over it,
    ``graph_parallel`` its sample axis); ``model_axis`` is the
    ``graph_parallel`` row-partition axis — destination rows shard over it
    and the per-level frontier all-gather runs on it alone.

    ``frontier`` selects the per-level execution mode — ``"dense"`` sweeps
    every edge/tile every level; ``"sparse"`` compacts each level to the
    active source tiles (`core.sparse` — per-level work scales with the
    live frontier instead of E) and, on ``graph_parallel``, additionally
    all-gathers a compacted frontier representation when it fits.  The two
    modes are **bit-identical**; sparse only changes what gets computed,
    never what comes out.  ``frontier_capacity`` tunes the sparse capacity
    buckets (0 = auto ladder): the active-tile compaction buffer size for
    the single-device / data_parallel engines, the per-shard packed-word
    budget of the sparse all-gather for ``graph_parallel``
    (`benchmarks/bench_frontier_profile.py` prints the occupancy histogram
    to set it from).
    """
    diffusion: str = "ic"
    backend: str = "dense"
    num_colors: int = 64
    master_seed: int = 0
    max_iters: int = 64
    sort_starts: bool = False
    tile_size: int = 128
    mesh_axis: str = "data"
    model_axis: str = "model"
    frontier: str = "dense"
    frontier_capacity: int = 0

    def __post_init__(self):
        if self.diffusion not in DIFFUSIONS:
            raise ValueError(f"diffusion {self.diffusion!r} not in "
                             f"{DIFFUSIONS}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if not supported(self.diffusion, self.backend):
            raise ValueError(
                f"unsupported combination diffusion={self.diffusion!r} × "
                f"backend={self.backend!r}; supported: "
                f"{sorted(_SUPPORTED)}")
        if self.num_colors < 1 or self.max_iters < 1 or self.tile_size < 1:
            raise ValueError("num_colors / max_iters / tile_size must be ≥ 1")
        if self.frontier not in FRONTIERS:
            raise ValueError(f"frontier {self.frontier!r} not in {FRONTIERS}")
        if self.frontier_capacity < 0:
            raise ValueError("frontier_capacity must be ≥ 0 (0 = auto)")
        if self.backend == "graph_parallel" \
                and self.mesh_axis == self.model_axis:
            raise ValueError(
                "graph_parallel needs DISTINCT axes: mesh_axis (batches) "
                f"and model_axis (graph rows) are both {self.mesh_axis!r}")

    def replace(self, **kw) -> "SamplerSpec":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------- manifest round-trip
    def to_manifest(self) -> dict:
        """JSON-serializable form for checkpoint manifest ``extra``."""
        return dataclasses.asdict(self)

    @classmethod
    def from_manifest(cls, d: dict) -> "SamplerSpec":
        """Inverse of ``to_manifest`` (unknown keys ignored — forward
        compatible with manifests written by newer specs)."""
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def resolve_spec(spec: SamplerSpec | None = None,
                 sample_kw: dict | None = None, *,
                 num_colors: int | None = None,
                 master_seed: int | None = None) -> SamplerSpec:
    """THE one spec-vs-arguments reconciliation policy, shared by every
    consumer (`PoolConfig`, `run_imm`/`estimate_theta`,
    `rrr.sample_collection`, `SamplingDriver`).

    ``num_colors``/``master_seed`` are ``None`` when the caller did not set
    them explicitly.  Legacy ``sample_kw`` dicts convert (with a
    DeprecationWarning, exclusive with ``spec``); an explicit ``spec`` wins
    over unset arguments, and a set argument that disagrees with the spec
    raises — never a silent override.
    """
    nc = 64 if num_colors is None else num_colors
    ms = 0 if master_seed is None else master_seed
    if sample_kw:
        if spec is not None:
            raise ValueError("pass spec OR legacy sample_kw, not both")
        return spec_from_sample_kw(sample_kw, num_colors=nc, master_seed=ms)
    if spec is None:
        return SamplerSpec(num_colors=nc, master_seed=ms)
    for name, mine in (("num_colors", num_colors),
                       ("master_seed", master_seed)):
        theirs = getattr(spec, name)
        if mine is not None and mine != theirs:
            raise ValueError(f"{name}={mine} conflicts with "
                             f"spec.{name}={theirs} — set it in one place")
    return spec


def spec_from_sample_kw(sample_kw: dict, *, num_colors: int = 64,
                        master_seed: int = 0,
                        warn: bool = True) -> SamplerSpec:
    """Convert a legacy ``rrr.sample_batch``-kwargs dict to a `SamplerSpec`.

    The old untyped dict (``PoolConfig.sample_kw`` / ``run_imm(**kw)``)
    carried ``model``, ``tg_rev``/``use_kernel``, ``max_levels`` and
    ``sort_starts``.  A prebuilt ``tg_rev`` cannot ride along (the facade
    owns tiling) — its presence selects the tiled/kernel backend and the
    tile layout is rebuilt from the graph.
    """
    if warn:
        warnings.warn(
            "sample_kw dicts are deprecated — pass a repro.sampling."
            "SamplerSpec instead (converted automatically for now)",
            DeprecationWarning, stacklevel=3)
    kw = dict(sample_kw)
    diffusion = kw.pop("model", "ic")
    tg_rev = kw.pop("tg_rev", None)
    use_kernel = kw.pop("use_kernel", False)
    backend = "dense"
    tile_size = 128
    if tg_rev is not None:
        backend = "kernel" if use_kernel else "tiled"
        tile_size = int(getattr(tg_rev, "tile_size", 128))
    spec = SamplerSpec(
        diffusion=diffusion, backend=backend, num_colors=num_colors,
        master_seed=master_seed, max_iters=int(kw.pop("max_levels", 64)),
        sort_starts=bool(kw.pop("sort_starts", False)), tile_size=tile_size)
    if kw:
        raise ValueError(f"unknown sample_kw keys {sorted(kw)} — cannot "
                         "convert to SamplerSpec")
    return spec
