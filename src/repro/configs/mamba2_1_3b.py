"""mamba2-1.3b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50_280, attention="none",
    ssm_state=128,
)
