"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts,
3 leading dense layers.  MTP head omitted (DESIGN.md §Arch-applicability).
[arXiv:2412.19437; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=18432, vocab_size=129_280,
    attention="mla", head_dim=128, v_head_dim=128,
    q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    num_experts=256, num_shared_experts=1, top_k=8, moe_d_ff=2048,
    first_dense_layers=3, rope_theta=10_000.0,
    optimizer_state_dtype="bfloat16",
)
