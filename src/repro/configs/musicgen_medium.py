"""musicgen-medium [audio] — decoder-only over EnCodec tokens, 4 codebooks
(sum-of-embeddings in, one head per codebook out); EnCodec itself stubbed.
[arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    activation="gelu", gated_mlp=False, num_codebooks=4,
    rope_theta=10_000.0,
)
