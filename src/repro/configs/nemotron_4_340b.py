"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP (ungated).
[arXiv:2402.16819; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8,
    d_ff=73728, vocab_size=256_000, head_dim=192,
    activation="relu2", gated_mlp=False, rope_theta=10_000.0,
    optimizer_state_dtype="bfloat16",
)
