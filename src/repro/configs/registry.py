"""Architecture registry: ``--arch <id>`` resolution for every assigned
config plus reduced smoke variants (same family, tiny dims) used by tests.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = [
    "nemotron-4-340b", "qwen1.5-110b", "llama3.2-3b", "command-r-35b",
    "deepseek-v3-671b", "llama4-maverick-400b-a17b", "zamba2-2.7b",
    "phi-3-vision-4.2b", "mamba2-1.3b", "musicgen-medium",
]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get(name: str) -> ModelConfig:
    if name not in _MOD:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MOD[name]}")
    return mod.CONFIG


def smoke(name: str) -> ModelConfig:
    """Reduced config of the same family: small layers/width/experts/vocab,
    runnable on CPU in seconds.  Full configs are only ever lowered
    (ShapeDtypeStruct) by the dry-run."""
    cfg = get(name)
    d = 64
    heads = 4
    kv = min(cfg.num_kv_heads, heads) if cfg.num_kv_heads else 0
    if cfg.num_heads and cfg.num_kv_heads == cfg.num_heads:
        kv = heads
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, len_pattern(cfg)),
        d_model=d,
        num_heads=heads if cfg.num_heads else 0,
        num_kv_heads=kv,
        head_dim=16 if cfg.num_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_heads=2 if cfg.family in ("ssm", "hybrid") else 0,
        ssm_chunk=16,
        num_experts=4 if cfg.num_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        first_dense_layers=1 if cfg.first_dense_layers else 0,
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        rope_head_dim=8 if cfg.rope_head_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        num_patches=4 if cfg.num_patches else 0,
        attn_block_q=16, attn_block_k=16,
        dtype="float32",
    )
    if cfg.family == "moe":
        # keep the dense/moe interleave valid for a small layer count
        n = 4 if cfg.first_dense_layers or cfg.moe_every > 1 else 2
        updates["num_layers"] = n
    if cfg.family == "hybrid":
        updates["hybrid_attn_every"] = 2
        updates["num_layers"] = 4
    return dataclasses.replace(cfg, **updates)


def len_pattern(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.hybrid_attn_every
    if cfg.family == "moe" and cfg.moe_every > 1:
        return cfg.moe_every
    return 1
