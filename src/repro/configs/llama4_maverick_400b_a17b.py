"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared,
interleaved dense/MoE (every other layer).  Early-fusion frontend stubbed.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202_048, head_dim=128,
    num_experts=128, num_shared_experts=1, top_k=1, moe_d_ff=8192,
    moe_every=2, rope_theta=500_000.0,
    optimizer_state_dtype="bfloat16",
)
