"""phi-3-vision-4.2b [vlm] — phi3-mini backbone; CLIP frontend is a stub:
input_specs() supplies 64 precomputed patch embeddings (1024-d) that a
learned projection prepends to the text sequence.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32_064, head_dim=96,
    num_patches=64, rope_theta=10_000.0,
)
